"""Fusion benchmark: fine-grained chain/map workload, ``--fuse auto`` vs
``--fuse off`` per control channel.

The paper's natural style — many small pure functions — produces graphs
whose per-task compute is far below the control-plane round-trip
(BENCH_multihost: ~0.78 ms/task extra on TCP alone).  This benchmark
builds exactly that shape: ``chains`` parallel chains of ``chain_len``
tiny numpy tasks feeding a strided map stage and a final reduce (801
nodes at the defaults — dispatch cost must dominate the constant
pool-spawn floor both cells share), then measures wall clock with the fusion pass off
(one dispatch per task — the PR-1..4 runtime) vs ``auto`` (super-task
dispatch + batched control plane), on both the ``pipe`` and ``tcp``
control channels of the process backend.

Every cell is cross-checked **bit-for-bit** against
``execute_sequential`` — fusion changes granularity, never values — and a
SIGKILL-mid-run cell pins that lineage recovery at super-task granularity
still reproduces the oracle after losing a worker.

Writes ``BENCH_fusion.json`` at the repo root: wall clock, speedup,
``control_msgs`` / ``control_frames`` / ``dispatch_overhead_s`` /
``n_clusters`` per cell, so the win is visible in control-plane terms,
not just wall clock.

``--smoke`` is the CI gate: a smaller graph, both channels, asserting the
fused/unfused differential vs the oracle, the SIGKILL-recovery
differential with ``--fuse auto``, a >=2x reduction in dispatch
round-trips AND in wire frames, and a must-not-regress bound on fused
wall clock.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_fusion
        [--chains 12] [--chain-len 60] [--maps 80] [--workers 2]
        [--reps 7] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor

from .common import median, print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fusion.json")


def _chain_step(x, _k):
    return x * np.float32(1.0001) + np.float32(_k)


def build_finegrained(*, chains: int = 8, chain_len: int = 50,
                      maps: int = 90, payload_elems: int = 64) -> TaskGraph:
    """``chains`` parallel chains of ``chain_len`` tiny tasks -> strided
    map stage (``maps`` tasks, fan-in 2) -> scalar reduce.  Deterministic
    float32 numpy arithmetic; per-task compute is microseconds, so the
    unfused runtime is pure control-plane overhead."""
    g = TaskGraph()
    heads: List[int] = []
    for c in range(chains):
        def seed(_c=c, _n=payload_elems):
            return np.arange(_n, dtype=np.float32) * np.float32(_c + 1)
        prev = g.add_node(f"seed{c}", seed, (), {}, TaskKind.PURE, deps=())
        for k in range(chain_len - 1):
            def step(x, _k=k):
                return _chain_step(x, _k)
            prev = g.add_node(f"c{c}s{k}", step, (_Ref(prev),), {},
                              TaskKind.PURE, deps=(prev,))
        heads.append(prev)
    mapped: List[int] = []
    for j in range(maps):
        deps = (heads[j % chains], heads[(j * 3 + 1) % chains])

        def combine(a, b, _j=j):
            return a * np.float32(0.5) + b + np.float32(_j)

        mapped.append(g.add_node(
            f"map{j}", combine, tuple(_Ref(d) for d in deps), {},
            TaskKind.PURE, deps=deps))

    def reduce_all(*xs):
        return float(sum(float(x.sum()) for x in xs))

    out = g.add_node("reduce", reduce_all,
                     tuple(_Ref(d) for d in mapped), {},
                     TaskKind.PURE, deps=mapped)
    g.mark_output(out)
    return g


def bit_equal(got: Dict[int, Any], oracle: Dict[int, Any]) -> bool:
    """Bit-for-bit dict equality that understands array values."""
    if got.keys() != oracle.keys():
        return False
    for k, x in got.items():
        y = oracle[k]
        if isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
            if not (isinstance(x, np.ndarray) and isinstance(y, np.ndarray)
                    and x.dtype == y.dtype and x.shape == y.shape
                    and np.array_equal(x, y)):
                return False
        elif x != y:
            return False
    return True


_STAT_KEYS = ("dispatched", "n_clusters", "tasks_fused", "control_msgs",
              "control_frames", "steals")


def run_cell(channel: str, fuse: str, args, graph_kw: Dict[str, int],
             oracle: Dict[int, Any]) -> Dict[str, Any]:
    walls: List[float] = []
    stats: Dict[str, Any] = {}
    for _ in range(args.reps):
        g = build_finegrained(**graph_kw)
        ex = ClusterExecutor(args.workers, channel=channel, fuse=fuse,
                             progress_timeout=180.0)
        t0 = time.perf_counter()
        got = ex.run(g)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        ex.close()
        assert bit_equal(got, oracle), \
            f"{channel}/fuse={fuse}: diverged from the sequential oracle"
    # median-of-N: a 2-core container's scheduling jitter dwarfs the
    # effect under test, so the median is the headline (every sample is
    # recorded alongside for the skeptical reader)
    row = {"channel": channel, "fuse": fuse, "wall_s": median(walls),
           "wall_best_s": min(walls),
           "wall_samples_s": [round(w, 4) for w in sorted(walls)]}
    for k in _STAT_KEYS:
        row[k] = stats.get(k, 0)
    row["dispatch_overhead_s"] = round(
        stats.get("dispatch_overhead_s", 0.0), 4)
    return row


def recovery_cell(channel: str, args, graph_kw: Dict[str, int],
                  oracle: Dict[int, Any]) -> Dict[str, Any]:
    """SIGKILL a worker mid-run with ``fuse=auto``: recovery must replay
    exactly the lost super-tasks and the result must stay bit-for-bit."""
    g = build_finegrained(**graph_kw)
    ex = ClusterExecutor(args.workers, channel=channel, fuse="auto",
                         fail_worker=(0, 3), progress_timeout=180.0)
    got = ex.run(g)
    ex.close()
    assert bit_equal(got, oracle), \
        f"{channel}: fused SIGKILL recovery diverged from the oracle"
    assert ex.stats["failures"] == 1, ex.stats
    assert ex.stats["recomputed"] > 0, ex.stats
    return {"channel": channel, "failures": ex.stats["failures"],
            "recomputed": ex.stats["recomputed"],
            "n_clusters": ex.stats["n_clusters"]}


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--chains", type=int, default=12)
    ap.add_argument("--chain-len", type=int, default=60)
    ap.add_argument("--maps", type=int, default=80)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: differential + must-not-regress gate, "
                         "smaller graph")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.chains = min(args.chains, 4)
        args.chain_len = min(args.chain_len, 30)
        args.maps = min(args.maps, 30)
        args.reps = 3       # median: a loaded CI box jitters single runs

    graph_kw = {"chains": args.chains, "chain_len": args.chain_len,
                "maps": args.maps}
    g = build_finegrained(**graph_kw)
    n_nodes = len(g.nodes)
    oracle = execute_sequential(g)

    rows: List[Dict[str, Any]] = []
    speedups: Dict[str, float] = {}
    dispatch_ratio: Dict[str, float] = {}
    frame_ratio: Dict[str, float] = {}
    for channel in ("pipe", "tcp"):
        off = run_cell(channel, "off", args, graph_kw, oracle)
        auto = run_cell(channel, "auto", args, graph_kw, oracle)
        rows += [off, auto]
        speedups[channel] = off["wall_s"] / max(auto["wall_s"], 1e-9)
        dispatch_ratio[channel] = off["dispatched"] / \
            max(auto["dispatched"], 1)
        frame_ratio[channel] = off["control_frames"] / \
            max(auto["control_frames"], 1)

    recovery = [recovery_cell(ch, args, graph_kw, oracle)
                for ch in ("pipe", "tcp")]

    if args.smoke:
        for ch in ("pipe", "tcp"):
            # deterministic gates: fusion must cut dispatch round-trips,
            # batching must cut wire writes (both >=2x on this shape)
            assert dispatch_ratio[ch] >= 2.0, \
                (f"{ch}: fusion cut dispatches only "
                 f"{dispatch_ratio[ch]:.2f}x (expected >=2x): {rows}")
            assert frame_ratio[ch] >= 2.0, \
                (f"{ch}: batching+fusion cut control frames only "
                 f"{frame_ratio[ch]:.2f}x (expected >=2x): {rows}")
        # must-not-regress: fused wall (median of reps) may never exceed
        # unfused by more than CI scheduling noise — a structural
        # regression shows up as a multiple, not a factor of 1.5
        for ch in ("pipe", "tcp"):
            off_w = next(r["wall_s"] for r in rows
                         if r["channel"] == ch and r["fuse"] == "off")
            auto_w = next(r["wall_s"] for r in rows
                          if r["channel"] == ch and r["fuse"] == "auto")
            assert auto_w <= off_w * 1.5, \
                f"{ch}: fused wall {auto_w:.3f}s regressed vs off {off_w:.3f}s"
        print(f"smoke: {n_nodes}-node fine-grained graph x{args.workers} "
              "workers — fused runs bit-identical (healthy + SIGKILL); "
              "dispatches cut "
              + ", ".join(f"{ch} {r:.1f}x"
                          for ch, r in dispatch_ratio.items())
              + "; wire frames cut "
              + ", ".join(f"{ch} {r:.1f}x"
                          for ch, r in frame_ratio.items()),
              flush=True)

    payload = {
        "config": {"chains": args.chains, "chain_len": args.chain_len,
                   "maps": args.maps, "n_nodes": n_nodes,
                   "workers": args.workers, "reps": args.reps,
                   "smoke": args.smoke},
        "cells": rows,
        "recovery": recovery,
        "speedup": speedups,
        "dispatch_reduction": dispatch_ratio,
        "control_frame_reduction": frame_ratio,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows(f"fine-grained {n_nodes}-node chain/map graph "
               f"({args.workers} workers) per channel x fuse", rows)
    print("\nfusion speedup: "
          + ", ".join(f"{ch} {s:.2f}x" for ch, s in speedups.items())
          + "; dispatches cut "
          + ", ".join(f"{ch} {r:.1f}x" for ch, r in dispatch_ratio.items())
          + "; wire frames cut "
          + ", ".join(f"{ch} {r:.1f}x" for ch, r in frame_ratio.items())
          + f" -> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
