"""Speculation benchmark: straggler-injected shuffle, speculation on vs off.

The paper's purity argument makes task duplication free: a pure task can
be re-executed anywhere, any number of times, and the first result wins.
This benchmark measures what that buys on a *tail-latency* workload — a
shuffle whose producers include injected stragglers — per control channel
(``pipe`` and ``tcp``), with ``speculate_after`` off vs on.

**Straggler injection.**  A straggler task's *value* is deterministic (the
differential against ``execute_sequential`` stays bit-for-bit), but its
*first* execution sleeps: the task atomically creates a sentinel file
(``O_EXCL``) and only the creator sleeps.  A speculative twin launched
after the original is already asleep sees the sentinel and returns
immediately — exactly the "re-execute elsewhere, first result wins"
shape.  Every non-straggler task sleeps a small ``work_s`` so the
runtime's EWMA calibration sees realistic durations (and therefore only
speculates on genuinely overdue tasks, well after the original created
its sentinel).

Writes ``BENCH_speculation.json`` at the repo root: wall clock per
(channel, speculation) cell, the speedup per channel, and the speculation
counters (``n_speculative`` / ``speculative_wins`` /
``speculative_wasted_s``) that bound the duplicated work.

``--smoke`` is the CI gate: 2 workers, one injected straggler, assert the
speculative twin wins and the differential vs the sequential oracle stays
bit-for-bit, on both the pipe and TCP channels.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_speculation
        [--sleep-s 2.0] [--work-s 0.2] [--consumers 12] [--workers 2]
        [--speculate-after 2.5] [--reps 1] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor

from .common import median, print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_speculation.json")


def build_straggler_shuffle(marker_dir: str, *, producers: int = 4,
                            stragglers: int = 1, consumers: int = 12,
                            fan_in: int = 2, payload_elems: int = 4096,
                            sleep_s: float = 2.0,
                            work_s: float = 0.2) -> TaskGraph:
    """Producers (the first ``stragglers`` of them injected) -> strided
    shuffle combine (each sleeping ``work_s`` of simulated compute) ->
    scalar reduce.  Values are deterministic; only timing varies."""
    g = TaskGraph()
    for i in range(producers):
        if i < stragglers:
            def produce(_i=i, _d=marker_dir, _s=sleep_s, _n=payload_elems):
                path = os.path.join(_d, f"straggler{_i}")
                try:        # O_EXCL: exactly one execution is the creator
                    fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                except FileExistsError:
                    fd = -1
                if fd >= 0:
                    os.close(fd)
                    time.sleep(_s)      # ... and only the creator straggles
                return np.arange(_n, dtype=np.float32) * np.float32(_i + 1)
        else:
            def produce(_i=i, _w=work_s, _n=payload_elems):
                time.sleep(_w)
                return np.arange(_n, dtype=np.float32) * np.float32(_i + 1)
        g.add_node(f"produce{i}", produce, (), {}, TaskKind.PURE,
                   deps=(), cost=1.0)
    for j in range(consumers):
        deps = [(j * 3 + k) % producers for k in range(fan_in)]

        def combine(*xs, _j=j, _w=work_s):
            time.sleep(_w)
            acc = xs[0] + np.float32(_j)
            for x in xs[1:]:
                acc = acc + x
            return acc

        g.add_node(f"combine{j}", combine, tuple(_Ref(d) for d in deps),
                   {}, TaskKind.PURE, deps=deps, cost=1.0)
    rdeps = list(range(producers, producers + consumers))

    def reduce_all(*xs):
        return float(sum(float(x.sum()) for x in xs))

    g.add_node("reduce", reduce_all, tuple(_Ref(d) for d in rdeps), {},
               TaskKind.PURE, deps=rdeps, cost=1.0)
    g.mark_output(producers + consumers)
    return g


def run_cell(channel: str, speculate_after: Optional[float], args,
             oracle: float, fuse: str = "off") -> Dict[str, Any]:
    """One (channel, speculation[, fusion]) cell; a fresh sentinel dir per
    rep so every run injects the same straggler.  The fused cell measures
    the cooperative mid-task cancel: a losing twin of a fused super-task
    aborts at the next member boundary instead of running the whole frame,
    so ``speculative_wasted_s`` stays bounded by the straggler's own
    sleep, not the full chain."""
    walls: List[float] = []
    stats: Dict[str, Any] = {}
    for _ in range(args.reps):
        with tempfile.TemporaryDirectory(prefix="rrspec") as marker:
            g = build_straggler_shuffle(
                marker, producers=args.producers,
                stragglers=args.stragglers, consumers=args.consumers,
                fan_in=args.fan_in, sleep_s=args.sleep_s,
                work_s=args.work_s)
            ex = ClusterExecutor(args.workers, channel=channel,
                                 speculate_after=speculate_after,
                                 fuse=fuse,
                                 progress_timeout=180.0)
            t0 = time.perf_counter()
            got = ex.run(g)
            walls.append(time.perf_counter() - t0)
            stats = dict(ex.stats)
            ex.close()
            out = args.producers + args.consumers
            assert got[out] == oracle, \
                f"{channel}/speculate={speculate_after}: {got[out]} != " \
                f"oracle {oracle}"
    return {"channel": channel, "fuse": fuse,
            "speculate_after": speculate_after or 0.0,
            "wall_s": median(walls),
            "n_speculative": stats.get("n_speculative", 0),
            "speculative_wins": stats.get("speculative_wins", 0),
            "speculative_wasted_s": round(
                stats.get("speculative_wasted_s", 0.0), 3)}


def smoke_twin_wins(args, oracle: float) -> None:
    """CI gate: on both channels, the injected straggler's speculative
    twin must win and the result must stay bit-for-bit oracle-equal."""
    for channel in ("pipe", "tcp"):
        with tempfile.TemporaryDirectory(prefix="rrspec") as marker:
            g = build_straggler_shuffle(
                marker, producers=args.producers,
                stragglers=args.stragglers, consumers=args.consumers,
                fan_in=args.fan_in, sleep_s=args.sleep_s,
                work_s=args.work_s)
            ex = ClusterExecutor(args.workers, channel=channel,
                                 speculate_after=args.speculate_after,
                                 progress_timeout=120.0)
            got = ex.run(g)
            out = args.producers + args.consumers
            assert got[out] == oracle, \
                f"{channel}: speculative run diverged from the oracle"
            assert ex.stats["n_speculative"] >= 1, ex.stats
            assert ex.stats["speculative_wins"] >= 1, \
                f"{channel}: no speculative twin won: {ex.stats}"
            ex.close()
    print(f"smoke: straggler shuffle x{args.workers} workers — twin won "
          "and stayed bit-identical to the oracle (pipe + tcp)",
          flush=True)


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--producers", type=int, default=4)
    ap.add_argument("--stragglers", type=int, default=1)
    ap.add_argument("--consumers", type=int, default=12)
    ap.add_argument("--fan-in", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--sleep-s", type=float, default=2.0,
                    help="injected straggler's first-execution sleep")
    ap.add_argument("--work-s", type=float, default=0.2,
                    help="per-task simulated compute (EWMA calibration)")
    ap.add_argument("--speculate-after", type=float, default=2.5)
    ap.add_argument("--reps", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: twin-wins + differential gate, small sleeps")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.sleep_s = min(args.sleep_s, 1.2)
        args.work_s = min(args.work_s, 0.1)
        args.consumers = min(args.consumers, 8)
        args.reps = 1

    # deterministic oracle: the straggler's sentinel dir is fresh, but the
    # VALUE is sleep-independent, so one sequential run fixes the answer
    with tempfile.TemporaryDirectory(prefix="rrspec") as marker:
        seq = execute_sequential(build_straggler_shuffle(
            marker, producers=args.producers, stragglers=args.stragglers,
            consumers=args.consumers, fan_in=args.fan_in,
            sleep_s=0.0, work_s=0.0))
    oracle = seq[args.producers + args.consumers]

    if args.smoke:
        smoke_twin_wins(args, oracle)

    rows: List[Dict[str, Any]] = []
    speedups: Dict[str, float] = {}
    for channel in ("pipe", "tcp"):
        off = run_cell(channel, None, args, oracle)
        on = run_cell(channel, args.speculate_after, args, oracle)
        rows += [off, on]
        speedups[channel] = off["wall_s"] / max(on["wall_s"], 1e-9)
    # fused cell: losing twins of fused super-tasks abort at member
    # boundaries (cooperative cancel), bounding speculative_wasted_s
    rows.append(run_cell("pipe", args.speculate_after, args, oracle,
                         fuse="auto"))

    payload = {
        "config": {
            "producers": args.producers, "stragglers": args.stragglers,
            "consumers": args.consumers, "fan_in": args.fan_in,
            "workers": args.workers, "sleep_s": args.sleep_s,
            "work_s": args.work_s,
            "speculate_after": args.speculate_after,
            "reps": args.reps, "smoke": args.smoke,
        },
        "cells": rows,
        "speedup": speedups,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows(f"straggler shuffle ({args.stragglers} straggler(s) x "
               f"{args.sleep_s}s, {args.workers} workers) per channel x "
               "speculation", rows)
    print("\nspeculation speedup: "
          + ", ".join(f"{ch} {s:.2f}x" for ch, s in speedups.items())
          + f" -> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
