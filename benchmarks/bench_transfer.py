"""Data-plane benchmark: driver-mediated vs zero-copy direct transfers.

A wide shuffle-style graph — ``producers`` tasks each emit a float32 array
of ``payload_mb`` MiB, ``consumers`` tasks each combine ``fan_in`` of them
(strided, so most reads are cross-worker), and a final reduce collapses to
a scalar — is executed twice on the process backend: once with
``transport="driver"`` (the PR-1 relay: every cross-worker value is
double-pickled through the driver pipe) and once with the zero-copy plane
(``shm``, or ``sock`` where shared memory is unavailable).

Writes ``BENCH_transfer.json`` at the repo root with wall times, the bytes
that crossed the driver pipe vs moved directly, and the speedup /
pipe-byte-reduction ratios the acceptance criteria pin (>= 2x wall, >= 10x
fewer driver-pipe bytes at the default payload).  ``--smoke`` shrinks the
payload for CI.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_transfer [--payload-mb 4]
        [--producers 8] [--consumers 8] [--fan-in 4] [--workers 4]
        [--reps 3] [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, serde

from .common import print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_transfer.json")


def build_shuffle(producers: int, consumers: int, fan_in: int,
                  payload_elems: int) -> TaskGraph:
    """Producers -> strided all-to-some shuffle -> elementwise combine ->
    scalar reduce.  Arrays are deterministic, so every backend/transport
    must agree with the sequential oracle bit-for-bit."""
    g = TaskGraph()
    for i in range(producers):

        def produce(_i=i, _n=payload_elems):
            return np.arange(_n, dtype=np.float32) * np.float32(_i + 1)

        g.add_node(f"produce{i}", produce, (), {}, TaskKind.PURE,
                   deps=(), cost=1.0)
    for j in range(consumers):
        deps = [(j * 3 + k) % producers for k in range(fan_in)]

        def combine(*xs, _j=j):
            acc = xs[0] + np.float32(_j)
            for x in xs[1:]:
                acc = acc + x
            return acc

        g.add_node(f"combine{j}", combine, tuple(_Ref(d) for d in deps),
                   {}, TaskKind.PURE, deps=deps, cost=1.0)
    rdeps = list(range(producers, producers + consumers))

    def reduce_all(*xs):
        return float(sum(float(x.sum()) for x in xs))

    g.add_node("reduce", reduce_all, tuple(_Ref(d) for d in rdeps), {},
               TaskKind.PURE, deps=rdeps, cost=1.0)
    g.mark_output(producers + consumers)
    return g


def run_once(graph: TaskGraph, transport: str, workers: int,
             reps: int, pipeline_depth: int = 4) -> Dict[str, Any]:
    """Median wall time + data-plane counters for one transport."""
    walls: List[float] = []
    stats: Dict[str, int] = {}
    used = transport
    for _ in range(reps):
        ex = ClusterExecutor(workers, transport=transport,
                             outputs_only=True, progress_timeout=180.0,
                             pipeline_depth=pipeline_depth)
        t0 = time.perf_counter()
        ex.run(graph)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        used = ex.transport_used or transport
    walls.sort()
    return {
        "transport": used,
        "wall_s": walls[len(walls) // 2],
        "bytes_driver_pipe": stats.get("bytes_driver", 0),
        "bytes_direct": stats.get("bytes_direct", 0),
        "bytes_moved": stats.get("bytes_moved", 0),
        "transfers_direct": stats.get("transfers_direct", 0),
        "transfers_driver": stats.get("transfers_driver", 0),
    }


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--payload-mb", type=float, default=24.0)
    ap.add_argument("--producers", type=int, default=6)
    ap.add_argument("--consumers", type=int, default=8)
    ap.add_argument("--fan-in", type=int, default=4)
    ap.add_argument("--wide-consumers", type=int, default=16,
                    help="consumer count for the wide-shuffle cell: every "
                         "consumer reads every producer (fan_in = "
                         "producers), the point-to-point baseline shape "
                         "bench_collectives compares its tree lowering "
                         "against")
    ap.add_argument("--pipeline-depth", type=int, default=4)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payloads / single rep for CI")
    ap.add_argument("--check", action="store_true",
                    help="also pin both transports to the sequential oracle")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.payload_mb = min(args.payload_mb, 1.0)
        args.producers = min(args.producers, 4)
        args.consumers = min(args.consumers, 4)
        args.workers = min(args.workers, 2)
        # the narrow cell keeps its cheap capped fan-in, but the wide cell
        # must stay *wide* (fan_in == producers) even in CI — it is the
        # recorded point-to-point baseline for the collectives A/B, and a
        # capped fan-in would silently measure a different shape
        args.fan_in = min(args.fan_in, 3)
        args.wide_consumers = min(args.wide_consumers, 6)
        args.reps = 1

    payload_elems = max(1, int(args.payload_mb * (1 << 20) / 4))
    graph = build_shuffle(args.producers, args.consumers, args.fan_in,
                          payload_elems)
    zero_copy = serde.resolve_transport("auto")
    if zero_copy == "driver":
        print("bench_transfer: no shm and no unix sockets available; "
              "nothing to compare", flush=True)
        return {}

    if args.check or args.smoke:
        seq = execute_sequential(graph)
        want = float(seq[graph.outputs[0]])
        for transport in ("driver", zero_copy):
            ex = ClusterExecutor(args.workers, transport=transport,
                                 outputs_only=True, progress_timeout=180.0,
                                 pipeline_depth=args.pipeline_depth)
            got = float(ex.run(graph)[graph.outputs[0]])
            assert got == want, (transport, got, want)
        print("oracle check: both transports bit-identical", flush=True)

    results = {t: run_once(graph, t, args.workers, args.reps,
                           args.pipeline_depth)
               for t in ("driver", zero_copy)}
    drv, zc = results["driver"], results[zero_copy]
    speedup = drv["wall_s"] / zc["wall_s"] if zc["wall_s"] > 0 else 0.0
    pipe_reduction = (drv["bytes_driver_pipe"] /
                      max(1, zc["bytes_driver_pipe"]))

    # wide-shuffle cell: every consumer reads every producer — the N×M
    # point-to-point fan-in that bench_collectives' tree lowering is
    # measured against; recorded here so the baseline lives in the same
    # JSON trajectory
    wide_graph = build_shuffle(args.producers, args.wide_consumers,
                               args.producers, payload_elems)
    if args.check or args.smoke:
        seq = execute_sequential(wide_graph)
        want = float(seq[wide_graph.outputs[0]])
        for transport in ("driver", zero_copy):
            ex = ClusterExecutor(args.workers, transport=transport,
                                 outputs_only=True, progress_timeout=180.0,
                                 pipeline_depth=args.pipeline_depth)
            got = float(ex.run(wide_graph)[wide_graph.outputs[0]])
            assert got == want, ("wide", transport, got, want)
        print("oracle check: wide-shuffle cell bit-identical on both "
              "transports", flush=True)
    wide = {t: run_once(wide_graph, t, args.workers, args.reps,
                        args.pipeline_depth)
            for t in ("driver", zero_copy)}
    wide_drv, wide_zc = wide["driver"], wide[zero_copy]
    wide_speedup = (wide_drv["wall_s"] / wide_zc["wall_s"]
                    if wide_zc["wall_s"] > 0 else 0.0)

    payload = {
        "config": {
            "payload_mb": args.payload_mb, "producers": args.producers,
            "consumers": args.consumers, "fan_in": args.fan_in,
            "wide_consumers": args.wide_consumers,
            "workers": args.workers, "reps": args.reps,
            "smoke": args.smoke, "tasks": len(graph.nodes),
            "wide_tasks": len(wide_graph.nodes),
        },
        "driver": drv,
        "zero_copy": zc,
        "speedup": speedup,
        "driver_pipe_byte_reduction": pipe_reduction,
        "wide": {"driver": wide_drv, "zero_copy": wide_zc,
                 "speedup": wide_speedup},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows("transfer: driver-relay vs zero-copy "
               f"({args.payload_mb} MiB payloads)",
               [{"path": k, **v} for k, v in results.items()]
               + [{"path": f"wide/{k}", **v} for k, v in wide.items()])
    print(f"\nspeedup {speedup:.2f}x (wide {wide_speedup:.2f}x), "
          f"driver-pipe bytes reduced "
          f"{pipe_reduction:.0f}x -> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
