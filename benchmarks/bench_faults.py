"""Chaos benchmark: the loss/delay/partition matrix under fault injection.

Every cell runs the same shuffle workload through a seeded
:class:`repro.faults.FaultPlan` — frame loss (keepalives), frame delay,
duplication + reordering, a timed partition (sever), and a flaky data
plane (injected ``TransferLost`` on peer fetches) — per control channel
(``pipe`` and ``tcp``), and asserts the result stays **bit-for-bit equal
to** ``execute_sequential``.  The interesting number per cell is not the
wall clock but what the policy layer did: suspicion episodes healed
without recompute, driver-relay fallbacks that saved a lineage replay,
and the retry counts the :class:`repro.faults.RetryPolicy` absorbed.

Writes ``BENCH_faults.json`` at the repo root.

``--smoke`` is the CI chaos gate: a fixed-seed plan combining every fault
class against a 50-node graph on the TCP channel, asserted bit-for-bit.

``--soak`` is the nightly randomized gate: same matrix, but the plan seed
comes from the clock (or ``--seed``) and is **printed first** — a chaos
failure is reproduced by re-running with the logged seed.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_faults
        [--nodes 120] [--workers 3] [--reps 1] [--seed 7]
        [--smoke | --soak]
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time
from typing import Any, Dict, List, Optional

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor
from repro.faults import FaultPlan, RetryPolicy

from .common import median

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_faults.json")

#: policy counters worth reporting per cell
POLICY_STATS = ("suspected", "healed", "quarantined", "readmitted",
                "relay_fallbacks", "deplosts", "recomputed", "failures")


def build_graph(nodes: int, seed: int, payload: int = 512) -> TaskGraph:
    """Arithmetic shuffle with byte payloads large enough to ride the
    data plane (the bench runs with a small ``shm_threshold``), so fetch
    faults have transfers to hit."""
    rng = random.Random(seed)
    g = TaskGraph()
    producers = max(3, nodes // 8)
    for i in range(producers):
        def produce(_i=i, _n=payload):
            return bytes((_i * 37 + k) % 251 for k in range(_n))
        g.add_node(f"p{i}", produce, (), {}, TaskKind.PURE,
                   deps=(), cost=1.0)
    for i in range(producers, nodes - 1):
        lo = max(0, i - 2 * producers)
        deps = sorted(rng.sample(range(lo, i), k=min(2, i - lo)))

        def mix(*xs, _i=i):
            acc = 0
            for x in xs:
                acc = (acc * 31 + (sum(x) if isinstance(x, bytes) else x)) \
                    % 1_000_003
            return (acc + _i) % 1_000_003

        g.add_node(f"t{i}", mix, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    rdeps = list(range(max(0, nodes - 9), nodes - 1))

    def reduce_all(*xs):
        return sum(int(x) if not isinstance(x, bytes) else sum(x)
                   for x in xs)

    g.add_node("reduce", reduce_all, tuple(_Ref(d) for d in rdeps), {},
               TaskKind.PURE, deps=rdeps, cost=1.0)
    g.mark_output(nodes - 1)
    return g


def matrix_plans(seed: int) -> Dict[str, Optional[FaultPlan]]:
    """The loss/delay/partition matrix, one fresh plan per call (plans
    carry firing counters, so cells never share an instance).  ``drop``
    is scoped to keepalives: control verbs ride TCP's reliable-or-dead
    contract, and dropping them would model a fault TCP cannot produce."""
    return {
        "clean": None,
        "loss": FaultPlan(seed=seed).drop(verb="hb", prob=0.5),
        "delay": FaultPlan(seed=seed + 1).delay(0.02, prob=0.3),
        "dup_reorder": (FaultPlan(seed=seed + 2)
                        .duplicate(prob=0.25).reorder(prob=0.25)),
        "partition": (FaultPlan(seed=seed + 3)
                      .sever(window=0.8, src=1, verb="done", nth=2)),
        "fetch_flake": FaultPlan(seed=seed + 4).fail_fetch(prob=0.6),
        "everything": (FaultPlan(seed=seed + 5)
                       .drop(verb="hb", prob=0.4)
                       .delay(0.01, prob=0.2)
                       .duplicate(prob=0.2)
                       .reorder(prob=0.2)
                       .sever(window=0.5, src=1, verb="done", nth=3)
                       .fail_fetch(prob=0.4)),
    }


def run_cell(channel: str, fault: str, plan: Optional[FaultPlan],
             args) -> Dict[str, Any]:
    g = build_graph(args.nodes, args.seed)
    seq = execute_sequential(g)
    walls: List[float] = []
    stats: Dict[str, Any] = {}
    for _ in range(args.reps):
        kw: Dict[str, Any] = dict(
            fault_plan=plan, transport="sock", shm_threshold=128,
            fetch_retry=RetryPolicy(attempts=3, base_delay=0.01,
                                    jitter=0.5),
            progress_timeout=120.0)
        if channel == "tcp":
            kw.update(channel="tcp", heartbeat_interval=0.1,
                      heartbeat_timeout=1.0, suspect_grace=5.0)
        ex = ClusterExecutor(args.workers, **kw)
        t0 = time.perf_counter()
        got = ex.run(g)
        walls.append(time.perf_counter() - t0)
        assert got == seq, \
            f"{channel}/{fault}: diverged from the sequential oracle"
        stats = {k: ex.stats.get(k, 0) for k in POLICY_STATS}
        ex.close()
    row = {"channel": channel, "fault": fault,
           "wall_s": round(median(walls), 4), **stats,
           "injected": plan.stats() if plan is not None else {}}
    print(f"  {channel:4s} {fault:12s} wall={row['wall_s']:7.3f}s "
          + " ".join(f"{k}={stats[k]}" for k in POLICY_STATS
                     if stats.get(k)), flush=True)
    return row


def smoke(args) -> None:
    """CI chaos gate: fixed-seed everything-plan, 50-node graph, TCP
    channel, bit-for-bit differential."""
    g = build_graph(50, args.seed)
    seq = execute_sequential(g)
    plan = matrix_plans(args.seed)["everything"]
    ex = ClusterExecutor(args.workers, channel="tcp", fault_plan=plan,
                         transport="sock", shm_threshold=128,
                         heartbeat_interval=0.1, heartbeat_timeout=1.0,
                         suspect_grace=5.0,
                         fetch_retry=RetryPolicy(attempts=3,
                                                 base_delay=0.01),
                         progress_timeout=120.0)
    got = ex.run(g)
    assert got == seq, "chaos smoke diverged from the sequential oracle"
    injected = plan.stats()
    assert injected, "chaos smoke injected nothing — plan mis-addressed?"
    ex.close()
    print(f"smoke: 50-node TCP chaos differential bit-for-bit "
          f"(seed={args.seed}, injected={injected}, "
          f"policy={{suspected: {ex.stats['suspected']}, healed: "
          f"{ex.stats['healed']}, relay_fallbacks: "
          f"{ex.stats['relay_fallbacks']}, recomputed: "
          f"{ex.stats['recomputed']}}})", flush=True)


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=120)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--reps", type=int, default=1)
    ap.add_argument("--seed", type=int, default=None,
                    help="fault-plan seed (default 7; --soak draws one "
                         "from the clock and logs it)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: fixed-seed 50-node TCP chaos differential")
    ap.add_argument("--soak", action="store_true",
                    help="nightly: randomized seed, logged for replay")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.seed is None:
        args.seed = int(time.time()) % 1_000_000 if args.soak else 7
    # the replay contract: the seed is the first thing on stdout, so a
    # failed nightly soak is reproduced with --seed <logged>
    print(f"chaos {'soak' if args.soak else 'matrix'} seed={args.seed}",
          flush=True)

    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        smoke(args)
        return {}

    rows: List[Dict[str, Any]] = []
    for channel in ("pipe", "tcp"):
        for fault, plan in matrix_plans(args.seed).items():
            rows.append(run_cell(channel, fault, plan, args))

    payload = {
        "config": {"nodes": args.nodes, "workers": args.workers,
                   "reps": args.reps, "seed": args.seed,
                   "soak": args.soak},
        "cells": rows,
        "differential": "all cells bit-for-bit vs execute_sequential",
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {out}", flush=True)
    return payload


if __name__ == "__main__":
    sys.exit(0 if main(sys.argv[1:]) is not None else 1)
