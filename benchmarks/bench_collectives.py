"""Collectives benchmark: staged tree reduction/broadcast vs point-to-point
fan-in, per control channel and consumer count.

The workload is the shape wide training/serving graphs are made of: ``N``
producers each emit a float32 payload, **every** one of ``M`` consumers
needs the sum of all of them, and a final scalar reduce collapses the
consumer outputs.  Written point-to-point — each consumer lists all N
producers and folds them itself — that is N×M payload transfers and
M×(N-1) array additions.  Written with first-class collective nodes
(``all_reduce`` + ``broadcast``, lowered by
``repro.core.collectives.lower_collectives``), the reduction happens once
along a worker tree and the result fans out through a replication tree:
~(N + M) transfers and N-1 additions, log-depth critical path.

Both graphs compute the same values with the **same bracketing**
(``tree_fold`` with the same arity), so every cell is cross-checked
bit-for-bit against ``execute_sequential`` and the two modes must agree
with each other exactly.  A SIGKILL cell kills a worker mid-tree and pins
that subtree-bounded lineage recovery still reproduces the oracle.

Writes ``BENCH_collectives.json`` at the repo root: wall clock per
channel × consumers × mode, bytes moved, transfer counts, and the
collective-vs-p2p speedup per cell (the acceptance headline is the
highest consumer count on each channel).

``--smoke`` is the CI gate: tiny payloads, both channels, asserting the
oracle differential in every cell (healthy + SIGKILL), that lowering
actually produced staged hops, a data-plane byte reduction, and a
must-not-regress bound on collective wall clock.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_collectives
        [--producers 16] [--consumers 4 32] [--payload-mb 4.0]
        [--workers 4] [--arity 4] [--reps 3] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.collectives import (DEFAULT_ARITY, add_all_reduce,
                                    add_broadcast, resolve_op, tree_fold)
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor

from .common import median, print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_collectives.json")


class _Produce:
    """Deterministic float32 payload; module-level class so spawn/TCP
    workers can unpickle it."""
    __slots__ = ("i", "n")

    def __init__(self, i: int, n: int):
        self.i, self.n = i, n

    def __call__(self):
        return np.arange(self.n, dtype=np.float32) * np.float32(self.i + 1)


class _Consume:
    """Reads the (already reduced) array: one weighted sum per consumer."""
    __slots__ = ("j",)

    def __init__(self, j: int):
        self.j = j

    def __call__(self, r):
        return float((r * np.float32(self.j + 1)).sum())


class _FoldConsume:
    """Point-to-point baseline consumer: pull ALL producer payloads and
    fold them locally with the collective's own bracketing
    (:func:`tree_fold`, same arity), then apply the consumer transform —
    so baseline and collective cells are bit-comparable."""
    __slots__ = ("j", "arity")

    def __init__(self, j: int, arity: int):
        self.j, self.arity = j, arity

    def __call__(self, *xs):
        _, combine = resolve_op("sum")
        r = tree_fold(list(xs), combine, self.arity)
        return float((r * np.float32(self.j + 1)).sum())


def _sum_floats(*xs):
    return float(sum(xs))


def edge_payload_bytes(g: TaskGraph) -> int:
    """Static data-plane demand of the *lowered* graph: every argument
    edge priced at its producer's ``out_bytes`` — what a cluster pays when
    consumers land on different workers/hosts (per-worker caching can hide
    some of it on a 2-worker box, which is why the smoke gate is static)."""
    from repro.core.collectives import lower_collectives
    lowered, _ = lower_collectives(g, "auto")
    total = 0
    for node in lowered.nodes.values():
        for r in node.args:
            tid = getattr(r, "tid", None)
            if tid is not None:
                total += lowered.nodes[tid].out_bytes
    return total


def _add_producers(g: TaskGraph, producers: int,
                   payload_elems: int) -> List[int]:
    return [g.add_node(f"produce{i}", _Produce(i, payload_elems), (), {},
                       TaskKind.PURE, deps=(), cost=1.0,
                       out_bytes=payload_elems * 4)
            for i in range(producers)]


def _add_reduce_out(g: TaskGraph, cons: List[int]) -> None:
    out = g.add_node("final", _sum_floats, tuple(_Ref(c) for c in cons),
                     {}, TaskKind.PURE, deps=tuple(cons))
    g.mark_output(out)


def build_p2p(producers: int, consumers: int, payload_elems: int,
              arity: int) -> TaskGraph:
    """Every consumer lists every producer: N×M edges, M local folds."""
    g = TaskGraph()
    prods = _add_producers(g, producers, payload_elems)
    cons = [g.add_node(f"consume{j}", _FoldConsume(j, arity),
                       tuple(_Ref(p) for p in prods), {}, TaskKind.PURE,
                       deps=tuple(prods), cost=1.0)
            for j in range(consumers)]
    _add_reduce_out(g, cons)
    return g


def build_collective(producers: int, consumers: int, payload_elems: int,
                     arity: int) -> TaskGraph:
    """One ``all_reduce`` + one ``broadcast`` carry the group traffic."""
    g = TaskGraph()
    prods = _add_producers(g, producers, payload_elems)
    ar = add_all_reduce(g, prods, "sum", arity=arity,
                        out_bytes=payload_elems * 4)
    bc = add_broadcast(g, ar, arity=arity, out_bytes=payload_elems * 4)
    cons = [g.add_node(f"consume{j}", _Consume(j), (_Ref(bc),), {},
                       TaskKind.PURE, deps=(bc,), cost=1.0)
            for j in range(consumers)]
    _add_reduce_out(g, cons)
    return g


_STAT_KEYS = ("dispatched", "bytes_moved", "transfers_direct",
              "transfers_driver", "collective_roots", "collective_stages")


def run_cell(channel: str, mode: str, consumers: int, args,
             want_out: float) -> Dict[str, Any]:
    """Median-of-reps wall clock for one (channel, mode, M) cell; every
    rep's output is pinned to the sequential oracle's scalar."""
    build = build_p2p if mode == "p2p" else build_collective
    walls: List[float] = []
    stats: Dict[str, Any] = {}
    for _ in range(args.reps):
        g = build(args.producers, consumers, args.payload_elems, args.arity)
        ex = ClusterExecutor(args.workers, channel=channel,
                             collectives="auto", outputs_only=True,
                             progress_timeout=180.0)
        t0 = time.perf_counter()
        got = ex.run(g)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        ex.close()
        out = got[g.outputs[0]]
        assert out == want_out, \
            (f"{channel}/{mode}/M={consumers}: output {out!r} diverged "
             f"from the sequential oracle {want_out!r}")
    row = {"channel": channel, "mode": mode, "consumers": consumers,
           "wall_s": median(walls), "wall_best_s": min(walls),
           "wall_samples_s": [round(w, 4) for w in sorted(walls)]}
    for k in _STAT_KEYS:
        row[k] = stats.get(k, 0)
    return row


def recovery_cell(channel: str, consumers: int, args,
                  want_out: float) -> Dict[str, Any]:
    """SIGKILL a worker mid-tree: subtree-bounded recovery must still
    reproduce the oracle bit-for-bit."""
    g = build_collective(args.producers, consumers, args.payload_elems,
                         args.arity)
    ex = ClusterExecutor(args.workers, channel=channel, collectives="auto",
                         outputs_only=True, fail_worker=(0, 3),
                         progress_timeout=180.0)
    got = ex.run(g)
    ex.close()
    assert got[g.outputs[0]] == want_out, \
        f"{channel}: collective SIGKILL recovery diverged from the oracle"
    assert ex.stats["failures"] == 1, ex.stats
    assert ex.stats["recomputed"] > 0, ex.stats
    return {"channel": channel, "consumers": consumers,
            "failures": ex.stats["failures"],
            "recomputed": ex.stats["recomputed"],
            "collective_stages": ex.stats.get("collective_stages", 0)}


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--producers", type=int, default=16)
    ap.add_argument("--consumers", type=int, nargs="+", default=[4, 32],
                    help="consumer-count sweep; the last (highest) cell "
                         "is the acceptance headline")
    ap.add_argument("--payload-mb", type=float, default=4.0)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--arity", type=int, default=DEFAULT_ARITY)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: differential + must-not-regress gate, tiny "
                         "payloads")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.producers = min(args.producers, 4)
        args.consumers = [min(m, 8) for m in args.consumers][-2:]
        args.payload_mb = min(args.payload_mb, 0.5)
        args.workers = min(args.workers, 2)
        args.arity = min(args.arity, 2)     # tiny N must still grow a tree
        args.reps = 2       # median: a loaded CI box jitters single runs
    args.consumers = sorted(set(args.consumers))
    args.payload_elems = max(1, int(args.payload_mb * (1 << 20) / 4))

    # one sequential oracle per consumer count; p2p and collective builds
    # share the bracketing, so a single scalar pins both modes
    want: Dict[int, float] = {}
    for m in args.consumers:
        gc = build_collective(args.producers, m, args.payload_elems,
                              args.arity)
        gp = build_p2p(args.producers, m, args.payload_elems, args.arity)
        oc = execute_sequential(gc)[gc.outputs[0]]
        op = execute_sequential(gp)[gp.outputs[0]]
        assert oc == op, ("builders disagree", m, oc, op)
        want[m] = oc

    # static data-plane demand per consumer count (channel-independent):
    # the scheduler-visible edge bytes the tree shape removes
    edge_cut: Dict[str, float] = {}
    for m in args.consumers:
        p2p_bytes = edge_payload_bytes(
            build_p2p(args.producers, m, args.payload_elems, args.arity))
        coll_bytes = edge_payload_bytes(
            build_collective(args.producers, m, args.payload_elems,
                             args.arity))
        edge_cut[str(m)] = p2p_bytes / max(coll_bytes, 1)

    rows: List[Dict[str, Any]] = []
    speedups: Dict[str, Dict[str, float]] = {}
    for channel in ("pipe", "tcp"):
        speedups[channel] = {}
        for m in args.consumers:
            p2p = run_cell(channel, "p2p", m, args, want[m])
            coll = run_cell(channel, "collective", m, args, want[m])
            rows += [p2p, coll]
            speedups[channel][str(m)] = (p2p["wall_s"] /
                                         max(coll["wall_s"], 1e-9))
            if max(args.producers, m) > args.arity:
                assert coll["collective_stages"] > 0, \
                    (f"{channel}/M={m}: lowering emitted no staged hops: "
                     f"{coll}")

    m_hi = args.consumers[-1]
    recovery = [recovery_cell(ch, m_hi, args, want[m_hi])
                for ch in ("pipe", "tcp")]

    if args.smoke:
        # deterministic gate: the lowered tree must remove scheduler-visible
        # edge bytes vs N×M point-to-point (static graph property, immune
        # to CI scheduling jitter)
        assert edge_cut[str(m_hi)] >= 1.3, \
            (f"M={m_hi}: collective lowering cut edge bytes only "
             f"{edge_cut[str(m_hi)]:.2f}x (expected >=1.3x)")
        for ch in ("pipe", "tcp"):
            # collective wall may never exceed p2p beyond CI jitter
            p2p_w = next(r["wall_s"] for r in rows
                         if r["channel"] == ch and r["mode"] == "p2p"
                         and r["consumers"] == m_hi)
            coll_w = next(r["wall_s"] for r in rows
                          if r["channel"] == ch
                          and r["mode"] == "collective"
                          and r["consumers"] == m_hi)
            assert coll_w <= p2p_w * 1.5, \
                (f"{ch}/M={m_hi}: collective wall {coll_w:.3f}s regressed "
                 f"vs p2p {p2p_w:.3f}s")
        print(f"smoke: {args.producers} producers x {args.consumers} "
              f"consumers, {args.payload_mb} MiB payloads — every cell "
              "bit-identical to the oracle (healthy + SIGKILL); "
              f"edge bytes cut {edge_cut[str(m_hi)]:.1f}x at M={m_hi}",
              flush=True)

    payload = {
        "config": {"producers": args.producers,
                   "consumers": args.consumers,
                   "payload_mb": args.payload_mb, "arity": args.arity,
                   "workers": args.workers, "reps": args.reps,
                   "smoke": args.smoke},
        "cells": rows,
        "recovery": recovery,
        "speedup": speedups,
        "edge_byte_reduction": edge_cut,
        "headline": {ch: speedups[ch][str(m_hi)] for ch in speedups},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows(f"collectives: tree all_reduce+broadcast vs point-to-point "
               f"fan-in ({args.producers} producers, "
               f"{args.payload_mb} MiB payloads, {args.workers} workers)",
               rows)
    print("\ncollective speedup at highest cell (M="
          f"{m_hi}): "
          + ", ".join(f"{ch} {s:.2f}x"
                      for ch, s in payload["headline"].items())
          + f" -> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
