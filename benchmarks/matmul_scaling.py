"""Paper Fig. 2 reproduction — matrix generation+multiplication task graphs,
makespan vs. worker count, against single-thread and SMP baselines.

The paper simulated its workers with Cloud Haskell processes on one machine;
this container has ONE CPU core (``nproc = 1``), so we do the same thing one
level cleaner:

* the **single-thread baseline** is a real, measured sequential execution of
  the workload (numpy/XLA payloads);
* per-task costs are **calibrated** from those measurements and fed into the
  deterministic discrete-event simulator (:mod:`repro.core.simulator`) —
  worker counts 1..256 — reproducing the paper's scaling curve in seconds;
* the **SMP baseline** (Haskell `par`/`pseq` ≈ intra-op threading) is the
  same sequential program with XLA's intra-op thread pool — on a 1-core
  container it coincides with single-thread, which we report honestly (the
  simulator's 1-worker makespan matches it, as in the paper's Fig. 2 where
  SMP ≈ 1-worker distributed);
* the **ThreadedExecutor** numbers measure real scheduler overhead
  (dispatch + steal cost per task) — the part that is NOT simulated.

Workload (paper §4): task size T = number of matrix operations; each unit is
``gen(2i), gen(2i+1) -> mul -> reduce`` over (n × n) float32 matrices.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (trace, task, execute_sequential, ThreadedExecutor,
                        simulate, theoretical_speedup, list_schedule)

from .common import print_rows, time_call, write_csv

WORKERS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def matrix_driver(n_tasks: int, size: int, cost_gen: float, cost_mul: float,
                  chain: int = 1):
    """The paper's workload as a traced driver.

    ``chain`` > 1 strings extra multiplies in sequence per unit, lowering
    max parallelism (used to show the Brent bound kicking in).
    """
    @task(cost=cost_gen, name="gen", out_bytes=size * size * 4)
    def gen(seed):
        rng = np.random.default_rng(seed)
        return rng.standard_normal((size, size), dtype=np.float32)

    @task(cost=cost_mul, name="mul", out_bytes=size * size * 4)
    def mul(a, b):
        return a @ b

    @task(cost=0.0, name="reduce")
    def red(*xs):
        return float(sum(float(x.sum()) for x in xs))

    outs = []
    for i in range(n_tasks):
        a = gen(2 * i)
        b = gen(2 * i + 1)
        m = mul(a, b)
        for _ in range(chain - 1):
            m = mul(m, b)
        outs.append(m)
    return red(*outs)


def calibrate(size: int) -> Dict[str, float]:
    """Measure real per-task seconds for gen and mul at this matrix size."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((size, size), dtype=np.float32)
    b = rng.standard_normal((size, size), dtype=np.float32)
    t_gen = time_call(lambda: rng.standard_normal((size, size),
                                                  dtype=np.float32), reps=3)
    t_mul = time_call(lambda: a @ b, reps=3)
    return {"gen": t_gen, "mul": t_mul}


def run(sizes=(256,), task_counts=(8, 32, 128), chain: int = 1,
        measure_real: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    for size in sizes:
        cal = calibrate(size)
        for T in task_counts:
            graph, _ = trace(matrix_driver, T, size, cal["gen"], cal["mul"],
                             chain)
            work = graph.total_work()
            span = graph.critical_path_length()

            # real single-thread baseline (measured, = paper's baseline)
            if measure_real:
                t0 = time.perf_counter()
                execute_sequential(graph)
                t_seq = time.perf_counter() - t0
            else:
                t_seq = work

            # real threaded run (scheduler overhead on 1 core)
            ex = ThreadedExecutor(4)
            t0 = time.perf_counter()
            ex.run(graph)
            t_thr4 = time.perf_counter() - t0

            base = {"size": size, "tasks": T, "chain": chain,
                    "n_nodes": len(graph), "work_s": work, "span_s": span,
                    "seq_wall_s": t_seq, "thr4_wall_s": t_thr4,
                    "sched_overhead_us_per_task":
                        max(0.0, (t_thr4 - t_seq)) / len(graph) * 1e6}
            for W in WORKERS:
                sim = simulate(graph, W)
                rows.append(dict(
                    base, workers=W, sim_makespan_s=sim.makespan,
                    speedup=work / sim.makespan if sim.makespan else 0.0,
                    bound=theoretical_speedup(graph, W),
                    steals=sim.n_steals,
                    utilization=sim.utilization))
    return rows


def main() -> List[Dict]:
    rows = run()
    # the narrow-parallelism variant: chained multiplies cap the speedup
    rows += run(task_counts=(16,), chain=8, measure_real=False)
    write_csv("matmul_scaling", rows)
    print_rows("Fig.2: matmul task scaling (simulated workers, "
               "calibrated costs)", rows)
    return rows


if __name__ == "__main__":
    main()
