"""Splice the generated roofline table into EXPERIMENTS.md.

Replaces the region after the ``<!-- ROOFLINE_TABLE -->`` marker (up to the
next blank-line-delimited paragraph) with the current table from
``results/dryrun``.  Run after a dry-run sweep:

  PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import os
import re

from . import roofline

MD = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
MARK = "<!-- ROOFLINE_TABLE -->"


def main() -> None:
    rows = roofline.load_cells("single")
    table = roofline.to_markdown(rows)
    with open(MD) as f:
        text = f.read()
    if MARK not in text:
        raise SystemExit(f"marker {MARK} missing from EXPERIMENTS.md")
    head, rest = text.split(MARK, 1)
    # drop any previously spliced table (lines starting with '|') directly
    # after the marker
    rest_lines = rest.lstrip("\n").split("\n")
    i = 0
    while i < len(rest_lines) and rest_lines[i].startswith("|"):
        i += 1
    rest = "\n".join(rest_lines[i:])
    with open(MD, "w") as f:
        f.write(head + MARK + "\n" + table + "\n" + rest)
    print(f"spliced {len(rows)} roofline rows into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
