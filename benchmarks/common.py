"""Shared benchmark helpers: timing, CSV output, result directories."""
from __future__ import annotations

import csv
import os
import time
from typing import Any, Callable, Dict, List, Sequence

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def time_call(fn: Callable, *args, reps: int = 3, warmup: int = 1,
              **kwargs) -> float:
    """Median wall-seconds of ``fn(*args, **kwargs)`` over ``reps`` calls."""
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def median(xs: Sequence[float]) -> float:
    """Upper median of wall-clock samples (ties toward the larger value,
    matching the suites' conservative headline reporting)."""
    xs = sorted(xs)
    return xs[len(xs) // 2]


def write_csv(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    if not rows:
        return path
    keys: List[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    return path


def print_rows(title: str, rows: Sequence[Dict[str, Any]]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    keys: List[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(_fmt(r.get(k, "")) for k in keys))


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
