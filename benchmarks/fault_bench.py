"""Fault-tolerance and straggler benchmarks (DESIGN.md §8).

Simulated (deterministic) cluster runs measuring:

* makespan inflation when k of N workers die mid-run, with lineage-based
  recomputation (the Spark-lineage design the paper points at);
* checkpoint-barrier density vs recovery cost (lineage_depth);
* straggler mitigation: speculative re-execution on/off when some workers
  silently slow down 10×.
"""
from __future__ import annotations

import random
from typing import Dict, List

import numpy as np

from repro.core import (simulate, WorkerEvent, trace, task,
                        checkpoint_barrier, lineage_depth,
                        execute_sequential)
from repro.cluster import ClusterExecutor
from .scheduler_bench import layered_dag, compute_dag

from .common import print_rows, write_csv


def bench_worker_failures(workers: int = 16, n_seeds: int = 5) -> List[Dict]:
    rows = []
    for n_fail in (0, 1, 4, 8):
        mks, recomp = [], []
        for s in range(n_seeds):
            g = layered_dag(300 + s, 14, 20)
            base = simulate(g, workers)
            # kill workers at evenly spaced times through the fault-free run
            events = [WorkerEvent(time=base.makespan * (i + 1) / (n_fail + 1),
                                  kind="fail", worker=i)
                      for i in range(n_fail)]
            r = simulate(g, workers, events=events)
            mks.append(r.makespan / base.makespan)
            recomp.append(r.n_recomputed)
        rows.append({"workers": workers, "failures": n_fail,
                     "makespan_inflation": sum(mks) / n_seeds,
                     "recomputed_tasks": sum(recomp) / n_seeds})
    return rows


def bench_elastic_join(workers: int = 8, n_seeds: int = 5) -> List[Dict]:
    """Elasticity: workers joining mid-run shorten the tail."""
    rows = []
    for joins in (0, 4, 8):
        mks = []
        for s in range(n_seeds):
            g = layered_dag(400 + s, 14, 20)
            base = simulate(g, workers)
            events = [WorkerEvent(time=base.makespan * 0.25, kind="join",
                                  worker=workers + i) for i in range(joins)]
            r = simulate(g, workers, events=events)
            mks.append(r.makespan / base.makespan)
        rows.append({"workers": workers, "joins": joins,
                     "makespan_vs_base": sum(mks) / n_seeds})
    return rows


def bench_stragglers(workers: int = 16, n_seeds: int = 5) -> List[Dict]:
    rows = []
    for speculate in (None, 1.5, 3.0):
        mks, spec = [], []
        for s in range(n_seeds):
            g = layered_dag(500 + s, 14, 20)
            base = simulate(g, workers)
            # 2 workers silently become 10x slower halfway through
            events = [WorkerEvent(time=base.makespan * 0.5, kind="slow",
                                  worker=w, factor=0.1) for w in (0, 1)]
            r = simulate(g, workers, events=events,
                         speculate_after=speculate)
            mks.append(r.makespan / base.makespan)
            spec.append(r.n_speculative)
        rows.append({"workers": workers,
                     "speculate_after_x": speculate or 0.0,
                     "makespan_inflation": sum(mks) / n_seeds,
                     "speculative_launches": sum(spec) / n_seeds})
    return rows


def bench_barrier_density() -> List[Dict]:
    """Checkpoint barriers cut lineage: recovery cost after a late loss
    drops with barrier frequency (at the cost of barrier materialization)."""
    rows = []
    chain_len = 64
    for every in (0, 32, 16, 8, 4):
        @task(cost=1.0)
        def step(x):
            return x + 1

        def driver():
            x = step(0)
            for i in range(1, chain_len):
                x = step(x)
                if every and i % every == 0:
                    x = checkpoint_barrier(x)
            return x

        g, _ = trace(driver)
        res = execute_sequential(g)
        tail = g.outputs[0]
        # worst-case single-loss recovery: lose the final value with only
        # barrier-durable results surviving
        from repro.core import TaskKind
        durable = {n.tid for n in g if n.kind is TaskKind.BARRIER}
        for b in list(durable):
            durable.update(g.nodes[b].deps)
        rows.append({
            "barrier_every": every,
            "n_barriers": sum(1 for n in g if n.kind is TaskKind.BARRIER),
            "recovery_depth_after_tail_loss":
                lineage_depth(g, tail, durable),
        })
    return rows


def bench_process_recovery(n_tasks: int = 120, workers: int = 4,
                           size: int = 96) -> List[Dict]:
    """REAL (not simulated) fault tolerance: SIGKILL one OS-process worker
    partway through a numpy-compute DAG and measure recovery overhead —
    wall-time inflation vs the fault-free run and how many tasks lineage
    recovery actually recomputed (vs the whole graph, which is what a
    restart-from-scratch scheme would redo)."""
    g = compute_dag(11, n_tasks, 0.12, size=size)
    seq = execute_sequential(g)
    rows = []
    base = ClusterExecutor(workers)
    base_res = base.run(g)
    assert all(np.allclose(base_res[t], seq[t]) for t in g.nodes)
    rows.append({"scenario": "fault_free", "workers": workers,
                 "wall_s": round(base.wall_time, 4),
                 "recomputed": 0, "inflation": 1.0})
    for frac, label in ((0.25, "kill_early"), (0.6, "kill_late")):
        ex = ClusterExecutor(
            workers, fail_worker=(0, max(1, int(n_tasks * frac / workers))))
        res = ex.run(g)
        assert all(np.allclose(res[t], seq[t]) for t in g.nodes)
        rows.append({
            "scenario": label, "workers": workers,
            "wall_s": round(ex.wall_time, 4),
            "recomputed": ex.stats["recomputed"],
            "inflation": round(ex.wall_time / base.wall_time, 3),
        })
    # elastic join: a replacement worker arrives right after the kill
    ex = ClusterExecutor(workers, fail_worker=(0, max(1, n_tasks // 8)),
                         join_after=(n_tasks // 4, 1))
    res = ex.run(g)
    assert all(np.allclose(res[t], seq[t]) for t in g.nodes)
    rows.append({
        "scenario": "kill_then_join", "workers": workers,
        "wall_s": round(ex.wall_time, 4),
        "recomputed": ex.stats["recomputed"],
        "inflation": round(ex.wall_time / base.wall_time, 3),
    })
    return rows


def main() -> List[Dict]:
    r1 = bench_worker_failures()
    r2 = bench_elastic_join()
    r3 = bench_stragglers()
    r4 = bench_barrier_density()
    r5 = bench_process_recovery()
    write_csv("fault_failures", r1)
    write_csv("fault_elastic", r2)
    write_csv("fault_stragglers", r3)
    write_csv("fault_barriers", r4)
    write_csv("fault_process_recovery", r5)
    print_rows("Worker failures (lineage recovery)", r1)
    print_rows("Elastic joins", r2)
    print_rows("Stragglers (speculative re-exec)", r3)
    print_rows("Checkpoint-barrier density vs recovery depth", r4)
    print_rows("Process backend: SIGKILL recovery overhead (real)", r5)
    return r1 + r2 + r3 + r4 + r5


if __name__ == "__main__":
    main()
