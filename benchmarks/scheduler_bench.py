"""Scheduler policy ablation (DESIGN.md §8).

Compares, across a family of random task DAGs and the paper's matrix
workload:

* ready-set priority: critical-path (HEFT rank_u) vs FIFO vs random;
* work stealing on/off (steal_latency=inf disables stealing usefully);
* static list-schedule vs dynamic work-stealing runtime under
  heterogeneous worker speeds (where static plans go stale).

All numbers are deterministic discrete-event simulations.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List

import numpy as np

from repro.core import (TaskGraph, TaskKind, simulate, list_schedule,
                        execute_sequential, make_executor,
                        theoretical_speedup)
from repro.core.tracing import RemappedRef

from .common import print_rows, write_csv


def _numpy_task(*xs, _seed: int = 0, _size: int = 96, _iters: int = 8):
    """BLAS payload: releases the GIL (and OpenBLAS may itself go
    multi-core), so thread and process backends compete on even terms."""
    rng = np.random.default_rng(_seed)
    m = rng.standard_normal((_size, _size))
    for x in xs:
        m = m + np.asarray(x)[: _size, : _size]
    for _ in range(_iters):
        m = m @ m.T
        m = m / (1.0 + np.abs(m).max())
    return m


def _python_task(*xs, _seed: int = 0, _steps: int = 200_000):
    """GIL-bound payload (pure-Python LCG): threads cannot parallelize this
    at all — the regime that motivates the OS-process backend."""
    h = (_seed * 2654435761 + 1) & 0xFFFFFFFF
    for x in xs:
        h ^= int(x) & 0xFFFFFFFF
    for _ in range(_steps):
        h = (h * 1664525 + 1013904223) & 0xFFFFFFFF
    return h


def compute_dag(seed: int, n: int, p: float, size: int = 96,
                iters: int = 8, payload: str = "numpy") -> TaskGraph:
    """Random DAG whose nodes do real compute (not simulated)."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]
        if payload == "numpy":
            fn, kw = _numpy_task, {"_seed": i, "_size": size,
                                   "_iters": iters}
        else:
            fn, kw = _python_task, {"_seed": i}
        g.add_node(f"m{i}", fn, tuple(RemappedRef(d) for d in deps),
                   kw, TaskKind.PURE, deps=deps,
                   cost=1.0, out_bytes=size * size * 8)
    g.mark_output(n - 1)
    return g


def bench_backends(n_tasks: int = 80, size: int = 128,
                   workers: int = 2) -> List[Dict]:
    """REAL execution: sequential oracle vs thread vs process backends, on
    (a) a GIL-bound pure-Python DAG — only processes can win — and (b) a
    GIL-releasing numpy DAG — both backends compete.  Unlike every other
    table in this file these rows are wall-clock measurements, not
    simulations."""
    rows = []
    for payload in ("python", "numpy"):
        g = compute_dag(7, n_tasks, 0.12, size=size, payload=payload)
        t0 = time.perf_counter()
        seq = execute_sequential(g)
        t_seq = time.perf_counter() - t0
        rows.append({"payload": payload, "backend": "sequential",
                     "workers": 1, "wall_s": round(t_seq, 4),
                     "speedup": 1.0, "matches": True})
        for backend in ("thread", "process"):
            ex = make_executor(backend, workers)
            res = ex.run(g)
            ok = all(np.allclose(res[t], seq[t]) for t in g.nodes)
            rows.append({
                "payload": payload, "backend": backend, "workers": workers,
                "wall_s": round(ex.wall_time, 4),
                "speedup": (round(t_seq / ex.wall_time, 2)
                            if ex.wall_time else 0),
                "matches": ok,
            })
    return rows


def random_dag(seed: int, n: int, p: float, *, cost_lo=0.5, cost_hi=2.0,
               fanin: int = 3) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-fanin:]
        g.add_node(f"t{i}", None, tuple(RemappedRef(d) for d in deps), {},
                   TaskKind.PURE, deps=deps,
                   cost=rng.uniform(cost_lo, cost_hi))
    g.mark_output(n - 1)
    return g


def layered_dag(seed: int, layers: int, width: int) -> TaskGraph:
    """Wide layered graph — the regime where policies differ most."""
    rng = random.Random(seed)
    g = TaskGraph()
    prev: List[int] = []
    for l in range(layers):
        cur = []
        for i in range(width):
            deps = ([rng.choice(prev)] if prev else []) + \
                ([rng.choice(prev)] if prev and rng.random() < 0.5 else [])
            deps = sorted(set(deps))
            cur.append(g.add_node(
                f"l{l}_{i}", None, tuple(RemappedRef(d) for d in deps), {},
                TaskKind.PURE, deps=deps, cost=rng.uniform(0.2, 3.0)))
        prev = cur
    out = g.add_node("sink", None, tuple(RemappedRef(d) for d in prev), {},
                     TaskKind.PURE, deps=prev, cost=0.1)
    g.mark_output(out)
    return g


def bench_policies(n_seeds: int = 5, workers: int = 16) -> List[Dict]:
    rows = []
    for kind in ("random", "layered"):
        for policy in ("critical_path", "fifo", "random"):
            mk_static, mk_dyn = [], []
            for s in range(n_seeds):
                g = (random_dag(s, 200, 0.05) if kind == "random"
                     else layered_dag(s, 12, 24))
                sched = list_schedule(g, workers, policy=policy)
                sched.validate_against(g)
                mk_static.append(sched.makespan)
                mk_dyn.append(simulate(g, workers, policy=policy).makespan)
            rows.append({
                "dag": kind, "policy": policy, "workers": workers,
                "static_makespan": sum(mk_static) / n_seeds,
                "dynamic_makespan": sum(mk_dyn) / n_seeds,
            })
    return rows


def bench_stealing(n_seeds: int = 5, workers: int = 16) -> List[Dict]:
    """Work stealing matters under heterogeneity: without it a slow worker's
    deque backlog stalls the tail of the run."""
    rows = []
    for hetero in (False, True):
        speeds = ([1.0] * workers if not hetero
                  else [0.25 if w % 4 == 0 else 1.0 for w in range(workers)])
        for steal, steal_lat in ((False, 0.0), (True, 0.0), (True, 0.05)):
            mks, steals = [], []
            for s in range(n_seeds):
                g = layered_dag(100 + s, 12, 24)
                r = simulate(g, workers, worker_speed=speeds,
                             steal_latency=steal_lat, allow_steal=steal)
                mks.append(r.makespan)
                steals.append(r.n_steals)
            rows.append({
                "hetero": hetero, "steal": steal,
                "steal_latency": steal_lat, "workers": workers,
                "makespan": sum(mks) / n_seeds,
                "steals": sum(steals) / n_seeds,
            })
    return rows


def bench_locality(n_seeds: int = 5, workers: int = 8) -> List[Dict]:
    """Input-fetch cost (comm_per_byte) rewards the locality heuristic
    (successor enqueued on the producing worker's deque)."""
    rows = []
    for cpb in (0.0, 1e-8, 1e-7):
        mks = []
        for s in range(n_seeds):
            g = layered_dag(200 + s, 10, 16)
            for node in g.nodes.values():
                node.out_bytes = 4 << 20      # 4 MB intermediates
            r = simulate(g, workers, comm_per_byte=cpb)
            mks.append(r.makespan)
        rows.append({"comm_per_byte": cpb, "workers": workers,
                     "makespan": sum(mks) / n_seeds})
    return rows


def main() -> List[Dict]:
    rows = bench_policies()
    rows2 = bench_stealing()
    rows3 = bench_locality()
    rows4 = bench_backends()
    write_csv("scheduler_policies", rows)
    write_csv("scheduler_stealing", rows2)
    write_csv("scheduler_locality", rows3)
    write_csv("scheduler_backends", rows4)
    print_rows("Scheduler policy ablation", rows)
    print_rows("Work stealing under heterogeneity", rows2)
    print_rows("Locality vs input-fetch cost", rows3)
    print_rows("Real execution: thread vs process backend", rows4)
    return rows + rows2 + rows3 + rows4


if __name__ == "__main__":
    main()
