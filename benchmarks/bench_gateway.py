"""Multi-tenant gateway benchmark: latency SLOs and fair-share under
contention (``BENCH_gateway.json``).

Three questions, one artifact:

1. **Per-tenant latency under contention** — T identical tenants each
   push a stream of jobs into one shared resident pool; the artifact
   records each tenant's p50/p99 submit-to-gather latency (client-side,
   cross-checked against the gateway's server-side SLO window).

2. **Fairness** — with equal weights, identical tenants must see
   comparable service: the max/min ratio of mean per-tenant latency is
   the headline fairness number.  A weighted pass (weight 2 vs 1) shows
   the dial works.

3. **Amortization** — the same total job count submitted one-at-a-time
   by a single tenant (no concurrency) vs the concurrent multi-tenant
   wall clock on the same pool: the throughput the shared resident
   service buys.

``--smoke`` is the CI gate: tiny sizes, every result bit-for-bit vs the
sequential oracle, and a hard fairness assertion (equal-weight tenants
within 3x mean latency of each other).

Usage::

    PYTHONPATH=src python -m benchmarks.bench_gateway [--tenants 2]
        [--jobs 30] [--nodes 40] [--workers 2] [--smoke]
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import random
import sys
import threading
import time
from functools import partial
from typing import Any, Dict, List

from repro.config import ClusterConfig
from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.gateway import GatewayService, connect

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_gateway.json")
TOKEN = "bench-gateway"


def _combine(i, *xs):
    return (i + sum(xs) * 7) % 1_000_003


def bench_dag(seed: int, n: int, p: float = 0.3) -> TaskGraph:
    """Cheap integer DAG whose node fns pickle into the gateway pool.

    Run via ``python -m``, this module is ``__main__`` and its functions
    would pickle unresolvably — so reference them through the canonical
    import instead (same objects when imported normally)."""
    canon = importlib.import_module("benchmarks.bench_gateway")
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]
        g.add_node(f"t{i}", partial(canon._combine, seed * 1000 + i),
                   tuple(_Ref(d) for d in deps), {}, TaskKind.PURE,
                   deps=deps, cost=0.5 + rng.random())
    g.mark_output(n - 1)
    return g


def _pctl(xs: List[float], q: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))]


def _lat_summary(lats: List[float]) -> Dict[str, float]:
    return {
        "p50_ms": round(_pctl(lats, 0.50) * 1e3, 3),
        "p99_ms": round(_pctl(lats, 0.99) * 1e3, 3),
        "mean_ms": round(sum(lats) / len(lats) * 1e3, 3),
        "jobs": len(lats),
    }


def run_tenants(address: str, spec: List[Dict[str, Any]], jobs: int,
                graph: TaskGraph, oracle: Dict[str, Any]
                ) -> Dict[str, Any]:
    """Each tenant in ``spec`` submits ``jobs`` copies of ``graph``
    concurrently; returns per-tenant latency summaries + total wall."""
    out: Dict[str, Any] = {}
    errs: List[BaseException] = []

    def tenant(name: str, priority: float) -> None:
        try:
            with connect(address, token=TOKEN, tenant=name,
                         priority=priority) as c:
                futs = [c.submit(graph, label=f"{name}-{i}")
                        for i in range(jobs)]
                lats = []
                for f in futs:
                    res = f.result(600)
                    assert res == oracle, f"tenant {name} diverged"
                    lats.append(f.stats["submit_to_gather_s"])
                out[name] = _lat_summary(lats)
                # server-side SLO window must agree it saw this tenant
                slo = c.stats()[name]["slo"]["submit_to_gather_s"]
                assert slo["p50"] is not None
        except BaseException as e:
            errs.append(e)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=tenant,
                                args=(s["name"], s["priority"]))
               for s in spec]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return {"tenants": out, "wall_s": round(wall, 3)}


def cli_smoke(workers: int, jobs: int, nodes: int) -> None:
    """CI gate for the service *binary*: start a real ``repro-gateway``
    subprocess, have two tenants submit concurrently over localhost TCP,
    and check oracle equality + per-tenant stats before a clean SIGINT
    drain.  (The unpickle side needs ``benchmarks.bench_gateway``
    importable in the service process: repo root cwd, ``python -m``.)"""
    import re
    import signal
    import subprocess

    graph = bench_dag(2, nodes)
    oracle = execute_sequential(graph)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.gateway",
         "--n-workers", str(workers), "--token", TOKEN,
         "--quota", "micro=1"],
        stdout=subprocess.PIPE, text=True)
    try:
        first = proc.stdout.readline()
        m = re.search(r"serving clients on (\S+)", first)
        assert m, f"gateway never announced its address: {first!r}"
        addr = m.group(1)
        spec = [{"name": "serve", "priority": 2.0},
                {"name": "batch", "priority": 1.0}]
        got = run_tenants(addr, spec, jobs, graph, oracle)
        assert all(got["tenants"][s["name"]]["jobs"] == jobs
                   for s in spec), got
        with connect(addr, token=TOKEN, tenant="serve") as c:
            st = c.stats()
            assert st["serve"]["completed"] >= jobs and "pool" in st, st
            # a quota'd tenant is rejected as the typed error, cross-process
            from repro.gateway import QuotaExceeded
            with connect(addr, token=TOKEN, tenant="micro") as cm:
                err = cm.submit(graph).exception(60)
                assert isinstance(err, QuotaExceeded), err
        proc.send_signal(signal.SIGINT)
        out, _ = proc.communicate(timeout=60)
        assert "stopped" in out, out
        print(f"smoke: repro-gateway CLI served 2 tenants x {jobs} jobs "
              f"over {addr}, typed quota rejection, clean drain",
              flush=True)
    finally:
        if proc.poll() is None:
            proc.kill()


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--jobs", type=int, default=30,
                    help="jobs per tenant in the contention pass")
    ap.add_argument("--nodes", type=int, default=40)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: tiny sizes + oracle/fairness assertions")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.jobs = min(args.jobs, 8)
        args.nodes = min(args.nodes, 25)
        cli_smoke(args.workers, args.jobs, args.nodes)

    graph = bench_dag(1, args.nodes)
    oracle = execute_sequential(graph)
    cfg = ClusterConfig(n_workers=args.workers, token=TOKEN, fuse="auto",
                        progress_timeout=120.0)

    with GatewayService(cfg) as gw:
        addr = gw.address

        # warmup: the first job pays worker fork + first-dispatch costs;
        # keep that out of every timed pass below
        with connect(addr, token=TOKEN, tenant="warmup") as c:
            assert c.submit(graph).result(600) == oracle

        # -- 1+2. equal-weight contention: latency SLOs + fairness ------
        spec = [{"name": f"tenant{i}", "priority": 1.0}
                for i in range(args.tenants)]
        fair = run_tenants(addr, spec, args.jobs, graph, oracle)
        means = [fair["tenants"][s["name"]]["mean_ms"] for s in spec]
        fairness_ratio = max(means) / min(means)
        print(f"equal-weight: {args.tenants} tenants x {args.jobs} jobs "
              f"in {fair['wall_s']}s, mean-latency ratio "
              f"{fairness_ratio:.2f}", flush=True)
        if args.smoke:
            assert all(fair["tenants"][s["name"]]["jobs"] == args.jobs
                       for s in spec), fair
            assert fairness_ratio <= 3.0, \
                f"equal-weight tenants served unfairly: {fair}"

        # -- 2b. the weight dial: weighted tenant vs best-effort --------
        gw.executor.set_tenant_weight("gold", 2.0)
        weighted = run_tenants(
            addr, [{"name": "gold", "priority": 2.0},
                   {"name": "bronze", "priority": 1.0}],
            args.jobs, graph, oracle)
        print(f"weighted 2:1 -> gold p50 "
              f"{weighted['tenants']['gold']['p50_ms']}ms, bronze p50 "
              f"{weighted['tenants']['bronze']['p50_ms']}ms", flush=True)

        # -- 3. amortization: one tenant, strictly sequential -----------
        t0 = time.perf_counter()
        with connect(addr, token=TOKEN, tenant="solo") as c:
            total = args.tenants * args.jobs
            for i in range(total):
                res = c.submit(graph).result(600)
                assert res == oracle
        seq_wall = time.perf_counter() - t0
        speedup = seq_wall / fair["wall_s"]
        print(f"sequential {total} jobs: {seq_wall:.3f}s -> concurrent "
              f"speedup {speedup:.2f}x", flush=True)

        pool = gw.stats()["pool"]

    payload = {
        "config": {"tenants": args.tenants, "jobs": args.jobs,
                   "nodes": args.nodes, "workers": args.workers,
                   "smoke": args.smoke},
        "equal_weight": fair,
        "fairness_mean_latency_ratio": round(fairness_ratio, 3),
        "weighted_2_to_1": weighted,
        "sequential_baseline": {"wall_s": round(seq_wall, 3),
                                "concurrent_speedup": round(speedup, 3)},
        "pool": {"n_workers": pool["n_workers"]},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"fairness ratio {fairness_ratio:.2f} (equal weights), "
          f"speedup {speedup:.2f}x vs sequential -> {args.out}",
          flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
