"""Perf-iteration driver (assignment §PERFORMANCE HILLCLIMBING).

Tools:
  * ``diagnose``: compile ONE cell (unrolled probe depth for speed) and
    print the top collectives / largest HLO ops WITH their jax source
    attribution (op_name metadata) — the "profile" of the dry-run world.
  * ``run``: compile a cell with config/mode overrides under a --tag, so
    results/dryrun/<cell>_<tag>.json records the variant; print the three
    roofline terms and the delta vs the untagged baseline.

  * ``search``: the *distributed-runtime* leg of the loop — sweep one
    scheduler/fusion knob through :func:`repro.core.simulator.search_policy`,
    optionally replaying a recorded :class:`~repro.core.adaptive.RunTrace`
    from a live run so candidates are priced against measured durations
    (docs/adaptive.md).  Pure python: no jax, no XLA env mutation.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb diagnose \\
      --arch qwen3-14b --shape train_4k [--depth 4]
  PYTHONPATH=src python -m benchmarks.hillclimb run \\
      --arch qwen3-14b --shape train_4k --tag remat_none \\
      --override remat=none
  PYTHONPATH=src python -m benchmarks.hillclimb search \\
      --knob keep_parallelism --grid 2,4,8,16 --workload lopsided \\
      [--trace results/trace.json]
"""
import argparse
import json
import os
import re
from typing import Dict, Optional


def _set_xla_flags() -> None:
    """Fake a 512-device host for the compile subcommands.

    Must run before jax initialises, which is why the compile paths
    import jax-touching modules lazily.  Deliberately NOT executed at
    module import: ``search`` (and anyone who merely imports this
    module, e.g. the test suite) must not have its process-wide
    ``XLA_FLAGS`` rewritten as a side effect.
    """
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_META_RE = re.compile(r'op_name="([^"]*)"')


def diagnose(args) -> None:
    from repro.compat import cost_analysis_dict
    from repro.launch.mesh import make_production_mesh
    from repro.launch import dryrun, steps as steps_mod
    from repro.configs import get_config

    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(args.arch)
    over: Dict = dict(parse_override(o) for o in args.override or [])
    if args.depth:
        over.update(n_layers=args.depth, layer_plan=(), scan_layers=False)
        if cfg.is_encoder_decoder:
            over["n_enc_layers"] = args.depth
    case = steps_mod.build_case(args.arch, args.shape, mesh, args.mode,
                                overrides=over)
    with mesh:
        compiled = steps_mod.lower_case(case).compile()
    hlo = compiled.as_text()
    cost = cost_analysis_dict(compiled)
    print(f"depth={args.depth or 'full'} flops/dev={cost.get('flops', 0):.3e}"
          f" bytes/dev={cost.get('bytes accessed', 0):.3e}")

    rows = []
    for line in hlo.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        b = dryrun._shape_bytes(m.group(1))
        if b == 0:
            continue
        meta = _META_RE.search(line)
        groups = dryrun._parse_groups(line)
        g = len(groups[0]) if groups else 0
        rows.append((b, m.group(2), g,
                     (meta.group(1) if meta else "?")[:110]))
    rows.sort(reverse=True)
    print(f"\ntop collectives (of {len(rows)}), result bytes per device:")
    for b, op, g, name in rows[:args.top]:
        print(f"  {b/1e6:10.1f} MB  {op:<19s} g={g:<4d} {name}")

    # biggest non-collective ops (memory-term suspects)
    big = []
    for line in hlo.splitlines():
        mm = re.search(r"=\s*(\S+)\s+(fusion|dot|convolution|custom-call|"
                       r"gather|scatter|dynamic-update-slice|copy|transpose|"
                       r"broadcast)\(", line)
        if not mm:
            continue
        b = dryrun._shape_bytes(mm.group(1))
        if b < 1e6:
            continue
        meta = _META_RE.search(line)
        big.append((b, mm.group(2), (meta.group(1) if meta else "?")[:110]))
    big.sort(reverse=True)
    print(f"\nlargest op results:")
    for b, op, name in big[:args.top]:
        print(f"  {b/1e6:10.1f} MB  {op:<19s} {name}")


def flashsim(args) -> None:
    """Quantify the memory-term share of materialized S×S attention-score
    tensors — exactly what the Pallas flash kernel keeps in VMEM on TPU.

    Compiles the two unrolled probes (depths p, 2p), sums the result bytes
    of every op whose shape carries a (S, S)-like trailing pair, and
    extrapolates to full depth (same scheme as dryrun.probe_correction).
    Reports the adjusted memory term.
    """
    from repro.compat import cost_analysis_dict
    from repro.launch.mesh import make_production_mesh
    from repro.launch import dryrun, steps as steps_mod
    from repro.configs import get_config
    from repro.models.config import SHAPES

    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(args.arch)
    S = SHAPES[args.shape].seq_len
    L1, L2 = dryrun._probe_depths(cfg)
    # kernel-resident shapes: S×S attention scores (flash_attention.py) and,
    # for SSD archs, the chunk×chunk intra-chunk matrices (ssm_scan.py)
    pats = [rf",{S},{S}[,\]]"]
    if cfg.ssm_state:
        c = cfg.ssm_chunk
        pats.append(rf",{c},{c},")
    sq_re = re.compile(rf"=\s*([a-z0-9]+\[[0-9]+(?:,[0-9]+)*\])")
    dim_res = [re.compile(p) for p in pats]
    got = {}
    for L in (L1, L2):
        over = dict(parse_override(o) for o in args.override or [])
        over.update(n_layers=L, layer_plan=(), scan_layers=False)
        case = steps_mod.build_case(args.arch, args.shape, mesh, args.mode,
                                    overrides=over)
        with mesh:
            compiled = steps_mod.lower_case(case).compile()
        hlo = compiled.as_text()
        sq = sum(dryrun._shape_bytes(m.group(1))
                 for m in sq_re.finditer(hlo)
                 if any(d.search(m.group(1)) for d in dim_res))
        got[L] = (float(cost_analysis_dict(compiled).get("bytes accessed", 0)),
                  float(sq))
        del hlo, compiled
    L = cfg.n_layers
    lerp = lambda a, b: a + (b - a) * (L - L1) / (L2 - L1)
    total = lerp(got[L1][0], got[L2][0])
    sq = lerp(got[L1][1], got[L2][1])
    HBM = 819e9
    print(f"{args.arch} × {args.shape} [{args.mode}]")
    print(f"  HLO bytes/dev          {total:.4g}  (t_mem {total/HBM:.3f} s)")
    print(f"  S×S score-op bytes/dev {sq:.4g}  ({sq/total:.1%} of total)")
    print(f"  flash-adjusted t_mem   {(total-sq)/HBM:.3f} s "
          f"({-sq/total*100:.1f}%)")


def run(args) -> None:
    from repro.launch import dryrun

    over = dict(parse_override(o) for o in args.override or [])
    rec = dryrun.run_cell(args.arch, args.shape, "single",
                          args.out, mode=args.mode,
                          overrides=over or None, tag=args.tag)
    if rec["status"] != "OK":
        print(rec.get("error", rec.get("reason")))
        return
    report(args.arch, args.shape, args.tag, args.out, rec)


def _terms(rec: Dict) -> Optional[Dict]:
    import benchmarks.roofline as R
    return R.analyse_record(rec)


def report(arch: str, shape: str, tag: str, out_dir: str,
           rec: Optional[Dict] = None) -> None:
    if rec is None:
        with open(os.path.join(out_dir,
                               f"{arch}__{shape}__single_{tag}.json")) as f:
            rec = json.load(f)
    row = _terms(rec)
    base_path = os.path.join(out_dir, f"{arch}__{shape}__single.json")
    base = None
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = _terms(json.load(f))
    print(f"\n{arch} × {shape} [{tag or 'baseline'}]")
    for k in ("t_compute_s", "t_memory_s", "t_collective_s", "mfu_bound",
              "useful_flops_ratio"):
        cur = row[k]
        if base:
            d = (cur - base[k]) / base[k] * 100 if base[k] else float("nan")
            print(f"  {k:<18s} {cur:12.6g}   ({d:+7.1f}% vs baseline "
                  f"{base[k]:.6g})")
        else:
            print(f"  {k:<18s} {cur:12.6g}")
    print(f"  dominant           {row['dominant']}")


def _search_workload(spec: str):
    """``lopsided`` (the bench_adaptive two-epoch graph) or
    ``random:SEED,N,P_EDGE`` (the property-test random DAG shape)."""
    if spec == "lopsided":
        from benchmarks.bench_adaptive import build_workload
        return build_workload(heavy_s=0.0, cheap_s=0.0)
    if spec.startswith("random:"):
        import random as _random
        from repro.core import TaskGraph, TaskKind
        seed, n, p = spec[len("random:"):].split(",")
        rng = _random.Random(int(seed))
        g = TaskGraph()
        for i in range(int(n)):
            deps = [j for j in range(i) if rng.random() < float(p)][-4:]
            g.add_node(f"t{i}", None, (), {}, TaskKind.PURE, deps=deps,
                       cost=rng.uniform(0.1, 4.0),
                       out_bytes=rng.randint(0, 1 << 20))
            if rng.random() < 0.1:
                g.mark_output(i)
        if not g.outputs:
            g.mark_output(int(n) - 1)
        return g
    raise SystemExit(f"unknown --workload {spec!r} "
                     "(want 'lopsided' or 'random:SEED,N,P')")


def search(args) -> None:
    from benchmarks.common import print_rows
    from repro.core.adaptive import RunTrace
    from repro.core.simulator import WorkerEvent, search_policy

    graph = _search_workload(args.workload)
    grid = []
    for c in args.grid.split(","):
        c = c.strip()
        grid.append(int(c) if args.knob in ("keep_parallelism",
                                            "collective_arity")
                    else float(c))
    events = []
    for spec in args.partition or []:
        t, w, dur = spec.split(":")
        events.append(WorkerEvent(time=float(t), kind="partition",
                                  worker=int(w), factor=float(dur)))
    trace = RunTrace.load(args.trace) if args.trace else None
    kw: Dict = {"dispatch_overhead": args.dispatch_overhead}
    if args.fuse:
        kw["fuse"] = args.fuse
    best, results = search_policy(
        args.knob, graph, args.workers, grid,
        events=events or None, trace=trace, **kw)
    rows = [{"candidate": c,
             "makespan_s": round(r.makespan, 4),
             "util": round(r.utilization, 3),
             "recomputed": r.n_recomputed,
             "speculative": r.n_speculative,
             "refusions": r.refusions,
             "best": "*" if c == best else ""}
            for c, r in sorted(results.items())]
    print_rows(f"search {args.knob} over {args.workload}"
               + (f" + trace {args.trace}" if args.trace else ""), rows)
    print(f"best {args.knob} = {best}  "
          f"(makespan {results[best].makespan:.4f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diagnose")
    d.add_argument("--arch", required=True)
    d.add_argument("--shape", required=True)
    d.add_argument("--mode", default="fsdp_tp")
    d.add_argument("--depth", type=int, default=4)
    d.add_argument("--top", type=int, default=15)
    d.add_argument("--override", action="append")
    r = sub.add_parser("run")
    r.add_argument("--arch", required=True)
    r.add_argument("--shape", required=True)
    r.add_argument("--mode", default="fsdp_tp")
    r.add_argument("--tag", required=True)
    r.add_argument("--out", default="results/dryrun")
    r.add_argument("--override", action="append")
    f = sub.add_parser("flashsim")
    f.add_argument("--arch", required=True)
    f.add_argument("--shape", required=True)
    f.add_argument("--mode", default="fsdp_tp")
    f.add_argument("--override", action="append")
    s = sub.add_parser("search")
    s.add_argument("--knob", required=True,
                   choices=("suspect_grace", "collective_arity",
                            "speculate_after", "keep_parallelism",
                            "fanin_cost", "group_cost"))
    s.add_argument("--grid", required=True,
                   help="comma-separated candidate values")
    s.add_argument("--workload", default="lopsided",
                   help="'lopsided' or 'random:SEED,N,P'")
    s.add_argument("--workers", type=int, default=4)
    s.add_argument("--trace", default=None,
                   help="RunTrace json from a live run (replay measured "
                        "durations instead of declared costs)")
    s.add_argument("--partition", action="append",
                   help="T:WORKER:DUR partition event (repeatable)")
    s.add_argument("--fuse", default=None)
    s.add_argument("--dispatch-overhead", type=float, default=0.0)
    args = ap.parse_args()
    if args.cmd in ("diagnose", "run", "flashsim"):
        _set_xla_flags()
    if args.cmd == "diagnose":
        diagnose(args)
    elif args.cmd == "flashsim":
        flashsim(args)
    elif args.cmd == "search":
        search(args)
    else:
        run(args)


if __name__ == "__main__":
    main()
