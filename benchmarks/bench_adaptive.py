"""Adaptive replanning benchmark: mis-costed lopsided workload,
``adaptive auto`` vs static planning, per control channel.

The static planner is only as good as the declared ``cost=`` hints.  This
benchmark builds the adversarial case — a two-epoch wide graph where a
few tasks per layer are ~100x more expensive than declared (all costs
claim 1.0, so fusion packs heavy and cheap tasks into the same
clusters) — and measures wall clock with the adaptive loop off (the
mis-fused plan runs as committed) vs ``adaptive auto`` (the cost model
calibrates on epoch-1 completions, the skew governor fires, and the
not-yet-dispatched epoch-2 frontier is re-fused under measured gates),
on both the ``pipe`` and ``tcp`` control channels.

Every cell is cross-checked **bit-for-bit** against
``execute_sequential`` — re-fusion changes granularity mid-run, never
values.  A well-costed control (identical graph, honest ``cost=`` hints)
pins the no-regression side: when the static plan is already right the
governor must stay quiet and adaptive wall clock must track static.  A
driver-SIGKILL cell kills the driver *after* re-fusion has fired and
resumes from the run log: the journaled ``refuse`` records must replay
(``refusions_replayed``) and the result must still match the oracle.
Finally the recorded :class:`~repro.core.adaptive.RunTrace` from a live
adaptive run is fed back through ``simulator.search_policy`` — the
offline leg of the loop — and the simulator must agree with the runtime
about whether re-fusion fires on this workload.

Writes ``BENCH_adaptive.json`` at the repo root: wall clock, speedup,
``refusions`` / ``cost_unit_s`` / ``adaptive_skew`` /
``adaptive_speculate_after`` / ``replan_triggers`` per cell, so the win
is visible in adaptive-loop terms, not just wall clock.

``--smoke`` is the CI gate: a smaller graph, both channels, asserting
the adaptive/static differential vs the oracle, >=1 re-fusion in every
adaptive cell (0 in every static cell), the resume-replay differential,
sim/runtime trigger agreement, and a must-not-regress bound on adaptive
wall clock.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_adaptive
        [--width 48] [--n-heavy 8] [--workers 4] [--reps 5] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Any, Dict, List, Optional

from repro.config import ClusterConfig
from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, DriverKilled

from .common import median, print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_adaptive.json")


def heavy_step(x, s):
    time.sleep(s)
    return x * 3 + 1


def cheap_step(x, s):
    time.sleep(s)
    return x + 1


def comb(*xs):
    return sum(int(x) for x in xs) % 1_000_003


def build_workload(*, width: int = 48, n_heavy: int = 8,
                   heavy_s: float = 0.1, cheap_s: float = 0.001,
                   miscosted: bool = True) -> TaskGraph:
    """Two epochs of a ``width``-wide layer, each pinched through a
    two-gate reduction (``ga``/``gb`` fan-ins -> ``gc`` combiner).

    The first ``n_heavy`` tasks of each layer sleep ``heavy_s``; the rest
    sleep ``cheap_s``.  With ``miscosted`` every task *declares*
    ``cost=1.0``, so sibling grouping packs heavy and cheap tasks
    together and the static plan is lopsided — epoch 1 is the adaptive
    runtime's calibration data, epoch 2 is the frontier it can still
    re-fuse.  The dual gates give every layer task two consumers, which
    keeps fusion's single-consumer contraction from absorbing the layers
    into the gates (the lopsidedness under test would vanish).  With
    ``miscosted=False`` heavy tasks declare their true cost ratio
    (``heavy_s / cheap_s``) — the honest hints — and the static plan is
    already balanced.
    """
    hc = 1.0 if miscosted else heavy_s / cheap_s
    g = TaskGraph()

    def layer(dep: Optional[int]) -> List[int]:
        tids = []
        for i in range(width):
            heavy = i < n_heavy
            t = len(g.nodes)
            fn = heavy_step if heavy else cheap_step
            s = heavy_s if heavy else cheap_s
            args = (_Ref(dep), s) if dep is not None else (i, s)
            g.add_node(f"w{t}", fn, args, {}, TaskKind.PURE,
                       deps=[dep] if dep is not None else [],
                       cost=hc if heavy else 1.0)
            tids.append(t)
        return tids

    def gatepair(tids: List[int]) -> int:
        a = g.add_node("ga", comb, tuple(_Ref(t) for t in tids), {},
                       TaskKind.PURE, deps=tids, cost=1.0)
        b = g.add_node("gb", comb, tuple(_Ref(t) for t in tids), {},
                       TaskKind.PURE, deps=tids, cost=1.0)
        return g.add_node("gc", comb, (_Ref(a), _Ref(b)), {},
                          TaskKind.PURE, deps=[a, b], cost=1.0)

    gate = gatepair(layer(None))
    g.mark_output(gatepair(layer(gate)))
    return g


def bit_equal(got: Dict[int, Any], oracle: Dict[int, Any]) -> bool:
    """Bit-for-bit dict equality (values here are python ints)."""
    return got == oracle


_STAT_KEYS = ("refusions", "replan_triggers", "n_clusters", "tasks_fused",
              "dispatched", "n_speculative")


def _cfg(channel: str, adaptive: str, args, **extra) -> ClusterConfig:
    return ClusterConfig(n_workers=args.workers, channel=channel,
                         fuse="auto", adaptive=adaptive,
                         progress_timeout=180.0, **extra)


def run_cell(channel: str, adaptive: str, args, graph_kw: Dict[str, Any],
             oracle: Dict[int, Any]) -> Dict[str, Any]:
    walls: List[float] = []
    stats: Dict[str, Any] = {}
    trace = None
    for _ in range(args.reps):
        g = build_workload(**graph_kw)
        ex = ClusterExecutor(config=_cfg(channel, adaptive, args))
        t0 = time.perf_counter()
        got = ex.run(g)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        trace = ex.last_trace
        ex.close()
        assert bit_equal(got, oracle), \
            f"{channel}/adaptive={adaptive}: diverged from the oracle"
    # median-of-N: scheduling jitter on a small container dwarfs the
    # effect under test (samples recorded for the skeptical reader)
    row = {"channel": channel, "adaptive": adaptive,
           "miscosted": graph_kw.get("miscosted", True),
           "wall_s": median(walls), "wall_best_s": min(walls),
           "wall_samples_s": [round(w, 4) for w in sorted(walls)]}
    for k in _STAT_KEYS:
        row[k] = stats.get(k, 0)
    for k in ("cost_unit_s", "adaptive_skew", "adaptive_speculate_after",
              "dispatch_cost_s"):
        row[k] = round(float(stats.get(k, 0.0)), 5)
    row["_trace"] = trace            # stripped before the json dump
    return row


def resume_cell(args, graph_kw: Dict[str, Any],
                oracle: Dict[int, Any]) -> Dict[str, Any]:
    """SIGKILL the driver *after* re-fusion fired, resume from the run
    log: the journaled ``refuse`` records replay so the done-claims of
    post-refusion cluster ids resolve against the plan that produced
    them, and the final result stays bit-for-bit."""
    with tempfile.TemporaryDirectory(prefix="bench_adaptive_") as ckpt:
        g = build_workload(**graph_kw)
        # tight flush cadence: the smoke graph completes in well under
        # the default 0.25s fsync interval, and an unflushed ``refuse``
        # record is exactly what this cell must prove gets replayed
        ex = ClusterExecutor(config=_cfg(
            "pipe", "auto", args, checkpoint_dir=ckpt,
            checkpoint_interval=0.02, fail_driver=args.fail_driver))
        try:
            ex.run(g)
            raise AssertionError("driver kill did not trigger")
        except DriverKilled as e:
            run_id = e.run_id
        finally:
            ex.close()
        g2 = build_workload(**graph_kw)
        ex2 = ClusterExecutor(config=_cfg(
            "pipe", "auto", args, checkpoint_dir=ckpt,
            checkpoint_interval=0.02, resume=run_id))
        got = ex2.run(g2)
        stats = dict(ex2.stats)
        ex2.close()
    assert bit_equal(got, oracle), \
        "resumed adaptive run diverged from the oracle"
    assert stats.get("refusions_replayed", 0) >= 1, \
        f"no journaled re-fusion replayed on resume: {stats}"
    return {"fail_driver": args.fail_driver,
            "refusions_replayed": stats["refusions_replayed"],
            "resumed_clusters": stats.get("resumed_clusters", 0),
            "refusions_after_resume": stats.get("refusions", 0),
            "n_clusters": stats.get("n_clusters", 0)}


def sim_cross_check(trace, args, graph_kw: Dict[str, Any]) -> Dict[str, Any]:
    """Feed the live run's RunTrace back through the simulator: the
    trigger model must agree that this workload fires re-fusion, and
    ``search_policy`` prices fusion candidates against *measured*
    durations — the offline leg of the adaptive loop."""
    from repro.core.simulator import search_policy, simulate

    g = build_workload(**graph_kw)
    res = simulate(g, args.workers, fuse="auto", adaptive="auto",
                   trace=trace, dispatch_overhead=trace.dispatch_s)
    best, results = search_policy(
        "keep_parallelism", g, args.workers, [2, 4, 8, 16],
        trace=trace, dispatch_overhead=trace.dispatch_s)
    return {"sim_refusions": res.refusions,
            "sim_refusion_times": [round(t, 4) for t in res.refusion_times],
            "best_keep_parallelism": best,
            "keep_parallelism_makespans": {
                str(c): round(r.makespan, 4) for c, r in results.items()}}


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--width", type=int, default=48)
    ap.add_argument("--n-heavy", type=int, default=8)
    ap.add_argument("--heavy-s", type=float, default=0.1)
    ap.add_argument("--cheap-s", type=float, default=0.001)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--fail-driver", type=int, default=14)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: differential + must-not-regress gate, "
                         "smaller graph")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.width = min(args.width, 24)
        args.n_heavy = min(args.n_heavy, 6)
        args.heavy_s = min(args.heavy_s, 0.05)
        args.reps = 2       # median: a loaded CI box jitters single runs

    mis_kw = {"width": args.width, "n_heavy": args.n_heavy,
              "heavy_s": args.heavy_s, "cheap_s": args.cheap_s,
              "miscosted": True}
    well_kw = dict(mis_kw, miscosted=False)
    g = build_workload(**mis_kw)
    n_nodes = len(g.nodes)
    oracle = execute_sequential(g)
    # identical fns+values, only the declared costs differ
    well_oracle = execute_sequential(build_workload(**well_kw))
    assert bit_equal(oracle, well_oracle)

    rows: List[Dict[str, Any]] = []
    speedups: Dict[str, float] = {}
    trace = None
    for channel in ("pipe", "tcp"):
        static = run_cell(channel, "off", args, mis_kw, oracle)
        auto = run_cell(channel, "auto", args, mis_kw, oracle)
        trace = auto.pop("_trace") or trace
        static.pop("_trace", None)
        rows += [static, auto]
        speedups[channel] = static["wall_s"] / max(auto["wall_s"], 1e-9)

    # well-costed control: honest hints -> the governor must stay quiet
    well_static = run_cell("pipe", "off", args, well_kw, oracle)
    well_auto = run_cell("pipe", "auto", args, well_kw, oracle)
    for r in (well_static, well_auto):
        r.pop("_trace", None)
        rows.append(r)
    well_ratio = well_auto["wall_s"] / max(well_static["wall_s"], 1e-9)

    resume = resume_cell(args, mis_kw, oracle)
    sim = sim_cross_check(trace, args, mis_kw)

    for ch in ("pipe", "tcp"):
        for r in rows:
            if r["miscosted"] and r["channel"] == ch:
                if r["adaptive"] == "auto":
                    assert r["refusions"] >= 1, \
                        f"{ch}: adaptive run never re-fused: {r}"
                else:
                    assert r["refusions"] == 0, r
    assert well_auto["refusions"] == 0, \
        f"governor fired on the well-costed control: {well_auto}"
    assert sim["sim_refusions"] >= 1, \
        f"simulator disagrees that re-fusion fires: {sim}"

    if args.smoke:
        # must-not-regress: adaptive wall (median of reps) may never
        # exceed static by more than CI scheduling noise
        for ch in ("pipe", "tcp"):
            off_w = next(r["wall_s"] for r in rows if r["miscosted"]
                         and r["channel"] == ch and r["adaptive"] == "off")
            auto_w = next(r["wall_s"] for r in rows if r["miscosted"]
                          and r["channel"] == ch and r["adaptive"] == "auto")
            assert auto_w <= off_w * 1.5, \
                (f"{ch}: adaptive wall {auto_w:.3f}s regressed vs "
                 f"static {off_w:.3f}s")
        assert well_ratio <= 1.5, \
            f"well-costed adaptive regressed {well_ratio:.2f}x"
        print(f"smoke: {n_nodes}-node lopsided graph x{args.workers} "
              "workers — adaptive runs bit-identical (healthy + driver "
              "SIGKILL/resume), re-fused "
              + ", ".join(f"{r['channel']} x{r['refusions']}"
                          for r in rows
                          if r["miscosted"] and r["adaptive"] == "auto")
              + f"; resume replayed {resume['refusions_replayed']}; "
              f"sim agrees ({sim['sim_refusions']} trigger(s))",
              flush=True)
    else:
        # headline artifact gates (the committed BENCH_adaptive.json)
        for ch in ("pipe", "tcp"):
            assert speedups[ch] >= 1.2, \
                (f"{ch}: adaptive speedup {speedups[ch]:.2f}x "
                 f"below the 1.2x bar: {rows}")
        assert well_ratio <= 1.05, \
            (f"well-costed adaptive overhead {well_ratio:.2f}x "
             f"exceeds 1.05x: {rows}")

    payload = {
        "config": {"width": args.width, "n_heavy": args.n_heavy,
                   "heavy_s": args.heavy_s, "cheap_s": args.cheap_s,
                   "n_nodes": n_nodes, "workers": args.workers,
                   "reps": args.reps, "smoke": args.smoke},
        "cells": rows,
        "resume": resume,
        "sim_cross_check": sim,
        "speedup": speedups,
        "wellcosted_ratio": well_ratio,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows(f"lopsided {n_nodes}-node two-epoch graph "
               f"({args.workers} workers) per channel x adaptive", rows)
    print("\nadaptive speedup (mis-costed): "
          + ", ".join(f"{ch} {s:.2f}x" for ch, s in speedups.items())
          + f"; well-costed overhead {well_ratio:.2f}x"
          + f"; resume replayed {resume['refusions_replayed']} re-fusion(s)"
          + f" -> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
