"""Multi-host control/data plane benchmark: pipe vs TCP.

Two questions, one artifact (``BENCH_multihost.json``):

1. **Control-plane overhead** — the same cheap 300-node DAG (integer
   arithmetic, ~zero compute) on forked pipe workers vs local TCP-dialed
   workers.  Every dispatch/done crosses the control channel, so the
   per-task wall-time delta is the price of framing + TCP + heartbeats
   over a kernel pipe.

2. **Per-transport shuffle wall-clock** — the wide shuffle from
   ``bench_transfer`` run over every data plane this host supports
   (``driver`` relay, ``shm``, ``sock``, ``tcp``), on both control
   planes where it makes sense.  This is the transport matrix a deploy
   chooses from: same-host shm vs the cross-host-capable TCP pulls.

``--smoke`` is the CI gate: 2 workers over the TCP channel, a 50-node
differential against the sequential oracle (bit-for-bit), plus a
SIGKILL-mid-run recovery check — then a tiny timing pass.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_multihost [--tasks 300]
        [--payload-mb 4] [--workers 2] [--reps 3] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time
from typing import Any, Dict, List, Optional

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, serde

from .bench_transfer import build_shuffle
from .common import median, print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_multihost.json")


def control_dag(n: int, p: float = 0.25, seed: int = 0) -> TaskGraph:
    """Cheap integer DAG: wall time ~= pure control-plane traffic."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def time_channel(graph: TaskGraph, channel: str, workers: int,
                 reps: int) -> Dict[str, Any]:
    walls = []
    stats: Dict[str, int] = {}
    for _ in range(reps):
        ex = ClusterExecutor(workers, channel=channel,
                             progress_timeout=180.0)
        t0 = time.perf_counter()
        ex.run(graph)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        ex.close()
    n = len(graph.nodes)
    wall = median(walls)
    return {"channel": channel, "wall_s": wall,
            "per_task_ms": 1e3 * wall / n,
            "dispatched": stats.get("dispatched", 0)}


def time_shuffle(graph: TaskGraph, channel: str, transport: str,
                 workers: int, reps: int) -> Dict[str, Any]:
    walls = []
    stats: Dict[str, int] = {}
    used = transport
    for _ in range(reps):
        ex = ClusterExecutor(workers, channel=channel, transport=transport,
                             outputs_only=True, progress_timeout=180.0,
                             pipeline_depth=4)
        t0 = time.perf_counter()
        ex.run(graph)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        used = ex.transport_used or transport
        ex.close()
    return {"channel": channel, "transport": used,
            "wall_s": median(walls),
            "bytes_driver": stats.get("bytes_driver", 0),
            "bytes_direct": stats.get("bytes_direct", 0),
            "transfers_direct": stats.get("transfers_direct", 0)}


def checkpoint_sweep(tasks: int, worker_counts: List[int],
                     reps: int) -> List[Dict[str, Any]]:
    """Run-log cost vs worker count, same DAG throughout.

    The tentpole claim is that checkpointing the control plane is flat in
    worker count: the hot-path record is a per-completion delta, so a
    64-worker run logs the same bytes per cluster as a 2-worker run
    (modulo the one-off per-worker adoption records).  The ``flatness``
    ratio in the artifact is max/min bytes-per-cluster across the sweep —
    ~1.0 is the design working, >2 is a regression."""
    import shutil
    import tempfile

    g = control_dag(tasks)
    rows = []
    for n in worker_counts:
        sizes = []
        for _ in range(reps):
            d = tempfile.mkdtemp(prefix="rrckpt")
            try:
                ex = ClusterExecutor(n, checkpoint_dir=d,
                                     checkpoint_interval=0.05,
                                     progress_timeout=180.0)
                ex.run(g)
                ex.close()
                sizes.append(os.path.getsize(
                    os.path.join(d, f"{ex.run_id}.log")))
            finally:
                shutil.rmtree(d, ignore_errors=True)
        b = median(sizes)
        rows.append({"workers": n, "log_bytes": int(b),
                     "bytes_per_cluster": round(b / tasks, 1)})
    return rows


def driver_kill_smoke(workers: int, tasks: int = 600) -> None:
    """CI gate for the tentpole: a real ``repro-driver`` subprocess is
    SIGKILL'd mid-run; ``--resume latest`` must re-adopt the surviving
    workers and finish bit-for-bit vs the sequential oracle."""
    import pickle
    import signal
    import subprocess
    import tempfile

    from repro.launch.driver import demo_graph

    seq = execute_sequential(demo_graph(tasks))
    for attempt in range(3):
        with tempfile.TemporaryDirectory(prefix="rrdk") as ckpt:
            out = os.path.join(ckpt, "out.pkl")
            base = [sys.executable, "-m", "repro.launch.driver",
                    "--graph", "repro.launch.driver:demo_graph",
                    "--arg", str(tasks), "--workers", str(workers),
                    "--checkpoint-dir", ckpt,
                    "--checkpoint-interval", "0.05", "--out", out]
            p = subprocess.Popen(base, stdout=subprocess.PIPE, text=True)
            p.stdout.readline()         # run id + address: driver is up
            killed = False
            deadline = time.monotonic() + 60
            while time.monotonic() < deadline and p.poll() is None:
                logs = [f for f in os.listdir(ckpt) if f.endswith(".log")]
                if logs and os.path.getsize(
                        os.path.join(ckpt, logs[0])) > 800:
                    p.send_signal(signal.SIGKILL)
                    killed = True
                    break
                time.sleep(0.005)
            p.wait(timeout=60)
            if not killed:              # run won the race: more work
                tasks *= 2
                continue
            r = subprocess.run(base + ["--resume", "latest"],
                               capture_output=True, text=True, timeout=180)
            assert r.returncode == 0, \
                f"resume failed rc={r.returncode}: {r.stderr[-2000:]}"
            with open(out, "rb") as f:
                got = pickle.load(f)
            assert got == execute_sequential(demo_graph(tasks)), \
                "resumed run diverged from the oracle"
            print(f"smoke: {workers}-worker repro-driver SIGKILL'd "
                  f"mid-run ({tasks}-task DAG), --resume latest "
                  "re-adopted the pool and matched the oracle "
                  "bit-for-bit", flush=True)
            return
    raise AssertionError("driver finished before the SIGKILL in every "
                         "attempt — could not exercise the resume path")


def smoke_differential(workers: int = 2) -> None:
    """CI gate: localhost-TCP control plane vs the sequential oracle,
    healthy and with a SIGKILL'd worker (heartbeat/EOF detection +
    lineage recovery)."""
    g = control_dag(50, 0.3, seed=7)
    seq = execute_sequential(g)
    ex = ClusterExecutor(workers, channel="tcp", progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    assert got == seq, "TCP-channel run diverged from the oracle"
    ex = ClusterExecutor(workers + 1, channel="tcp", fail_worker=(1, 2),
                         progress_timeout=120.0)
    got = ex.run(g)
    assert got == seq, "TCP-channel recovery run diverged from the oracle"
    assert ex.stats["failures"] == 1 and ex.stats["recomputed"] > 0, \
        ex.stats
    ex.close()
    print(f"smoke: 50-node DAG over TcpChannel x{workers} workers "
          "bit-identical to oracle (healthy + SIGKILL-recovered)",
          flush=True)


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tasks", type=int, default=300)
    ap.add_argument("--payload-mb", type=float, default=4.0)
    ap.add_argument("--producers", type=int, default=6)
    ap.add_argument("--consumers", type=int, default=6)
    ap.add_argument("--fan-in", type=int, default=3)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: differential gate + tiny timing pass")
    ap.add_argument("--driver-kill-smoke", action="store_true",
                    help="CI: SIGKILL a real repro-driver mid-run and "
                    "verify --resume latest finishes bit-for-bit")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.tasks = min(args.tasks, 120)
        args.payload_mb = min(args.payload_mb, 0.5)
        args.producers = min(args.producers, 4)
        args.consumers = min(args.consumers, 4)
        args.reps = 1
        smoke_differential(args.workers)
    if args.driver_kill_smoke:
        driver_kill_smoke(args.workers)

    # -- 1. control-plane overhead: pipe vs tcp on a cheap DAG ------------
    ctl = control_dag(args.tasks)
    control = {ch: time_channel(ctl, ch, args.workers, args.reps)
               for ch in ("pipe", "tcp")}
    overhead = (control["tcp"]["per_task_ms"]
                - control["pipe"]["per_task_ms"])

    # -- 2. per-transport shuffle wall-clock ------------------------------
    payload_elems = max(1, int(args.payload_mb * (1 << 20) / 4))
    shuffle = build_shuffle(args.producers, args.consumers, args.fan_in,
                            payload_elems)
    transports = ["driver", "tcp"]
    if serde.shm_available():
        transports.append("shm")
    if hasattr(__import__("socket"), "AF_UNIX"):
        transports.append("sock")
    rows = [time_shuffle(shuffle, "pipe", t, args.workers, args.reps)
            for t in transports]
    # the full multi-host shape: TCP control plane + TCP bulk pulls
    rows.append(time_shuffle(shuffle, "tcp", "tcp", args.workers,
                             args.reps))

    # -- 3. run-log checkpoint cost vs worker count -----------------------
    counts = [2, 8] if args.smoke else [2, 4, 8, 16, 32, 64]
    ckpt_rows = checkpoint_sweep(args.tasks, counts, args.reps)
    per = [r["bytes_per_cluster"] for r in ckpt_rows]
    flatness = max(per) / min(per) if min(per) > 0 else float("inf")
    if args.smoke:
        assert flatness <= 2.0, \
            f"checkpoint bytes/cluster not flat in workers: {ckpt_rows}"

    payload = {
        "config": {
            "tasks": args.tasks, "payload_mb": args.payload_mb,
            "producers": args.producers, "consumers": args.consumers,
            "fan_in": args.fan_in, "workers": args.workers,
            "reps": args.reps, "smoke": args.smoke,
        },
        "control_plane": control,
        "control_overhead_ms_per_task": overhead,
        "shuffle": rows,
        "checkpoint": {"rows": ckpt_rows,
                       "flatness_max_over_min": round(flatness, 3)},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows(f"control plane: {args.tasks}-task DAG, "
               f"{args.workers} workers", list(control.values()))
    print_rows(f"shuffle ({args.payload_mb} MiB payloads) per "
               "channel x transport", rows)
    print_rows(f"run-log bytes vs worker count ({args.tasks} clusters, "
               f"flatness {flatness:.2f})", ckpt_rows)
    print(f"\nTCP control-plane overhead: {overhead:+.2f} ms/task "
          f"-> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
