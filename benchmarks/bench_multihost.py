"""Multi-host control/data plane benchmark: pipe vs TCP.

Two questions, one artifact (``BENCH_multihost.json``):

1. **Control-plane overhead** — the same cheap 300-node DAG (integer
   arithmetic, ~zero compute) on forked pipe workers vs local TCP-dialed
   workers.  Every dispatch/done crosses the control channel, so the
   per-task wall-time delta is the price of framing + TCP + heartbeats
   over a kernel pipe.

2. **Per-transport shuffle wall-clock** — the wide shuffle from
   ``bench_transfer`` run over every data plane this host supports
   (``driver`` relay, ``shm``, ``sock``, ``tcp``), on both control
   planes where it makes sense.  This is the transport matrix a deploy
   chooses from: same-host shm vs the cross-host-capable TCP pulls.

``--smoke`` is the CI gate: 2 workers over the TCP channel, a 50-node
differential against the sequential oracle (bit-for-bit), plus a
SIGKILL-mid-run recovery check — then a tiny timing pass.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_multihost [--tasks 300]
        [--payload-mb 4] [--workers 2] [--reps 3] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time
from typing import Any, Dict, List, Optional

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, serde

from .bench_transfer import build_shuffle
from .common import median, print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_multihost.json")


def control_dag(n: int, p: float = 0.25, seed: int = 0) -> TaskGraph:
    """Cheap integer DAG: wall time ~= pure control-plane traffic."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def time_channel(graph: TaskGraph, channel: str, workers: int,
                 reps: int) -> Dict[str, Any]:
    walls = []
    stats: Dict[str, int] = {}
    for _ in range(reps):
        ex = ClusterExecutor(workers, channel=channel,
                             progress_timeout=180.0)
        t0 = time.perf_counter()
        ex.run(graph)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        ex.close()
    n = len(graph.nodes)
    wall = median(walls)
    return {"channel": channel, "wall_s": wall,
            "per_task_ms": 1e3 * wall / n,
            "dispatched": stats.get("dispatched", 0)}


def time_shuffle(graph: TaskGraph, channel: str, transport: str,
                 workers: int, reps: int) -> Dict[str, Any]:
    walls = []
    stats: Dict[str, int] = {}
    used = transport
    for _ in range(reps):
        ex = ClusterExecutor(workers, channel=channel, transport=transport,
                             outputs_only=True, progress_timeout=180.0,
                             pipeline_depth=4)
        t0 = time.perf_counter()
        ex.run(graph)
        walls.append(time.perf_counter() - t0)
        stats = dict(ex.stats)
        used = ex.transport_used or transport
        ex.close()
    return {"channel": channel, "transport": used,
            "wall_s": median(walls),
            "bytes_driver": stats.get("bytes_driver", 0),
            "bytes_direct": stats.get("bytes_direct", 0),
            "transfers_direct": stats.get("transfers_direct", 0)}


def smoke_differential(workers: int = 2) -> None:
    """CI gate: localhost-TCP control plane vs the sequential oracle,
    healthy and with a SIGKILL'd worker (heartbeat/EOF detection +
    lineage recovery)."""
    g = control_dag(50, 0.3, seed=7)
    seq = execute_sequential(g)
    ex = ClusterExecutor(workers, channel="tcp", progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    assert got == seq, "TCP-channel run diverged from the oracle"
    ex = ClusterExecutor(workers + 1, channel="tcp", fail_worker=(1, 2),
                         progress_timeout=120.0)
    got = ex.run(g)
    assert got == seq, "TCP-channel recovery run diverged from the oracle"
    assert ex.stats["failures"] == 1 and ex.stats["recomputed"] > 0, \
        ex.stats
    ex.close()
    print(f"smoke: 50-node DAG over TcpChannel x{workers} workers "
          "bit-identical to oracle (healthy + SIGKILL-recovered)",
          flush=True)


def main(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tasks", type=int, default=300)
    ap.add_argument("--payload-mb", type=float, default=4.0)
    ap.add_argument("--producers", type=int, default=6)
    ap.add_argument("--consumers", type=int, default=6)
    ap.add_argument("--fan-in", type=int, default=3)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: differential gate + tiny timing pass")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args(argv if argv is not None else [])
    if args.smoke:
        if args.out == OUT_PATH:    # never clobber the headline artifact
            args.out = OUT_PATH.replace(".json", "_smoke.json")
        args.tasks = min(args.tasks, 120)
        args.payload_mb = min(args.payload_mb, 0.5)
        args.producers = min(args.producers, 4)
        args.consumers = min(args.consumers, 4)
        args.reps = 1
        smoke_differential(args.workers)

    # -- 1. control-plane overhead: pipe vs tcp on a cheap DAG ------------
    ctl = control_dag(args.tasks)
    control = {ch: time_channel(ctl, ch, args.workers, args.reps)
               for ch in ("pipe", "tcp")}
    overhead = (control["tcp"]["per_task_ms"]
                - control["pipe"]["per_task_ms"])

    # -- 2. per-transport shuffle wall-clock ------------------------------
    payload_elems = max(1, int(args.payload_mb * (1 << 20) / 4))
    shuffle = build_shuffle(args.producers, args.consumers, args.fan_in,
                            payload_elems)
    transports = ["driver", "tcp"]
    if serde.shm_available():
        transports.append("shm")
    if hasattr(__import__("socket"), "AF_UNIX"):
        transports.append("sock")
    rows = [time_shuffle(shuffle, "pipe", t, args.workers, args.reps)
            for t in transports]
    # the full multi-host shape: TCP control plane + TCP bulk pulls
    rows.append(time_shuffle(shuffle, "tcp", "tcp", args.workers,
                             args.reps))

    payload = {
        "config": {
            "tasks": args.tasks, "payload_mb": args.payload_mb,
            "producers": args.producers, "consumers": args.consumers,
            "fan_in": args.fan_in, "workers": args.workers,
            "reps": args.reps, "smoke": args.smoke,
        },
        "control_plane": control,
        "control_overhead_ms_per_task": overhead,
        "shuffle": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print_rows(f"control plane: {args.tasks}-task DAG, "
               f"{args.workers} workers", list(control.values()))
    print_rows(f"shuffle ({args.payload_mb} MiB payloads) per "
               "channel x transport", rows)
    print(f"\nTCP control-plane overhead: {overhead:+.2f} ms/task "
          f"-> {args.out}", flush=True)
    return payload


if __name__ == "__main__":
    main(sys.argv[1:])
