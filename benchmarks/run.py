"""Benchmark orchestrator: one suite per paper table/figure + the roofline.

``PYTHONPATH=src python -m benchmarks.run [--suite name]``

Suites:
  matmul     — paper Fig. 2: matrix-task scaling vs workers (+ baselines)
  scheduler  — policy ablation (greedy-CP / FIFO / random; stealing; locality)
  fault      — failures, elasticity, stragglers, checkpoint barriers
  roofline   — per-(arch × shape) roofline terms from the dry-run artifacts
               (requires ``python -m repro.launch.dryrun`` results on disk)
  transfer   — data plane: driver-relayed vs zero-copy (shm / unix-socket)
               cross-worker transfers on a wide shuffle graph; writes
               BENCH_transfer.json at the repo root
  multihost  — control plane: fork+pipe vs localhost-TCP worker channels
               (per-task dispatch overhead) and the per-transport shuffle
               matrix incl. direct TCP pulls; writes BENCH_multihost.json
  speculation— tail latency: straggler-injected shuffle with speculative
               re-execution off vs on, per control channel; writes
               BENCH_speculation.json
  fusion     — driver hot path: fine-grained 801-node chain/map graph with
               the graph-compilation pass (--fuse auto) vs per-task
               dispatch (--fuse off), per control channel, bit-for-bit
               oracle + SIGKILL-recovery cross-checks; writes
               BENCH_fusion.json
  faults     — chaos: the loss/delay/partition matrix under seeded fault
               injection (FaultPlan), per control channel, every cell
               bit-for-bit vs the sequential oracle; writes
               BENCH_faults.json
  collectives— group communication: tree-lowered all_reduce + broadcast
               vs the N×M point-to-point fan-in baseline, per control
               channel × consumer count, bit-for-bit oracle + SIGKILL
               cross-checks; writes BENCH_collectives.json
  adaptive   — closed loop: mis-costed lopsided workload with adaptive
               re-fusion on vs off, per control channel, well-costed
               no-regression control, driver-SIGKILL resume replaying
               journaled re-fusions, trace-driven simulator cross-check;
               writes BENCH_adaptive.json
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (matmul_scaling, scheduler_bench, fault_bench, roofline,
               bench_transfer, bench_multihost, bench_speculation,
               bench_fusion, bench_faults, bench_collectives,
               bench_adaptive)

SUITES = {
    "matmul": matmul_scaling.main,
    "scheduler": scheduler_bench.main,
    "fault": fault_bench.main,
    "roofline": roofline.main,
    "transfer": bench_transfer.main,
    "multihost": bench_multihost.main,
    "speculation": bench_speculation.main,
    "fusion": bench_fusion.main,
    "faults": bench_faults.main,
    "collectives": bench_collectives.main,
    "adaptive": bench_adaptive.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all", choices=["all"] + list(SUITES))
    args = ap.parse_args()
    names = list(SUITES) if args.suite == "all" else [args.suite]
    t0 = time.time()
    failures = []
    for name in names:
        print(f"\n########## suite: {name} ##########", flush=True)
        try:
            SUITES[name]()
        except Exception as e:   # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"suite {name} FAILED: {e!r}", flush=True)
    print(f"\nbenchmarks done in {time.time() - t0:.1f}s; "
          f"{len(failures)} suite failure(s)")
    for name, err in failures:
        print(f"  FAIL {name}: {err}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
