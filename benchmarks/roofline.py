"""Roofline analysis from the dry-run's compiled artifacts (assignment
§ROOFLINE ANALYSIS).

Reads every ``results/dryrun/<arch>__<shape>__<mesh>[ _tag].json`` produced
by :mod:`repro.launch.dryrun` and derives, per cell:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
  memory term     = HLO_bytes_per_device / HBM_bw               [s]
  collective term = wire_ici/ICI_bw + wire_dcn/DCN_bw           [s]
                    (per-device wire bytes, ring-algorithm factors and
                     replica-group sizes parsed from the partitioned HLO)

plus MODEL_FLOPS = 6·N(_active)·D (train) or 2·N_active·D (inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, the
roofline-implied MFU bound, and a one-line lever.

v5e constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI; DCN taken at 25 GB/s per chip (cross-pod).
"""
from __future__ import annotations

import functools
import glob
import json
import math
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9
HBM_PER_CHIP = 16 * 1024 ** 3          # v5e: 16 GiB

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@functools.lru_cache(maxsize=None)
def _param_counts(arch: str):
    """(total, active) parameter counts — eval_shape only, no allocation."""
    import jax
    from repro.configs import get_config
    from repro.models import transformer as TF
    from repro.models import encdec as ED
    cfg = get_config(arch)
    if cfg.is_encoder_decoder:
        n = sum(math.prod(l.shape) for l in
                jax.tree.leaves(ED.abstract_params(cfg)))
        return n, n
    return TF.count_params(cfg), TF.count_active_params(cfg)


def _tokens_per_step(shape: str) -> int:
    from repro.models.config import SHAPES
    s = SHAPES[shape]
    if s.kind == "train":
        return s.seq_len * s.global_batch
    if s.kind == "prefill":
        return s.seq_len * s.global_batch
    return s.global_batch              # decode: one token per sequence


def model_flops(arch: str, shape: str) -> float:
    from repro.models.config import SHAPES
    total, active = _param_counts(arch)
    D = _tokens_per_step(shape)
    mult = 6.0 if SHAPES[shape].kind == "train" else 2.0
    return mult * active * D


def analyse_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "OK":
        return None
    chips = rec["chips"]
    # prefer depth-corrected costs (unrolled probes; the scanned program's
    # cost_analysis counts the layer loop body once) — see launch/dryrun.py
    src = rec.get("corrected", rec)
    flops_dev = src.get("flops_per_device", rec["flops_per_device"])
    bytes_dev = src.get("bytes_per_device", rec["bytes_per_device"])
    coll = src.get("collectives", rec.get("collectives", {}))
    if rec.get("mesh") == "multi" and "corrected" not in rec:
        coll = rec.get("collectives", {})
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    wire_ici = coll.get("_wire_ici_bytes", 0.0)
    wire_dcn = coll.get("_wire_dcn_bytes", 0.0)
    t_coll = wire_ici / ICI_BW + wire_dcn / DCN_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    mf = model_flops(rec["arch"], rec["shape"])
    mf_dev = mf / chips
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    t_model = mf_dev / PEAK_FLOPS
    mfu_bound = t_model / bound if bound > 0 else 0.0

    mem_gib = (rec.get("argument_size_in_bytes", 0)
               + rec.get("temp_size_in_bytes", 0)) / 1024 ** 3

    raw = {k: v for k, v in coll.items() if not k.startswith("_")}
    big_coll = max(raw, key=raw.get) if raw else "-"

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "mfu_bound": mfu_bound,
        "useful_flops_ratio": useful_ratio,
        "mem_gib_per_dev": mem_gib,
        "fits_hbm": mem_gib * 1024 ** 3 < HBM_PER_CHIP,
        "top_collective": big_coll,
        "lever": lever(dominant, rec, useful_ratio, big_coll),
    }


def lever(dominant: str, rec: Dict, useful_ratio: float,
          big_coll: str) -> str:
    kind = rec["shape"].split("_")[0]
    if dominant == "compute":
        if useful_ratio < 0.55 and kind == "train":
            return ("remat recompute inflates HLO FLOPs "
                    f"(useful={useful_ratio:.0%}); relax checkpoint policy")
        return "compute-bound near useful FLOPs; raise arithmetic intensity per chip (larger per-chip tile)"
    if dominant == "memory":
        if kind in ("decode", "long"):
            return ("decode is HBM-bound on weights+KV reads; quantize KV / "
                    "shard cache over more axes / batch more requests")
        return "HBM-bound: fuse elementwise chains, avoid f32 spills, check layout transposes"
    return f"collective-bound (top: {big_coll}); reshard to cut it or overlap with compute"


def load_cells(mesh: str = "single", tag: str = "") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh or rec.get("tag", "") != tag:
            continue
        row = analyse_record(rec)
        if row:
            rows.append(row)
    return rows


def skip_cells(mesh: str = "single") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh or rec.get("status") != "SKIP":
            continue
        if rec.get("tag", ""):
            continue
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "reason": rec["reason"][:70]})
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | Tcomp (ms) | Tmem (ms) | Tcoll (ms) | dominant "
           "| MFU-bound | useful | lever |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} "
            f"| {r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} "
            f"| **{r['dominant']}** | {r['mfu_bound']:.1%} "
            f"| {r['useful_flops_ratio']:.0%} | {r['lever']} |")
    return "\n".join(out)


def main() -> List[Dict]:
    from .common import print_rows, write_csv
    rows = load_cells("single")
    write_csv("roofline_single", rows)
    slim = [{k: v for k, v in r.items()
             if k in ("arch", "shape", "t_compute_s", "t_memory_s",
                      "t_collective_s", "dominant", "mfu_bound",
                      "useful_flops_ratio")} for r in rows]
    print_rows("Roofline (single-pod 256-chip mesh)", slim)
    skips = skip_cells("single")
    if skips:
        print_rows("Skipped cells", skips)
    multi = load_cells("multi")
    if multi:
        write_csv("roofline_multi", multi)
        # multi-pod is the shardability + DCN-attribution check (the scored
        # roofline table is single-pod, with probe-corrected costs); only
        # print the collective/DCN view — per-layer FLOPs/bytes corrections
        # are not computed for multi cells, so MFU there would mislead
        sl = [{k: v for k, v in r.items()
               if k in ("arch", "shape", "t_collective_s", "dominant")}
              for r in multi]
        print_rows("Multi-pod 512-chip: collective/DCN view "
                   "(shardability check; roofline scored on single-pod)", sl)
    return rows


if __name__ == "__main__":
    main()
