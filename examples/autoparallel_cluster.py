"""The paper's full pipeline at cluster scale: trace → schedule →
work-stealing execution under failures/stragglers → SPMD mesh lowering.

Demonstrates the three levels of the auto-parallelizer:
  inter-op (simulated): the matrix task DAG from the paper's §4 benchmark
            scheduled on a simulated 64-worker cluster, with a worker
            failure and lineage recovery mid-run;
  inter-op (REAL):      the same DAG executed by the multi-process
            ClusterExecutor — OS-process workers, driver-side object
            store — with one worker SIGKILLed mid-run and recovered via
            lineage + an elastic replacement join;
  intra-op: the SAME traced DAG lowered into one pjit program on an 8-device
            mesh (run in a subprocess with forced host devices), with the
            placement pass choosing every intermediate's sharding.

Run: PYTHONPATH=src python examples/autoparallel_cluster.py
"""
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                     # noqa: E402

from repro.core import (task, trace, simulate, WorkerEvent,        # noqa: E402
                        execute_sequential, theoretical_speedup)
from repro.cluster import ClusterExecutor              # noqa: E402


def matrix_driver(n_tasks=32, size=64):
    @task(cost=1.0, name="gen", out_bytes=size * size * 4)
    def gen(seed):
        rng = np.random.default_rng(seed)
        return rng.standard_normal((size, size), dtype=np.float32)

    @task(cost=2.0, name="mul", out_bytes=size * size * 4)
    def mul(a, b):
        return a @ b

    @task(cost=0.1, name="reduce")
    def red(*xs):
        return float(sum(float(x.sum()) for x in xs))

    outs = []
    for i in range(n_tasks):
        outs.append(mul(gen(2 * i), gen(2 * i + 1)))
    return red(*outs)


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.core import (task, trace, placeholder, MeshExecutor,
                        standard_rules, ValueInfo, execute_sequential)
from repro.parallel.mesh import make_mesh_for

@task(cost=1.0)
def gen(seed):
    return jax.random.normal(jax.random.PRNGKey(seed), (256, 256))

@task(cost=2.0)
def mul(a, b):
    return a @ b

@task(cost=0.1)
def combine(*xs):
    return sum(xs)

def driver():
    return combine(*[mul(gen(2*i), gen(2*i+1)) for i in range(4)])

graph, _ = trace(driver)
mesh = make_mesh_for(8, model_parallel=2)
info = {t: ValueInfo((256, 256), 4, ("batch", "d_model"))
        for t in graph.nodes}
ex = MeshExecutor(graph, mesh, standard_rules("dp_tp", pod_axis=None),
                  value_info=info)
out = ex({})[0]
want = execute_sequential(graph)[graph.outputs[0]]
# partitioned matmuls reduce in a different order than the single-device
# oracle; tolerate reduction-reordering noise
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           rtol=1e-4, atol=1e-4)
coll = [l.split()[0] for l in ex.hlo_text().splitlines()
        if "all-reduce(" in l or "all-gather(" in l]
print(f"   SPMD lowering on {mesh.shape}: output matches sequential;"
      f" {len(coll)} collectives in the partitioned HLO")
"""

if __name__ == "__main__":
    graph, _ = trace(matrix_driver)
    print("1) traced matrix workload:", graph.summary())

    print("\n2) 64-worker cluster, fault-free:")
    base = simulate(graph, 64)
    print(f"   makespan {base.makespan:.2f}s  "
          f"(speedup {graph.total_work()/base.makespan:.1f}x, "
          f"bound {theoretical_speedup(graph, 64):.1f}x, "
          f"steals {base.n_steals})")

    print("\n3) same run, worker 0 dies + two stragglers appear:")
    events = [WorkerEvent(time=base.makespan * 0.4, kind="fail", worker=0),
              WorkerEvent(time=base.makespan * 0.3, kind="slow", worker=1,
                          factor=0.1),
              WorkerEvent(time=base.makespan * 0.3, kind="slow", worker=2,
                          factor=0.1)]
    r = simulate(graph, 64, events=events, speculate_after=1.5)
    print(f"   makespan {r.makespan:.2f}s "
          f"({r.makespan/base.makespan:.2f}x of fault-free) | "
          f"recomputed {r.n_recomputed} tasks (lineage) | "
          f"{r.n_speculative} speculative re-executions")

    print("\n4) REAL multi-process cluster: 4 OS-process workers, worker 0 "
          "SIGKILLed mid-run,\n   a replacement joins; lineage recovery + "
          "elastic replan keep the answer exact:")
    ex = ClusterExecutor(4, fail_worker=(0, 4),
                         join_after=(len(graph.nodes) // 2, 1))
    res = ex.run(graph)
    want = execute_sequential(graph)
    assert all(np.allclose(res[t], want[t]) for t in graph.nodes)
    plan_sizes = [len(e["plan"]) for e in ex.recovery_events]
    print(f"   {len(graph.nodes)} tasks in {ex.wall_time:.2f}s | "
          f"failures {ex.stats['failures']} (recomputed "
          f"{ex.stats['recomputed']} = lineage plan {plan_sizes}) | "
          f"joins {ex.stats['joins']} | transfers {ex.stats['transfers']} "
          f"| matches sequential oracle ✓")

    print("\n5) lower the DAG onto an 8-device SPMD mesh (subprocess):")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    p = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    print(p.stdout.rstrip())
    if p.returncode != 0:
        print(p.stderr[-2000:])
        raise SystemExit(1)
    print("\nall stages OK  ✓")
