"""End-to-end driver: train a qwen2-family LM on CPU with the full stack —
data pipeline → auto-sharded train step → async checkpointing → restart.

This is the reduced-scale version of ``python -m repro.launch.train`` (the
launcher this script calls); the full-size configs run the same code path
on a real mesh (proven by the 512-device dry-run).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 256]

At the default 200 steps / ~17M params this takes a few CPU-minutes and the
loss drops well below the unigram entropy of the synthetic zipf stream —
then the script kills itself at step ~60%, restarts from the checkpoint,
and shows the loss curve continuing exactly (fault-tolerance demo).
"""
import argparse
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)
    crash_at = max(args.steps * 6 // 10, 2)

    print(f"=== phase 1: train to step {crash_at}, then 'crash' ===")
    r1 = train_mod.main([
        "--arch", "qwen2-7b", "--reduced",
        "--steps", str(crash_at),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt, "--ckpt-every", "20",
        "--log-every", "20",
    ])

    print("\n=== phase 2: restart from the checkpoint, finish the run ===")
    r2 = train_mod.main([
        "--arch", "qwen2-7b", "--reduced",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt, "--ckpt-every", "20",
        "--log-every", "20", "--resume",
    ])

    # the restart resumed from the last checkpoint BEFORE the crash, so the
    # first resumed losses replay the same (step-addressed) batches
    print("\n=== summary ===")
    print(f"phase-1 final loss {r1['losses'][-1]:.4f} at step {crash_at - 1}")
    print(f"phase-2 resumed at step {r2['start_step']}, "
          f"final loss {r2['losses'][-1]:.4f}")
    assert r2["losses"][-1] < r1["losses"][0] * 0.8, "no learning?"
    print("loss decreased end-to-end across the restart  ✓")


if __name__ == "__main__":
    main()
