"""Quickstart — the paper's interface in 60 lines.

Mark coarse functions with ``@task`` / ``@io_task``, write a plain Python
driver, and the auto-parallelizer does the rest: it traces the driver into a
data-dependency DAG (the paper's "parser"), schedules tasks greedily as
their inputs become ready, and executes them on a work-stealing worker pool
— while IO stays in program order via RealWorld-token edges.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (task, io_task, trace, list_schedule, simulate,
                        ThreadedExecutor, execute_sequential,
                        theoretical_speedup)

# --- the paper's §2 example, verbatim shape -------------------------------


@io_task(cost=2.0)
def clean_files():
    print("  [io] clean_files")
    return np.arange(64.0)                      # "Summary"


@task(cost=5.0)
def complex_evaluation(x):
    return float((x * x).sum())


@io_task(cost=2.0)
def semantic_analysis():
    print("  [io] semantic_analysis")
    return 42


def main_driver():
    x = clean_files()
    y = complex_evaluation(x)
    z = semantic_analysis()
    return y, z


if __name__ == "__main__":
    print("1) trace the driver -> dependency DAG (paper Fig. 1):")
    graph, outs = trace(main_driver)
    print("  ", graph.summary())
    for node in graph:
        deps = list(node.deps) + [f"RW:{t}" for t in node.token_deps]
        print(f"   {node.name}#{node.tid} kind={node.kind.value} deps={deps}")

    print("\n2) greedy ready-set schedule on 2 workers:")
    sched = list_schedule(graph, 2)
    for p in sorted(sched.placements.values(), key=lambda p: p.start):
        print(f"   w{p.worker}  t={p.start:4.1f}..{p.end:4.1f}  "
              f"{graph.nodes[p.tid].name}")
    print(f"   makespan {sched.makespan:.1f}s vs sequential "
          f"{graph.total_work():.1f}s "
          f"(bound {theoretical_speedup(graph, 2):.2f}x)")

    print("\n3) execute for real (4 threads, work stealing):")
    seq = execute_sequential(graph)
    par = ThreadedExecutor(4).run(graph)
    assert all(seq[t] == par[t] for t in graph.outputs)
    print("   parallel == sequential, effects in program order  ✓")

    print("\n4) simulate the same DAG on a 512-worker cluster:")
    r = simulate(graph, 512)
    print(f"   makespan {r.makespan:.1f}s (span-bound — this tiny graph "
          f"has max_parallelism {graph.max_parallelism():.2f})")
