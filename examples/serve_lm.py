"""Serving example: batched prefill+decode with continuous batching.

Uses the same step functions the decode_32k / prefill_32k dry-run cells
compile, at CPU scale.  Reports TTFT and per-token latency.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod   # noqa: E402

if __name__ == "__main__":
    out = serve_mod.main([
        "--arch", "yi-9b", "--reduced",
        "--requests", "8", "--slots", "4", "--max-new", "8",
    ])
    assert out["decode_steps"] > 0
    print("continuous-batching serve loop OK  ✓")
