"""ClusterConfig consolidation: the frozen config object, the legacy
keyword shim (DeprecationWarning once per name), and the shared argparse
flag group every launcher now generates from the config fields."""
import argparse
import dataclasses
import warnings

import pytest

import repro
from repro.config import (ClusterConfig, TENANT_FIELDS, _warned_kwargs,
                          resolve_config)
from repro.cluster import ClusterExecutor


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """The shim warns once per name per process; make each test see a
    fresh process for deterministic warning counts."""
    saved = set(_warned_kwargs)
    _warned_kwargs.clear()
    yield
    _warned_kwargs.clear()
    _warned_kwargs.update(saved)


# ------------------------------------------------------------- the shim

def test_legacy_kwarg_warns_once_per_name():
    with pytest.warns(DeprecationWarning, match="'fuse'.*deprecated"):
        resolve_config(None, {"fuse": "auto"})
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # second use: no warning
        resolve_config(None, {"fuse": "off"})
    with pytest.warns(DeprecationWarning, match="'outputs_only'"):
        resolve_config(None, {"outputs_only": True})


def test_legacy_kwargs_equal_config_form():
    with pytest.warns(DeprecationWarning):
        ex_legacy = ClusterExecutor(4, fuse="auto", outputs_only=True,
                                    progress_timeout=120.0)
    ex_config = ClusterExecutor(config=ClusterConfig(
        n_workers=4, fuse="auto", outputs_only=True,
        progress_timeout=120.0))
    assert ex_legacy.config == ex_config.config


def test_legacy_kwargs_override_config_fields():
    cfg = ClusterConfig(n_workers=2, fuse="off")
    with pytest.warns(DeprecationWarning):
        merged = resolve_config(cfg, {"fuse": "auto"})
    assert merged.fuse == "auto" and merged.n_workers == 2
    assert cfg.fuse == "off"                # input config untouched


def test_unknown_kwarg_is_typeerror_like_a_misspelled_keyword():
    with pytest.raises(TypeError, match="fuze"):
        resolve_config(None, {"fuze": "auto"})
    with pytest.raises(TypeError, match="ClusterExecutor"):
        ClusterExecutor(2, not_a_field=1)


def test_positional_n_workers_overrides_config():
    ex = ClusterExecutor(3, config=ClusterConfig(n_workers=8))
    assert ex.config.n_workers == 3


# ---------------------------------------------------------- the config

def test_config_is_frozen_and_replace_copies():
    cfg = ClusterConfig(n_workers=2)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.n_workers = 4
    assert cfg.replace(n_workers=4).n_workers == 4
    assert cfg.n_workers == 2


def test_config_validates_choices():
    with pytest.raises(ValueError, match="transport"):
        ClusterConfig(transport="carrier-pigeon")
    with pytest.raises(ValueError, match="fuse"):
        ClusterConfig(fuse="sometimes")


def test_executor_rejects_resume_without_checkpoint_dir():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        ClusterExecutor(config=ClusterConfig(n_workers=1, resume="abc"))


def test_public_reexport():
    assert repro.ClusterConfig is ClusterConfig


# ------------------------------------------------------- the flag group

def test_flags_round_trip():
    cfg = ClusterConfig(n_workers=5, transport="tcp", channel="tcp",
                        fuse="auto", token="s3cret", speculate_after=1.5,
                        checkpoint_dir="/tmp/ck", outputs_only=True)
    ap = argparse.ArgumentParser()
    ClusterConfig.add_flags(ap)
    args = ap.parse_args(cfg.to_flags())
    assert ClusterConfig.from_flags(args) == cfg


def test_flags_defaults_match_config_defaults():
    ap = argparse.ArgumentParser()
    ClusterConfig.add_flags(ap)
    assert ClusterConfig.from_flags(ap.parse_args([])) == ClusterConfig()


def test_add_flags_defaults_override():
    """Launchers keep their historical defaults (e.g. fuse=auto) without
    forking the flag definitions."""
    ap = argparse.ArgumentParser()
    ClusterConfig.add_flags(ap, names=("fuse", "channel"),
                            defaults={"fuse": "auto"})
    args = ap.parse_args([])
    assert args.fuse == "auto"
    assert ClusterConfig.from_flags(args).fuse == "auto"


def test_channel_auto_parses_to_none():
    ap = argparse.ArgumentParser()
    ClusterConfig.add_flags(ap, names=("channel",))
    assert ap.parse_args(["--channel", "auto"]).channel is None
    assert ap.parse_args(["--channel", "tcp"]).channel == "tcp"


def test_from_flags_names_ignores_colliding_launcher_flags():
    """A launcher's own flags may share a destination with a config
    field (train.py --resume, --seed); reading back with the same
    ``names`` subset must not leak them into the cluster config."""
    ap = argparse.ArgumentParser()
    ClusterConfig.add_flags(ap, names=("fuse",))
    ap.add_argument("--resume", action="store_true")   # launcher's own
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(["--resume"])
    cfg = ClusterConfig.from_flags(args, names=("fuse",))
    assert cfg.resume is None and cfg.seed == ClusterConfig().seed


def test_flag_subset_selection():
    ap = argparse.ArgumentParser()
    ClusterConfig.add_flags(ap, names=("fuse",))
    args = ap.parse_args([])
    assert not hasattr(args, "transport")


def test_tenant_fields_are_a_strict_subset_of_the_submit_surface():
    """Per-job tenant knobs must never silently grow to pool-level ones:
    everything else on ClusterConfig belongs to the gateway operator."""
    assert TENANT_FIELDS == frozenset({"outputs_only", "label"})
    field_names = {f.name for f in dataclasses.fields(ClusterConfig)}
    assert "outputs_only" in field_names
    assert "transport" not in TENANT_FIELDS
