"""End-to-end launcher tests: train → checkpoint → resume, and serving.

These drive the REAL launchers (the same code the dry-run compiles) at
reduced scale, in-process.
"""
import numpy as np
import pytest

from repro.launch import train as train_mod
from repro.launch import serve as serve_mod


def test_train_checkpoint_resume_exact(tmp_path):
    ck = str(tmp_path / "ck")
    base = ["--arch", "qwen2-7b", "--reduced", "--batch", "2",
            "--seq", "16", "--ckpt-every", "5", "--log-every", "100"]
    common = base + ["--ckpt-dir", ck]
    r1 = train_mod.main(common + ["--steps", "8"])
    assert len(r1["losses"]) == 8
    assert r1["losses"][-1] < r1["losses"][0]          # it learns

    # uninterrupted reference run to step 12 (its OWN ckpt dir — must not
    # overwrite the checkpoint the resumed run restarts from)
    r_full = train_mod.main(base + ["--ckpt-dir", str(tmp_path / "ref"),
                                    "--steps", "12"])

    # resumed run: restarts from r1's step-5 checkpoint, replays 6..11
    r2 = train_mod.main(common + ["--steps", "12", "--resume"])
    assert r2["start_step"] == 6
    # the data stream is step-addressed, so the resumed losses REPLAY the
    # reference run's trajectory exactly from the checkpoint point
    np.testing.assert_allclose(r2["losses"], r_full["losses"][6:12],
                               rtol=1e-4, atol=1e-5)


def test_train_moe_reduced_runs():
    r = train_mod.main(["--arch", "dbrx-132b", "--reduced", "--steps", "3",
                        "--batch", "2", "--seq", "16", "--log-every", "100"])
    assert np.isfinite(r["losses"]).all()


def test_serve_continuous_batching():
    out = serve_mod.main(["--arch", "yi-9b", "--reduced", "--requests", "4",
                          "--slots", "2", "--max-new", "4"])
    fin = out["finished"]
    assert len(fin) == 4
    assert all(len(r.out) == 4 for r in fin)
    assert all(r.t_done >= r.t_first >= r.t_submit for r in fin)


def test_serve_rejects_encdec():
    with pytest.raises(SystemExit):
        serve_mod.main(["--arch", "whisper-tiny", "--reduced",
                        "--requests", "1"])


def test_train_show_graph_executes_on_thread_backend(capsys):
    """--show-graph traces one driver iteration and really executes it on
    the selected backend; the traced-step loss must equal the main loop's
    step-0 loss (same recipe, same seed, same batch)."""
    r = train_mod.main(["--arch", "qwen2-7b", "--reduced", "--steps", "1",
                        "--batch", "2", "--seq", "16", "--log-every", "100",
                        "--show-graph", "--backend", "thread"])
    out = capsys.readouterr().out
    assert "[thread backend] executed 4 tasks" in out
    traced = float(out.split("traced-driver step loss:")[1].split()[0])
    assert traced == pytest.approx(r["losses"][0], rel=1e-4)


def test_serve_show_graph_executes_on_thread_backend(capsys):
    """The traced prefill→decode chain executed on the thread backend must
    produce the same first tokens as the real serving loop."""
    out_res = serve_mod.main(["--arch", "qwen2-7b", "--reduced",
                              "--requests", "1", "--slots", "1",
                              "--max-new", "4", "--show-graph",
                              "--backend", "thread"])
    out = capsys.readouterr().out
    traced = eval(out.split("traced request tokens:")[1].splitlines()[0])
    assert traced == out_res["finished"][0].out[:3]
