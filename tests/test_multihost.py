"""Multi-host control plane: the Channel abstraction, the TCP channel
(handshake, heartbeat liveness, backpressure), ``repro-worker`` dial-in,
the ``tcp`` data-plane transport, per-host locality, elastic joins under
``sock``/TCP, and the transport-validation satellite.

Local TCP workers are *forked dialers* — the graph is inherited by fork
(closures allowed) while every control message rides real localhost TCP,
so these differentials exercise the exact multi-host code path: framed
streams, heartbeats, EOF-not-SIGCHLD death detection, goodbye frames.
The ``repro-worker`` tests add the full remote contract on top: a fresh
interpreter dials the driver, receives the pickled graph in the welcome
frame, and serves tasks — which is why their task functions live at
module level (`_mh_combine`), exactly like ``start_method="spawn"``.
"""
import glob
import os
import pickle
import random
import signal
import socket
import subprocess
import sys
import threading
import time
from functools import partial

import numpy as np
import pytest

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.scheduler import list_schedule
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, serde
from repro.cluster.channel import (ChannelClosed, TcpChannel, TcpListener,
                                   _FrameBuffer, _send_frame, dial_driver,
                                   PROTOCOL_MAGIC, PROTOCOL_VERSION)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKER_ENV = dict(
    os.environ,
    PYTHONPATH=os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.dirname(os.path.abspath(__file__))]
        + ([os.environ["PYTHONPATH"]] if os.environ.get("PYTHONPATH") else [])))


def exec_dag(seed: int, n: int, p: float, sleep: float = 0.0) -> TaskGraph:
    """Random integer DAG (closures — fine for fork-started dialers)."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i, _s=sleep):
            if _s:
                time.sleep(_s)
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def array_dag(seed: int, n: int, p: float, elems: int) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i, _e=elems):
            acc = (np.arange(_e) % 89).astype(np.float32) \
                * np.float32(_i % 5 + 1)
            for x in xs:
                acc = (acc + x).astype(np.float32)
            return acc

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def _mh_combine(i, *xs):
    """Module-level task body: picklable, so remote workers can import it."""
    return (i + sum(xs) * 7) % 1_000_003


def _mh_combine_slow(i, *xs):
    """Same arithmetic, padded to keep a run alive while a joiner dials."""
    time.sleep(0.03)
    return _mh_combine(i, *xs)


def picklable_dag(seed: int, n: int, p: float, slow: bool = False
                  ) -> TaskGraph:
    """DAG whose node fns survive pickling (remote-worker requirement)."""
    rng = random.Random(seed)
    fn = _mh_combine_slow if slow else _mh_combine
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]
        g.add_node(f"t{i}", partial(fn, i),
                   tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=1.0)
    g.mark_output(n - 1)
    return g


def results_equal(got, want) -> bool:
    return set(got) == set(want) and all(
        np.array_equal(got[t], want[t])
        if isinstance(want[t], np.ndarray) else got[t] == want[t]
        for t in want)


def start_repro_worker(address: str, *extra: str) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.remote",
         "--connect", address, "--timeout", "30", *extra],
        env=WORKER_ENV, cwd=REPO)


# ------------------------------------------------------------ channel units

def test_frame_buffer_reassembles_split_frames():
    """Length-prefixed framing must survive arbitrary TCP segmentation."""
    msgs = [("run", 1, {"a": 1}), ("hb",), ("done", 0, 1, 0.5, 64, [2, 3])]
    blob = b"".join(
        len(p).to_bytes(8, "little") + p
        for p in (pickle.dumps(m, protocol=5) for m in msgs))
    for step in (1, 3, 7, len(blob)):
        buf = _FrameBuffer()
        out = []
        for i in range(0, len(blob), step):
            out.extend(buf.feed(blob[i:i + step]))
        assert out == msgs


def _handshaken_pair(listener: TcpListener, **chan_kw):
    """Dial the listener like a worker would; return (driver_chan, sock).
    The hello is JSON — the driver never unpickles pre-auth bytes."""
    import json

    sock = socket.create_connection(
        tuple(listener.address.rsplit(":", 1))[:1]
        + (int(listener.address.rsplit(":", 1)[1]),))
    _send_frame(sock, json.dumps(
        {"magic": PROTOCOL_MAGIC, "version": PROTOCOL_VERSION,
         "token": None, "host": "far-host", "pid": os.getpid(),
         "has_graph": True}).encode("utf-8"))
    server_sock, hello = listener.get_worker(timeout=10.0)
    assert hello["host"] == "far-host"
    return TcpChannel(server_sock, **chan_kw), sock


def test_tcp_channel_heartbeat_death_and_goodbye():
    """A silent TCP peer is dead after heartbeat_timeout — but a peer that
    said an explicit goodbye is a clean exit, never a crash."""
    listener = TcpListener("127.0.0.1:0")
    try:
        chan, sock = _handshaken_pair(listener, heartbeat_timeout=0.3)
        assert chan.dead() is None
        time.sleep(0.5)
        reason = chan.dead()
        assert reason is not None and "heartbeat" in reason
        # a goodbye frame absolves the silence
        _send_frame(sock, pickle.dumps(("bye", 0), protocol=5))
        time.sleep(0.05)
        assert chan.recv_available() == [("bye", 0)]
        time.sleep(0.5)
        assert chan.dead() is None      # clean shutdown, not a crash
        chan.close()
        sock.close()
    finally:
        listener.close()


def test_tcp_channel_backpressure_bounds_sends():
    """A peer that stops draining must surface as ChannelClosed from send
    (bounded outbox), not wedge the caller in a blocking sendall."""
    listener = TcpListener("127.0.0.1:0")
    try:
        chan, sock = _handshaken_pair(
            listener, outbox_size=1, send_timeout=0.2)
        payload = ("blob", b"x" * (4 << 20))    # beyond loopback buffers
        with pytest.raises(ChannelClosed, match="backpressure"):
            for _ in range(64):
                chan.send(payload)
        chan.close()
        sock.close()
    finally:
        listener.close()


def test_listener_rejects_bad_token_and_version():
    listener = TcpListener("127.0.0.1:0", token="s3cret")
    try:
        with pytest.raises(ChannelClosed, match="rejected"):
            dial_driver(listener.address, token="wrong", timeout=5.0,
                        has_graph=True)
        # and a good token handshakes (driver side never welcomes here,
        # so just verify the hello got queued)
        def good_dial():
            try:        # no welcome ever comes back in this unit test
                dial_driver(listener.address, token="s3cret",
                            timeout=5.0, has_graph=True)
            except ChannelClosed:
                pass

        threading.Thread(target=good_dial, daemon=True).start()
        _, hello = listener.get_worker(timeout=10.0)
        assert hello["token"] == "s3cret"
    finally:
        listener.close()


# ----------------------------------------------- localhost-TCP differential

def test_tcp_channel_differential_50_node():
    """Acceptance: TaskGraph over TcpChannel matches the oracle."""
    g = exec_dag(42, 50, 0.3)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, channel="tcp")
    try:
        assert ex.run(g) == seq
        assert ex.stats["dispatched"] >= 50
        assert ex.stats["failures"] == 0
    finally:
        ex.close()


def test_tcp_channel_arrays_and_tcp_transport_bit_identical():
    """Control plane AND data plane over TCP: float32 arrays bit-for-bit,
    bulk bytes moving worker-to-worker over direct TCP pulls."""
    g = array_dag(7, 18, 0.4, elems=1 << 16)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, channel="tcp", transport="tcp")
    try:
        res = ex.run(g)
        assert results_equal(res, seq)
        assert ex.transport_used == "tcp"
        assert ex.stats["transfers_direct"] > 0
        assert ex.stats["bytes_direct"] > 0
    finally:
        ex.close()


def test_tcp_transport_on_pipe_channel_matches_oracle():
    """The tcp data plane is independent of the control plane: forked
    pipe workers pulling bulk values over TCP peer sockets."""
    g = array_dag(11, 14, 0.4, elems=1 << 15)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, transport="tcp", shm_threshold=1)
    assert results_equal(ex.run(g), seq)
    assert ex.stats["transfers_direct"] > 0


def test_tcp_channel_sigkill_heartbeat_recovery():
    """Acceptance: SIGKILL a TCP worker mid-run.  No SIGCHLD reaches the
    channel layer's liveness logic — the death is seen by the socket/
    heartbeat path — and lineage recovery still matches the oracle."""
    g = array_dag(13, 24, 0.4, elems=1 << 14)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, channel="tcp", fail_worker=(1, 2))
    try:
        res = ex.run(g)
        assert results_equal(res, seq)
        assert ex.stats["failures"] == 1
        assert ex.stats["recomputed"] > 0
        assert len(ex.recovery_events) >= 1
    finally:
        ex.close()


def test_tcp_channel_outputs_only_gc():
    g = exec_dag(5, 60, 0.3)
    seq = execute_sequential(g)
    want = {t: seq[t] for t in g.outputs}
    ex = ClusterExecutor(2, channel="tcp", outputs_only=True)
    try:
        assert ex.run(g) == want
        assert ex.stats["dropped"] > 0
    finally:
        ex.close()


# -------------------------------------------------------------- elasticity

def test_elastic_join_under_sock_transport():
    """Satellite: add_worker/join_after under transport='sock' — join two
    workers mid-run, then SIGKILL one of the joiners."""
    if not hasattr(socket, "AF_UNIX"):
        pytest.skip("no unix sockets here")
    g = exec_dag(11, 120, 0.25)
    seq = execute_sequential(g)
    # joiners get wids 2 and 3; kill wid 2 after its 2nd completion
    ex = ClusterExecutor(2, transport="sock", shm_threshold=1,
                         join_after=(20, 2), fail_worker=(2, 2))
    assert ex.run(g) == seq
    assert ex.stats["joins"] == 2
    assert ex.stats["failures"] == 1


def test_elastic_join_tcp_channel_then_kill_joiner():
    """Satellite: elastic join over the TCP channel, then SIGKILL the
    joined worker — heartbeat/EOF detection + lineage recovery."""
    g = exec_dag(17, 120, 0.25)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, channel="tcp", join_after=(15, 1),
                         fail_worker=(2, 2))
    try:
        assert ex.run(g) == seq
        assert ex.stats["joins"] == 1
        assert ex.stats["failures"] == 1
    finally:
        ex.close()


def _mh_exit_now(*a, **kw):
    os._exit(3)


def test_dead_local_dialer_fails_fast(monkeypatch):
    """A dialer that dies at bootstrap must fail the run immediately with
    its exit code, not hang out the whole accept_timeout."""
    import repro.cluster.executor as exmod

    monkeypatch.setattr(exmod, "tcp_worker_main", _mh_exit_now)
    ex = ClusterExecutor(1, channel="tcp", accept_timeout=60.0)
    t0 = time.monotonic()
    try:
        with pytest.raises(RuntimeError, match="before dialing"):
            ex.run(exec_dag(1, 5, 0.3))
        assert time.monotonic() - t0 < 20.0
    finally:
        ex.close()


def test_add_worker_idle_grows_tcp_pool():
    ex = ClusterExecutor(1, channel="tcp")
    try:
        ex.add_worker()
        assert ex.n_workers == 2
        g = exec_dag(23, 40, 0.3)
        assert ex.run(g) == execute_sequential(g)
    finally:
        ex.close()


# ----------------------------------------------------------- repro-worker

def test_repro_worker_dialed_pool_differential():
    """Acceptance: workers started by the repro-worker CLI (fresh
    interpreters, graph shipped in the welcome frame) match the oracle."""
    g = picklable_dag(3, 50, 0.3)
    seq = execute_sequential(g)
    ex = ClusterExecutor(workers=["remote", "remote"])
    procs = [start_repro_worker(ex.address) for _ in range(2)]
    try:
        assert ex.run(g) == seq
        assert ex.stats["dispatched"] >= 50
    finally:
        for p in procs:
            assert p.wait(timeout=30) == 0      # explicit goodbye, rc 0
        ex.close()


def test_repro_worker_joins_midrun_then_sigkilled():
    """Acceptance: a repro-worker that dials a LIVE run joins elastically;
    SIGKILLing it mid-run is heartbeat/EOF-detected and lineage-recovered
    (the driver sends remote workers a ``die``, here we also kill the os
    process directly)."""
    g = picklable_dag(9, 90, 0.3, slow=True)    # a run long enough to join
    seq = execute_sequential(picklable_dag(9, 90, 0.3))
    ex = ClusterExecutor(workers=["local"], channel="tcp", transport="tcp",
                         fail_worker=(1, 1))
    proc = start_repro_worker(ex.address)
    try:
        res = ex.run(g)
        assert res == seq
        assert ex.stats["joins"] == 1       # the dial became a join
        assert ex.stats["failures"] == 1    # and then we killed it
        rc = proc.wait(timeout=30)
        assert rc != 0                      # died by signal, not goodbye
    finally:
        if proc.poll() is None:
            proc.kill()
        ex.close()


def test_remote_rejects_unpicklable_graph_with_clear_error():
    g = exec_dag(1, 8, 0.4)                 # closures: not picklable
    ex = ClusterExecutor(workers=["remote"], accept_timeout=30.0)
    proc = start_repro_worker(ex.address)
    try:
        with pytest.raises(ValueError, match="not picklable"):
            ex.run(g)
        assert proc.wait(timeout=30) != 0   # worker saw the reject
    finally:
        if proc.poll() is None:
            proc.kill()
        ex.close()


# ------------------------------------------------------ transport matrix

def test_remote_pool_refuses_host_local_transports():
    with pytest.raises(ValueError, match="host-local"):
        ClusterExecutor(workers=["remote"], transport="shm")
    with pytest.raises(ValueError, match="host-local"):
        serde.resolve_transport("sock", multihost=True)
    assert serde.resolve_transport("auto", multihost=True) == "tcp"
    assert serde.resolve_transport("driver", multihost=True) == "driver"


def test_launcher_transport_validation():
    """Satellite: --transport/--channel are validated against what the
    chosen backend supports, with a named error instead of a deep
    KeyError."""
    import argparse

    from repro.launch.backend import add_backend_args, validate_backend_args

    ap = argparse.ArgumentParser()
    add_backend_args(ap)
    ok = ap.parse_args(["--backend", "process", "--transport", "tcp",
                        "--channel", "tcp"])
    validate_backend_args(ok)               # no error
    bad = ap.parse_args(["--backend", "thread", "--transport", "shm"])
    with pytest.raises(SystemExit, match="thread"):
        validate_backend_args(bad)
    bad2 = ap.parse_args(["--backend", "thread", "--channel", "tcp"])
    with pytest.raises(SystemExit, match="channel"):
        validate_backend_args(bad2)
    with pytest.raises(SystemExit):         # argparse rejects unknown names
        ap.parse_args(["--backend", "process", "--transport", "warp"])
    with pytest.raises(ValueError, match="channel"):
        ClusterExecutor(2, channel="quantum")
    with pytest.raises(ValueError, match="remote workers"):
        ClusterExecutor(workers=["remote"], channel="pipe")
    from repro.core import make_executor
    with pytest.raises(ValueError, match="process"):
        make_executor("thread", 2, transport="shm")
    with pytest.raises(ValueError, match="process"):
        make_executor("thread", 2, channel="tcp")


# ----------------------------------------------------- peer-socket hygiene

def test_sweep_peer_sockets_removes_stale_files(tmp_path):
    d = tmp_path / "rrpeerXYZ"
    d.mkdir()
    for i in range(3):
        (d / f"w{i}.sock").write_bytes(b"")
    (d / "straggler.txt").write_text("x")
    assert serde.sweep_peer_sockets(str(d)) == 3
    assert not d.exists()
    assert serde.sweep_peer_sockets(str(d)) == 0    # idempotent


def test_sock_run_leaves_no_peer_dir_even_after_sigkill(monkeypatch):
    """Satellite: the shutdown sweep takes the peer-socket tmpdir with the
    same hygiene as /dev/shm — including sockets of SIGKILL'd workers that
    never ran their own close()."""
    if not hasattr(socket, "AF_UNIX"):
        pytest.skip("no unix sockets here")
    import tempfile as _tf

    made = []
    real = _tf.mkdtemp

    def spy(*a, **kw):
        path = real(*a, **kw)
        made.append(path)
        return path

    monkeypatch.setattr("repro.cluster.executor.tempfile.mkdtemp", spy)
    g = exec_dag(31, 60, 0.3)
    ex = ClusterExecutor(2, transport="sock", shm_threshold=1,
                         fail_worker=(0, 2))
    assert ex.run(g) == execute_sequential(g)
    assert ex.stats["failures"] == 1
    assert made, "sock transport should have made a peer dir"
    for path in made:
        assert not os.path.exists(path), f"peer dir leaked: {path}"


def test_peer_server_binds_over_stale_socket_file(tmp_path):
    stale = tmp_path / "w0.sock"
    srv = serde.PeerServer(str(stale), {0: 123})
    srv.close()
    stale.write_bytes(b"")                   # simulate a leftover file
    srv2 = serde.PeerServer(str(stale), {0: 456})
    got = serde.peer_fetch(serde.PeerRef(str(stale), 0, 8, 0))
    assert got == 456
    srv2.close()


def test_tcp_peer_server_roundtrip():
    store = {7: np.arange(1000, dtype=np.int64)}
    srv = serde.PeerServer(None, store, advertise_host="127.0.0.1")
    assert srv.path.startswith("tcp://")
    ref = serde.PeerRef(srv.path, 7, 8000, 0, secret=srv.secret)
    got = serde.peer_fetch(ref)
    assert np.array_equal(got, store[7])
    with pytest.raises(serde.TransferLost):
        serde.peer_fetch(serde.PeerRef(srv.path, 99, 8, 0,
                                       secret=srv.secret))
    # the capability gate: no secret / a wrong secret gets nothing
    with pytest.raises(serde.TransferLost):
        serde.peer_fetch(serde.PeerRef(srv.path, 7, 8000, 0))
    with pytest.raises(serde.TransferLost):
        serde.peer_fetch(serde.PeerRef(srv.path, 7, 8000, 0,
                                       secret="f" * 32), timeout=3.0)
    srv.close()
    # NOTE: "fetch from a closed server" is asserted via the unix family —
    # some sandboxed-CI loopback stacks fake-accept TCP connects to closed
    # ports, which peer_fetch maps to TransferLost anyway (corrupt stream)
    with pytest.raises(serde.TransferLost):
        serde.peer_fetch(serde.PeerRef("/nonexistent/peer.sock", 7, 8, 0),
                         timeout=2.0)


def test_no_shm_leak_on_tcp_channel(tmp_path):
    if not serde.shm_available():
        pytest.skip("no shared memory in this environment")
    g = exec_dag(41, 60, 0.3)
    ex = ClusterExecutor(2, channel="tcp", transport="shm", shm_threshold=1,
                         fail_worker=(1, 3))
    try:
        assert ex.run(g) == execute_sequential(g)
    finally:
        ex.close()
    assert not glob.glob(f"/dev/shm/{ex.seg_prefix}*")


# -------------------------------------------------- per-host locality

def test_scheduler_worker_host_locality_groups():
    """Same-host workers are near (shm-priced), cross-host ones far
    (TCP-priced): the consumer of a big value whose owner is busy should
    fall to the owner's host-mate, not to the distant idle worker."""
    g = TaskGraph()
    g.add_node("big", lambda: 0, (), {}, TaskKind.PURE, deps=(), cost=1.0)
    g.add_node("use", lambda x: x, (_Ref(0),), {}, TaskKind.PURE,
               deps=[0], cost=1.0)
    g.mark_output(1)
    kw = dict(done={0: 0.0}, placed={0: 1},
              data_sizes={0: 1 << 23}, bandwidth=float(1 << 20),
              worker_speed=[1.0, 0.01, 1.0])    # the owner is very slow
    near = list_schedule(g, 3, worker_host=["A", "B", "B"], **kw)
    assert near.placements[1].worker == 2       # host-mate of the bytes
    far = list_schedule(g, 3, worker_host=["A", "B", "C"], **kw)
    assert far.placements[1].worker == 0        # all moves equally far
    with pytest.raises(ValueError, match="worker_host"):
        list_schedule(g, 3, worker_host=["A", "B"], **kw)


def test_objectstore_tracks_hosts():
    from repro.cluster import DriverObjectStore

    g = exec_dag(2, 4, 0.5)
    store = DriverObjectStore(g)
    store.add_worker(0, host="A")
    store.add_worker(1, host="B")
    store.record(0, 0, nbytes=8)
    assert store.on_host(0, "A") and not store.on_host(0, "B")
    store.record_replica(0, 1)
    assert store.on_host(0, "B")
    store.drop_worker(0)
    assert not store.on_host(0, "A") and store.on_host(0, "B")


# ------------------------------------------------ driver restart (tentpole)

def test_tcp_driver_kill_workers_rejoin_and_resume(tmp_path):
    """Tentpole acceptance over TCP: emulate a driver SIGKILL (raw socket
    teardown, no shutdown niceties), start a NEW executor resuming the
    run — every forked worker survives the outage, re-dials the rebound
    address, and is re-adopted with its object store intact, so the resume
    needs no fresh spawns, no deaths, and no recomputation."""
    from repro.cluster import DriverKilled
    g = exec_dag(31, 150, 0.25, sleep=0.002)
    seq = execute_sequential(exec_dag(31, 150, 0.25))
    ex = ClusterExecutor(3, channel="tcp", checkpoint_dir=str(tmp_path),
                         checkpoint_interval=0.0, fail_driver=40)
    with pytest.raises(DriverKilled):
        ex.run(g)
    assert ex.run_id

    t0 = time.monotonic()
    ex2 = ClusterExecutor(3, channel="tcp", checkpoint_dir=str(tmp_path),
                          resume=ex.run_id, rejoin_timeout=8.0)
    try:
        assert ex2.run(g) == seq
        wall = time.monotonic() - t0
        assert ex2.stats["joins"] == 0 and ex2.stats["failures"] == 0
        assert ex2.stats["resumed_clusters"] > 0
        assert ex2.stats["recomputed"] == 0     # worker stores survived
        # regression: every survivor must rejoin PROMPTLY.  Fork children
        # used to inherit the driver-side accepted sockets of earlier
        # workers, keeping those connections alive past the driver's death
        # — the peers never saw EOF and sat out the whole rejoin window
        assert wall < 6.0, f"rejoin barrier stalled: {wall:.1f}s"
    finally:
        ex2.close()


def test_tcp_resume_worker_lost_in_outage_single_recovery_plan(tmp_path):
    """A worker SIGKILL'd DURING the driver outage: the resumed driver
    reconciles checkpoint claims against rejoin inventories and issues
    exactly ONE recovery plan for the loss (never a second when the
    heartbeat also notices), then backfills the pool to spec."""
    from repro.cluster import DriverKilled
    g = picklable_dag(13, 100, 0.3)
    seq = execute_sequential(g)
    ex = ClusterExecutor(workers=["remote", "remote"],
                         checkpoint_dir=str(tmp_path),
                         checkpoint_interval=0.0, fail_driver=30,
                         accept_timeout=30.0)
    procs = [start_repro_worker(ex.address) for _ in range(2)]
    try:
        with pytest.raises(DriverKilled):
            ex.run(g)
        procs[0].kill()                 # dies while no driver is watching
        procs[0].wait(timeout=10)

        ex2 = ClusterExecutor(workers=["remote", "remote"],
                              checkpoint_dir=str(tmp_path),
                              resume=ex.run_id, rejoin_timeout=4.0)
        try:
            assert ex2.run(g) == seq
            outage = [e for e in ex2.recovery_events
                      if e["worker"] == "driver-outage"]
            assert len(ex2.recovery_events) == len(outage) <= 1
        finally:
            ex2.close()
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait(timeout=10)


def test_repro_driver_real_sigkill_then_resume_latest(tmp_path):
    """The real thing, end to end: a ``repro-driver`` subprocess is
    SIGKILL'd mid-run (no emulation — the OS reaps it), its fork-started
    workers keep running, and a second ``repro-driver --resume latest``
    rebinds the address, re-adopts them, and finishes bit-for-bit."""
    ckpt = str(tmp_path)
    base = [sys.executable, "-m", "repro.launch.driver",
            "--graph", "test_multihost:_dk_slow_graph",
            "--workers", "2", "--checkpoint-dir", ckpt,
            "--checkpoint-interval", "0.05",
            "--out", os.path.join(ckpt, "out.pkl")]
    p = subprocess.Popen(base, env=WORKER_ENV, cwd=REPO,
                         stdout=subprocess.PIPE, text=True)
    try:
        assert "listening" in p.stdout.readline()
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            logs = glob.glob(os.path.join(ckpt, "*.log"))
            if logs and os.path.getsize(logs[0]) > 600:
                break
            time.sleep(0.01)
            assert p.poll() is None, "driver finished before the kill"
        p.send_signal(signal.SIGKILL)
        assert p.wait(timeout=30) != 0
    finally:
        if p.poll() is None:
            p.kill()

    r = subprocess.run(base + ["--resume", "latest"], env=WORKER_ENV,
                       cwd=REPO, capture_output=True, text=True,
                       timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resuming" in r.stdout
    with open(os.path.join(ckpt, "out.pkl"), "rb") as f:
        got = pickle.load(f)
    assert results_equal(got, execute_sequential(picklable_dag(9, 80, 0.3)))


def _dk_slow_graph():
    """Graph builder the driver-kill drill passes to ``repro-driver``:
    slow enough that the SIGKILL reliably lands mid-run."""
    return picklable_dag(9, 80, 0.3, slow=True)


# ----------------------------------------------------- stale-segment sweep

def test_sweep_stale_segments_scoped_to_dead_owners(tmp_path):
    """``repro-worker`` startup sweep: removes ``rr*`` segments whose
    embedded driver pid is dead, keeps live-owner segments and anything
    it cannot attribute."""
    d = str(tmp_path)
    dead = subprocess.Popen([sys.executable, "-c", "pass"])
    dead.wait()
    live_pid, dead_pid = os.getpid(), dead.pid
    names = {
        "stale_worker": f"rr{dead_pid:x}0123abcdw3_7",
        "stale_driver": f"rr{dead_pid:x}0123abcdd_0",
        "stale_bare": f"rr{dead_pid:x}0123abcd",
        "live": f"rr{live_pid:x}0123abcdw0_1",
        "unparseable": "rrnothexatallw0_1",
        "foreign": "somethingelse.bin",
    }
    for n in names.values():
        with open(os.path.join(d, n), "wb") as f:
            f.write(b"x")
    assert serde.sweep_stale_segments(d) == 3
    left = set(os.listdir(d))
    assert left == {names["live"], names["unparseable"], names["foreign"]}
    assert serde.sweep_stale_segments(d) == 0       # idempotent
