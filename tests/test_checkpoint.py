"""Checkpoint store: roundtrip, async, GC, resume, atomicity."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (save_checkpoint, restore_checkpoint,
                                    latest_step, AsyncCheckpointer,
                                    CheckpointManager)


def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 42, tree(), extra={"data_step": 42})
    assert latest_step(d) == 42
    out, extra = restore_checkpoint(d, target=tree())
    assert extra == {"data_step": 42}
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(12.0).reshape(3, 4))
    assert out["params"]["b"].dtype == jnp.bfloat16
    assert int(out["opt"]["step"]) == 7


def test_restore_without_target_returns_flat(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, tree())
    values, _ = restore_checkpoint(d)
    assert any("w" in k for k in values)


def test_restore_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, tree())
    bad = tree()
    bad["params"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        restore_checkpoint(d, target=bad)


def test_latest_and_explicit_step(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30):
        t = tree()
        t["opt"]["step"] = jnp.asarray(s)
        save_checkpoint(d, s, t)
    assert latest_step(d) == 30
    out, _ = restore_checkpoint(d, step=20, target=tree())
    assert int(out["opt"]["step"]) == 20


def test_no_tmp_dirs_left_behind(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, tree())
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_async_checkpointer_and_gc(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=2)
    for s in range(5):
        ck.save(s, tree())
    ck.wait()
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
    assert steps == [3, 4]


def test_async_snapshot_is_immediate(tmp_path):
    """The device->host snapshot happens synchronously: mutating the tree
    after save() must not corrupt the checkpoint."""
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=1)
    t = {"w": np.zeros((256, 256), np.float32)}
    ck.save(0, t)
    t["w"][:] = 999.0          # mutate after snapshot
    ck.wait()
    out, _ = restore_checkpoint(d, target={"w": np.zeros((256, 256),
                                                         np.float32)})
    assert float(out["w"].max()) == 0.0


def test_manager_save_cadence_and_resume(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, every=10, keep=3, async_save=False)
    saved = [s for s in range(35) if mgr.maybe_save(s, tree(), {"s": s})]
    assert saved == [0, 10, 20, 30]
    out, extra = mgr.restore_latest(tree())
    assert extra == {"s": 30}
    mgr.finish()
