"""SPMD behaviour on a multi-device (8 forced host CPU devices) world.

Each test runs in a subprocess because jax pins the device count at first
init — the main pytest process must keep seeing ONE device (assignment
§MULTI-POD DRY-RUN item 0).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + \
    os.environ.get("XLA_FLAGS", "")
import jax
import jax.numpy as jnp
import numpy as np
assert jax.device_count() == 8
"""


def run_script(body: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", HEADER + body],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr[-4000:]}"
    return p.stdout


def test_mesh_executor_matches_sequential():
    run_script("""
from repro.core import (task, trace, placeholder, execute_sequential,
                        MeshExecutor, standard_rules, ValueInfo)
from repro.parallel.mesh import make_mesh_for

@task(cost=1.0)
def gen(seed):
    return jax.random.normal(jax.random.PRNGKey(seed), (64, 64))

@task(cost=2.0)
def mul(a, b):
    return a @ b

@task(cost=1.0)
def add(a, b):
    return a + b

def driver():
    x = placeholder("x")
    a = gen(0); b = gen(1)
    return add(mul(a, x), mul(b, x))

graph, _ = trace(driver)
x = jax.random.normal(jax.random.PRNGKey(9), (64, 64))
seq = execute_sequential(graph, inputs={"x": x})
want = seq[graph.outputs[0]]

mesh = make_mesh_for(8, model_parallel=2)
rules = standard_rules("dp_tp", pod_axis=None)
info = {t: ValueInfo((64, 64), 4, ("batch", "d_model")) for t in graph.nodes}
ex = MeshExecutor(graph, mesh, rules, value_info=info,
                  input_axes={"x": ("batch", "d_model")})
out = ex({"x": x})[0]
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
# introspection used by roofline
assert ex.cost_analysis().get("flops", 0) > 0
assert "fusion" in ex.hlo_text() or "dot" in ex.hlo_text()
print("mesh executor OK")
""")


def test_pipeline_matches_sequential_stack():
    run_script("""
import dataclasses
from repro.configs import get_config
from repro.models import transformer as TF
from repro.parallel.pipeline import split_stages, pipelined_forward
from repro.parallel.mesh import make_mesh_for

cfg = get_config("yi-9b").reduced(n_layers=4, compute_dtype="float32",
                                  param_dtype="float32", remat="none")
params = TF.init_params(cfg, jax.random.PRNGKey(0))
lay = params["layers"]
B, S, d = 8, 16, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

# oracle: sequential scan over the same stacked layers
positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
body = TF._layer_body(cfg, None, use_cache=False, train=True,
                      positions=positions, cache_pos=None,
                      shared_params=None, shared_norm=None)
xs = {"params": lay, "idx": jnp.arange(4)}
(y_ref, aux_ref, _, _), _ = jax.lax.scan(body, (x, jnp.zeros(()), None, None), xs)

mesh = make_mesh_for(8, model_parallel=2, pods=4)   # 4 pipeline stages
sp = split_stages(lay, 4, 4)
fn = pipelined_forward(cfg, mesh, n_microbatch=4, stage_axis="pod")
y, aux = fn(sp, x)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
print("pipeline OK, bubble=", (4-1)/(4+4-1))
""")


def test_dp_gradient_sync_plain_and_compressed():
    run_script("""
from repro.parallel.mesh import make_mesh_for
from repro.parallel.collectives import dp_gradient_sync
from repro.parallel.compression import Int8BlockCompressor
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = make_mesh_for(8, model_parallel=1)
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32)) * 0.01}
# place the leading axis over data: each shard holds a different slice
sh = NamedSharding(mesh, P("data"))
gs = {"w": jax.device_put(g["w"], sh)}

with mesh:
    plain = dp_gradient_sync(gs, mesh, ("data",))
# NB inside shard_map with replicated specs each device sees its full copy;
# pmean over data therefore averages the 8 replicas -> equals mean over axis
want = np.asarray(g["w"])  # replicated value: pmean of identical copies
comp = Int8BlockCompressor(block=64)
with mesh:
    cz = dp_gradient_sync(gs, mesh, ("data",), compressor=comp)
err = np.abs(np.asarray(cz["w"]) - np.asarray(plain["w"])).max()
scale = np.abs(np.asarray(plain["w"])).max()
assert err <= scale / 127.0 + 1e-6, (err, scale)
print("dp sync OK", err)
""")


def test_mesh_collective_helpers_match_dense_references():
    # satellite to the graph-level collectives: the in-mesh shard_map
    # helpers are pinned against dense jnp references so BOTH collective
    # layers (mesh-level and graph-level) have differential coverage
    run_script("""
import functools
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.parallel.collectives import (ring_permute, all_gather_seq,
                                        reduce_scatter, dp_gradient_sync)

mesh = Mesh(np.array(jax.devices()).reshape(8), ("dp",))
sm = functools.partial(shard_map, mesh=mesh)

x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
xs = jax.device_put(x, NamedSharding(mesh, P("dp", None)))

# ring_permute: device i ships its row block to (i+shift)%8, so the
# assembled result is the row blocks rolled forward by `shift`
for shift in (1, 3):
    f = sm(lambda s, k=shift: ring_permute(s, "dp", k),
           in_specs=P("dp", None), out_specs=P("dp", None))
    got = np.asarray(f(xs))
    want = np.asarray(jnp.roll(x, shift, axis=0))
    assert np.array_equal(got, want), (shift, got, want)

# all_gather_seq (tiled, dim=1): every device ends up holding the full
# concatenation of the row blocks along columns
f = sm(lambda s: all_gather_seq(s, "dp", dim=1),
       in_specs=P("dp", None), out_specs=P("dp", None))
got = np.asarray(f(xs))          # (8, 32): row j = device j's gathered copy
flat = np.asarray(x).reshape(-1)
for j in range(8):
    assert np.array_equal(got[j], flat), j

# reduce_scatter (tiled, dim=1) over row shards: the total column sum,
# scattered so device j keeps column block j
w = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
ws = jax.device_put(w, NamedSharding(mesh, P("dp", None)))
f = sm(lambda s: reduce_scatter(s, "dp", dim=1),
       in_specs=P("dp", None), out_specs=P(None, "dp"))
got = np.asarray(f(ws))
want = np.asarray(w.sum(axis=0, keepdims=True))
assert np.allclose(got, want), (got, want)

# reduce_scatter default dim=0 on a replicated operand: psum of the 8
# identical copies, scattered back over rows -> 8 * w
f = sm(lambda s: reduce_scatter(s, "dp"),
       in_specs=P(None, None), out_specs=P("dp", None))
wr = jax.device_put(w, NamedSharding(mesh, P(None, None)))
got = np.asarray(f(wr))
assert np.allclose(got, 8.0 * np.asarray(w)), got

# dp_gradient_sync is the identity when no mesh axis matches
g = {"w": x}
assert dp_gradient_sync(g, mesh, ("tensor",)) is g

print("mesh collective helpers OK")
""")


def test_fit_sharding_drops_nondivisible_axes():
    run_script("""
from repro.launch.steps import _fit_sharding
from repro.parallel.mesh import make_mesh_for
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = make_mesh_for(8, model_parallel=8)   # model axis = 8
ok = jax.ShapeDtypeStruct((1024, 16), jnp.float32)
bad = jax.ShapeDtypeStruct((51865, 16), jnp.float32)   # whisper vocab
sh = NamedSharding(mesh, P("model", None))
assert _fit_sharding(ok, sh).spec == P("model")
assert _fit_sharding(bad, sh).spec == P()
print("fit sharding OK")
""")


def test_production_mesh_in_512_device_world():
    """make_production_mesh(single & multi) under the dry-run device count."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert m2.shape == {"pod": 2, "data": 16, "model": 16}
assert m2.size == 512
print("meshes OK")
"""
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-2000:]
