"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes and finiteness.
(The FULL configs are exercised via the dry-run only.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.models import frontends
from repro.optim import AdamW

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _smoke_lm(cfg):
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pe = (frontends.synth_patches(cfg, B) if cfg.family == "vlm" else None)
    logits, _, aux = TF.forward(params, toks, cfg, patch_embeds=pe)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt = AdamW(lr=1e-3)
    step = jax.jit(TF.make_train_step(cfg, opt))
    batch = {"tokens": toks, "labels": toks}
    if pe is not None:
        batch["patch_embeds"] = pe
    params2, _, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))), jax.tree.map(
            lambda a, b: (a - b).astype(jnp.float32), params, params2), 0.0)
    assert delta > 0


def _smoke_encdec(cfg):
    params = ED.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    frames = frontends.synth_frames(cfg, B)
    loss, m = ED.make_loss_fn(cfg)(params,
                                   {"frames": frames, "tokens": toks,
                                    "labels": toks})
    assert np.isfinite(float(loss))
    last, cache = jax.jit(ED.make_prefill_step(cfg, max_len=S + 2))(
        params, toks, frames)
    assert last.shape == (B, cfg.vocab_size)
    l2, _ = jax.jit(ED.make_decode_step(cfg))(
        params, cache, jnp.argmax(last, -1)[:, None])
    assert np.isfinite(np.asarray(l2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    cfg = get_config(arch)
    # exact full config sanity: field values match the assignment
    assert cfg.name == arch
    red = cfg.reduced()
    assert red.family == cfg.family
    assert red.layer_plan[0] == cfg.layer_plan[0].split("+")[0] or True
    if cfg.is_encoder_decoder:
        _smoke_encdec(red)
    else:
        _smoke_lm(red)


def test_full_config_values_match_assignment():
    c = get_config("qwen3-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 40, 8, 17408, 151936)
    assert c.qk_norm
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    assert c.shared_attn_every == 6
    c = get_config("dbrx-132b")
    assert (c.n_experts, c.experts_per_token) == (16, 4)
    c = get_config("llama4-maverick-400b-a17b")
    assert (c.n_experts, c.experts_per_token, c.vocab_size) == (128, 1, 202048)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (64, 4096, 16)
    assert set(c.layer_plan) == {"mamba1"}
    c = get_config("granite-20b")
    assert c.n_kv_heads == 1
    c = get_config("qwen2-7b")
    assert c.qkv_bias
    c = get_config("whisper-tiny")
    assert c.is_encoder_decoder and c.n_enc_layers == 4
    c = get_config("llava-next-34b")
    assert c.family == "vlm" and c.n_patches > 0
    c = get_config("yi-9b")
    assert (c.n_heads, c.n_kv_heads) == (32, 4)


def test_param_counts_in_expected_range():
    """count_params on FULL configs (eval_shape only — no allocation)."""
    expect = {                      # (low, high) in billions
        "qwen3-14b": (12, 17),
        "yi-9b": (8, 10),
        "qwen2-7b": (6.5, 8.5),
        "granite-20b": (18, 23),
        "falcon-mamba-7b": (6, 8.5),
        "dbrx-132b": (115, 145),
        "llama4-maverick-400b-a17b": (360, 440),
        "llava-next-34b": (30, 38),
        "zamba2-7b": (6, 9),
    }
    for arch, (lo, hi) in expect.items():
        n = TF.count_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"
    n = TF.count_params(get_config("whisper-tiny"),) if False else None
