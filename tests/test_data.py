"""Data pipeline: (step, host) determinism, shard disjointness, prefetch,
IO-task ordering."""
import numpy as np

from repro.core import trace, execute_sequential, TaskKind
from repro.data.pipeline import (SyntheticLMDataset, Prefetcher,
                                 make_data_source)


def test_batch_at_deterministic_and_step_addressed():
    ds = SyntheticLMDataset(1000, 16, 8, seed=3)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (8, 16)
    assert a["tokens"].dtype == np.int32
    # labels are next-token shifted views of the same stream
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_tokens_in_vocab_range():
    ds = SyntheticLMDataset(100, 32, 4)
    b = ds.batch_at(0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 100


def test_host_shards_differ_and_partition_batch():
    n_hosts = 4
    shards = [SyntheticLMDataset(1000, 16, 16, n_hosts=n_hosts, host_id=h,
                                 seed=1).batch_at(3) for h in range(n_hosts)]
    assert all(s["tokens"].shape == (4, 16) for s in shards)
    for i in range(n_hosts):
        for j in range(i + 1, n_hosts):
            assert not np.array_equal(shards[i]["tokens"],
                                      shards[j]["tokens"])


def test_prefetcher_yields_in_order_and_resumes():
    ds = SyntheticLMDataset(1000, 8, 4, seed=2)
    pf = Prefetcher(ds, start_step=10, depth=2)
    try:
        b10 = pf.next()
        b11 = pf.next()
        np.testing.assert_array_equal(b10["tokens"], ds.batch_at(10)["tokens"])
        np.testing.assert_array_equal(b11["tokens"], ds.batch_at(11)["tokens"])
        assert pf.step == 12        # checkpointable cursor
    finally:
        pf.close()
    # resume from the cursor reproduces the continuation exactly
    pf2 = Prefetcher(ds, start_step=12, depth=2)
    try:
        b12 = pf2.next()
        np.testing.assert_array_equal(b12["tokens"], ds.batch_at(12)["tokens"])
    finally:
        pf2.close()


def test_data_source_is_effectful_and_ordered():
    ds = SyntheticLMDataset(1000, 8, 4)
    load = make_data_source(ds)

    def driver():
        return load(), load(), load()

    g, _ = trace(driver)
    nodes = list(g)
    assert all(n.kind is TaskKind.EFFECTFUL for n in nodes)
    # RealWorld chain: each load token-depends on the previous
    assert nodes[1].token_deps == (nodes[0].tid,)
    assert nodes[2].token_deps == (nodes[1].tid,)
    res = execute_sequential(g)
    np.testing.assert_array_equal(res[0]["tokens"], ds.batch_at(0)["tokens"])
    np.testing.assert_array_equal(res[2]["tokens"], ds.batch_at(2)["tokens"])
