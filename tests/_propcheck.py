"""Property-testing front-end: real ``hypothesis`` when installed, otherwise
a tiny derandomized fallback with the same decorator surface.

The suites import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly, so tier-1 runs on a bare container (no hypothesis)
and still gets shrinking + fuzzing wherever hypothesis *is* available.

The fallback draws ``max_examples`` pseudo-random examples from each strategy
with a seed derived from the test name — deterministic across runs, different
across tests.  Only the strategy combinators the suites actually use are
implemented (``integers``, ``floats``, ``sampled_from``, ``tuples``,
``booleans``, ``lists``); extend as tests grow.

``REPRO_PROP_EXAMPLES_SCALE`` multiplies every suite's ``max_examples``
(both real-hypothesis and fallback paths) — the nightly CI workflow sets
it to fuzz far past the PR-latency budget without the suites hardcoding
two budgets.
"""
from __future__ import annotations

import functools
import os
import random
import zlib

_EXAMPLES_SCALE = float(os.environ.get("REPRO_PROP_EXAMPLES_SCALE", "1") or 1)


def _scaled(n: int) -> int:
    return max(1, int(n * _EXAMPLES_SCALE))


try:
    from hypothesis import given, strategies as st  # noqa: F401
    from hypothesis import settings as _hyp_settings
    HAVE_HYPOTHESIS = True

    def settings(max_examples: int = 25, **kw):
        return _hyp_settings(max_examples=_scaled(max_examples), **kw)
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        __slots__ = ("_draw",)

        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        """Namespace mimicking ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda r: r.choice(items))

        @staticmethod
        def tuples(*ss):
            return _Strategy(
                lambda r: tuple(s.example_from(r) for s in ss))

        @staticmethod
        def lists(elem, min_size=0, max_size=8):
            return _Strategy(
                lambda r: [elem.example_from(r)
                           for _ in range(r.randint(min_size, max_size))])

    st = _St()

    def settings(max_examples: int = 25, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = _scaled(max_examples)
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(fn, "_max_examples", 25)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = tuple(s.example_from(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            # pytest must see a zero-arg signature, not the wrapped one —
            # otherwise it tries to resolve the drawn params as fixtures
            del runner.__wrapped__
            runner.hypothesis_fallback = True
            return runner
        return deco
