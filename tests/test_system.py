"""End-to-end behaviour of the paper's system: the exact example from §2
(clean_files / complex_evaluation / semantic_analysis) plus the matrix
workload from §4, traced → scheduled → executed in parallel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (task, io_task, trace, execute_sequential,
                        ThreadedExecutor, simulate, list_schedule,
                        theoretical_speedup, TaskKind)


# ---- the paper's §2 example, transliterated -------------------------------

EFFECT_LOG = []


@io_task(cost=2.0)
def clean_files():
    EFFECT_LOG.append("clean_files")
    return jnp.arange(8.0)          # "Summary"


@task(cost=5.0)
def complex_evaluation(x):
    return int(jnp.sum(x))


@io_task(cost=2.0)
def semantic_analysis():
    EFFECT_LOG.append("semantic_analysis")
    return 42


def paper_main():
    x = clean_files()
    y = complex_evaluation(x)
    z = semantic_analysis()
    return y, z


def test_paper_example_dependency_graph():
    graph, (y, z) = trace(paper_main)
    # 3 tasks; complex_evaluation depends on clean_files;
    # semantic_analysis is token-ordered after clean_files (RealWorld edge)
    assert len(graph) == 3
    nodes = {n.name: n for n in graph}
    ce = nodes["complex_evaluation"]
    sa = nodes["semantic_analysis"]
    cf = nodes["clean_files"]
    assert cf.tid in ce.deps
    assert cf.tid in sa.token_deps        # RealWorld threading
    assert ce.kind is TaskKind.PURE
    assert sa.kind is TaskKind.EFFECTFUL
    # "once clean_files is done, both complex_evaluation and
    # semantic_analysis can be scheduled"
    sched = list_schedule(graph, 2)
    sched.validate_against(graph)
    p = sched.placements
    assert p[ce.tid].start >= p[cf.tid].end
    assert p[sa.tid].start >= p[cf.tid].end
    # and they can overlap on 2 workers
    assert (p[ce.tid].start < p[sa.tid].end
            and p[sa.tid].start < p[ce.tid].end)


def test_paper_example_execution_matches_and_orders_effects():
    EFFECT_LOG.clear()
    graph, _ = trace(paper_main)
    seq = execute_sequential(graph)
    log_seq = list(EFFECT_LOG)

    EFFECT_LOG.clear()
    par = ThreadedExecutor(4).run(graph)
    log_par = list(EFFECT_LOG)

    assert log_seq == log_par == ["clean_files", "semantic_analysis"]
    for t in graph.outputs:
        a, b = seq[t], par[t]
        assert np.asarray(a).tolist() == np.asarray(b).tolist()


# ---- the paper's §4 workload: matrix generation + multiplication ----------

def matrix_driver(n_tasks: int, size: int):
    @task(cost=1.0, name="gen")
    def gen(seed):
        return jax.random.normal(jax.random.PRNGKey(seed), (size, size))

    @task(cost=2.0, name="mul")
    def mul(a, b):
        return a @ b

    @task(cost=0.5, name="reduce")
    def red(*xs):
        return sum(jnp.sum(x) for x in xs)

    outs = []
    for i in range(n_tasks):
        a = gen(2 * i)
        b = gen(2 * i + 1)
        outs.append(mul(a, b))
    return red(*outs)


def test_matrix_workload_parallel_equals_sequential():
    graph, _ = trace(matrix_driver, 6, 32)
    assert len(graph) == 6 * 3 + 1
    seq = execute_sequential(graph)
    ex = ThreadedExecutor(4)
    par = ex.run(graph)
    out = graph.outputs[0]
    np.testing.assert_allclose(float(seq[out]), float(par[out]), rtol=1e-5)


def test_matrix_workload_scales_in_simulation():
    """The Fig. 2 claim: makespan falls ~linearly with workers until the
    dependency structure runs out (Brent bound)."""
    graph, _ = trace(matrix_driver, 16, 8)
    m1 = simulate(graph, 1).makespan
    m4 = simulate(graph, 4).makespan
    m16 = simulate(graph, 16).makespan
    assert m1 == pytest.approx(graph.total_work())
    assert m4 < m1 / 2.5                        # decent scaling at 4
    assert m16 <= m4                            # monotone
    assert m16 >= graph.critical_path_length() - 1e-9   # Brent lower bound
    assert m1 / m16 <= theoretical_speedup(graph, 16) + 1e-9
