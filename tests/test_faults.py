"""Deterministic fault injection: the FaultPlan/RetryPolicy layer and the
partition-aware degradation it exercises.

Every cluster test here is a differential against ``execute_sequential`` —
the injection layer may reorder, duplicate, stall, or sever, but results
must stay bit-for-bit and (where the owner stays alive) ``recomputed``
must stay 0.  See ``docs/faults.md`` for the fault model.
"""
import pickle
import random
import time

import pytest

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor
from repro.faults import FaultPlan, FaultRule, RetryPolicy, scaled


# --------------------------------------------------------------- graphs

def exec_dag(seed: int, n: int, p: float) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def shuffle_graph(producers: int = 4, consumers: int = 8,
                  payload: int = 256) -> TaskGraph:
    """Producers emit byte payloads big enough to ride the data plane
    (``shm_threshold`` in the tests is set below ``payload``), a strided
    shuffle forces cross-worker fetches, a reduce checks every byte."""
    g = TaskGraph()
    for i in range(producers):
        def produce(_i=i, _n=payload):
            return bytes((_i * 31 + k) % 251 for k in range(_n))
        g.add_node(f"p{i}", produce, (), {}, TaskKind.PURE,
                   deps=(), cost=1.0)
    for j in range(consumers):
        deps = [j % producers, (j + 1) % producers]

        def combine(a, b, _j=j):
            return bytes((x + y + _j) % 251 for x, y in zip(a, b))

        g.add_node(f"c{j}", combine, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=1.0)
    rdeps = list(range(producers, producers + consumers))

    def reduce_all(*xs):
        return sum(sum(x) for x in xs)

    g.add_node("reduce", reduce_all, tuple(_Ref(d) for d in rdeps), {},
               TaskKind.PURE, deps=rdeps, cost=1.0)
    g.mark_output(producers + consumers)
    return g


def two_chains(length: int = 6, sleep: float = 0.05) -> TaskGraph:
    """Two independent chains so both workers hold sole copies of live
    values — the partition tests need the severed worker to matter."""
    g = TaskGraph()
    tid = 0
    tails = []
    for c in range(2):
        prev = None
        for i in range(length):
            deps = [prev] if prev is not None else []

            def fn(*xs, _c=c, _i=i, _s=sleep):
                time.sleep(_s)
                return (_c * 1000 + _i + sum(xs) * 3) % 1_000_003

            g.add_node(f"c{c}t{i}", fn, tuple(_Ref(d) for d in deps), {},
                       TaskKind.PURE, deps=deps, cost=1.0)
            prev = tid
            tid += 1
        tails.append(prev)

    def join(a, b):
        return a * 7 + b

    g.add_node("join", join, (_Ref(tails[0]), _Ref(tails[1])), {},
               TaskKind.PURE, deps=tails, cost=1.0)
    g.mark_output(tid)
    return g


# ----------------------------------------------------------- unit: plan

def test_fault_rule_validation():
    with pytest.raises(ValueError):
        FaultRule("teleport")
    with pytest.raises(ValueError):
        FaultRule("drop", nth=0)
    with pytest.raises(ValueError):
        FaultRule("drop", prob=1.5)


def test_fault_plan_nth_addressing_is_deterministic():
    plan = FaultPlan(seed=1).drop(src=1, dst="driver", verb="done", nth=2)
    fired = [bool(plan.frame_actions(1, "driver", "done"))
             for _ in range(4)]
    assert fired == [False, True, False, False]   # nth=2 fires exactly once
    # a different link keeps its own counter
    assert not plan.frame_actions(2, "driver", "done")


def test_fault_plan_prob_stream_is_seeded():
    def firing_pattern(seed):
        plan = FaultPlan(seed=seed).drop(verb="hb", prob=0.5)
        return [bool(plan.frame_actions(1, "driver", "hb"))
                for _ in range(32)]

    assert firing_pattern(7) == firing_pattern(7)
    assert firing_pattern(7) != firing_pattern(8)


def test_fault_plan_pickles_description_not_counters():
    plan = FaultPlan(seed=3).drop(verb="done", nth=1)
    assert plan.frame_actions(1, 2, "done")       # consume the firing
    clone = pickle.loads(pickle.dumps(plan))
    assert clone.rules == plan.rules
    assert clone.frame_actions(1, 2, "done")      # counters restarted
    assert not plan.frame_actions(1, 2, "done")   # original stays spent


def test_fault_plan_sever_window_is_symmetric():
    plan = FaultPlan(seed=0).sever(window=0.2, src=1, verb="done", nth=1)
    assert plan.frame_actions(1, "driver", "done")
    assert plan.severed(1, "driver") is not None
    assert plan.severed("driver", 1) is not None   # both directions
    time.sleep(0.25)
    assert plan.severed(1, "driver") is None       # window expired


def test_scaled_plan_clamps_and_preserves_nth():
    plan = FaultPlan(seed=5).drop(verb="hb", prob=0.4).delay(
        0.01, nth=3, verb="done")
    hot = scaled(plan, 10.0)
    assert hot.rules[0].prob == 1.0               # clamped
    assert hot.rules[1].nth == 3                  # exact rules untouched


# ---------------------------------------------------------- unit: retry

def test_retry_policy_backoff_is_bounded_and_seeded():
    pol = RetryPolicy(attempts=5, base_delay=0.1, factor=2.0,
                      max_delay=0.3, jitter=0.0)
    delays = [pol.backoff(i) for i in range(4)]   # 0-based attempts
    assert delays == [0.1, 0.2, 0.3, 0.3]         # capped at max_delay
    jit = RetryPolicy(attempts=5, base_delay=0.1, jitter=0.5)
    rng = random.Random(9)
    assert all(0.1 <= jit.backoff(0, rng=rng) <= 0.15 for _ in range(20))


def test_retry_policy_run_retries_then_raises():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        raise OSError("nope")

    pol = RetryPolicy(attempts=3, base_delay=0.001, jitter=0.0)
    with pytest.raises(OSError):
        pol.run(flaky, retryable=lambda e: isinstance(e, OSError))
    assert calls == [0, 1, 2]

    calls.clear()
    with pytest.raises(OSError):     # non-retryable: no second attempt
        pol.run(flaky, retryable=lambda e: False)
    assert calls == [0]


def test_retry_policy_deadline_cuts_attempts_short():
    pol = RetryPolicy(attempts=50, base_delay=0.05, factor=1.0,
                      jitter=0.0, deadline=0.12)
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        raise OSError("nope")

    t0 = time.perf_counter()
    with pytest.raises(OSError):
        pol.run(flaky, retryable=lambda e: True)
    assert time.perf_counter() - t0 < 1.0
    assert 1 <= len(calls) < 50


# ----------------------------------------- differential: fault matrix

def _plan_for(fault: str) -> FaultPlan:
    if fault == "drop":
        # keepalives only: control verbs assume TCP's reliable-or-dead
        # contract, so dropping them would model a fault TCP can't produce
        return FaultPlan(seed=11).drop(verb="hb", prob=0.5)
    if fault == "delay":
        return FaultPlan(seed=12).delay(0.02, prob=0.3)
    if fault == "dup":
        return FaultPlan(seed=13).duplicate(prob=0.3)
    if fault == "reorder":
        return FaultPlan(seed=14).reorder(prob=0.3)
    if fault == "sever":
        return FaultPlan(seed=15).sever(window=0.3, src=1, verb="done",
                                        nth=1)
    if fault == "fail_fetch":
        return FaultPlan(seed=16).fail_fetch(nth=1)
    raise AssertionError(fault)


@pytest.mark.parametrize("channel", ["pipe", "tcp"])
@pytest.mark.parametrize("fault", ["drop", "delay", "dup", "reorder",
                                   "sever", "fail_fetch"])
def test_fault_matrix_differential(channel, fault):
    """Every fault class, both control channels, bit-for-bit vs the
    sequential oracle — the in-tree version of the chaos smoke."""
    g = shuffle_graph()
    seq = execute_sequential(g)
    kw = dict(fault_plan=_plan_for(fault), transport="sock",
              shm_threshold=64,
              fetch_retry=RetryPolicy(attempts=3, base_delay=0.01,
                                      jitter=0.0))
    if channel == "tcp":
        kw.update(channel="tcp", heartbeat_interval=0.1,
                  heartbeat_timeout=1.0, suspect_grace=5.0)
    ex = ClusterExecutor(2, **kw)
    assert ex.run(g) == seq
    assert ex.stats["failures"] == 0


def test_combined_plan_differential_pipe_and_tcp():
    """All fault classes at once — the worst single plan still converges."""
    g = exec_dag(21, 60, 0.3)
    seq = execute_sequential(g)
    for channel in ("pipe", "tcp"):
        plan = (FaultPlan(seed=99)
                .drop(verb="hb", prob=0.4)
                .delay(0.01, prob=0.2)
                .duplicate(prob=0.2)
                .reorder(prob=0.2)
                .sever(window=0.3, src=1, verb="done", nth=2))
        kw = dict(fault_plan=plan)
        if channel == "tcp":
            kw.update(channel="tcp", heartbeat_interval=0.1,
                      heartbeat_timeout=1.0, suspect_grace=5.0)
        ex = ClusterExecutor(2, **kw)
        assert ex.run(g) == seq


# ------------------------------------------- degradation: flaky fetches

def test_persistent_fetch_faults_fall_back_to_relay():
    """Owner alive + retries exhausted => driver-relay fallback, NOT
    lineage recompute: ``deplost`` re-queues must prefer the relay."""
    g = shuffle_graph(producers=4, consumers=8)
    seq = execute_sequential(g)
    plan = FaultPlan(seed=31).fail_fetch()        # every attempt fails
    ex = ClusterExecutor(2, fault_plan=plan, transport="sock",
                         shm_threshold=64,
                         fetch_retry=RetryPolicy(attempts=2,
                                                 base_delay=0.01,
                                                 jitter=0.0))
    assert ex.run(g) == seq
    assert ex.stats["relay_fallbacks"] >= 1
    assert ex.stats["recomputed"] == 0            # owner never died
    assert ex.stats["deplosts"] >= 1
    # the driver-side plan object never fires fetch rules itself: the
    # hook runs on each worker's own (forked/pickled) copy
    assert plan.stats() == {}


def test_same_value_lost_twice_in_a_row():
    """The same value hitting ``TransferLost`` twice (several consumers
    racing on a dead data plane) must stay idempotent in the driver:
    one relay handle, no double recovery, bit-for-bit result."""
    g = shuffle_graph(producers=2, consumers=12)
    seq = execute_sequential(g)
    plan = FaultPlan(seed=32).fail_fetch()
    ex = ClusterExecutor(3, fault_plan=plan, transport="sock",
                         shm_threshold=64,
                         fetch_retry=RetryPolicy(attempts=2,
                                                 base_delay=0.01,
                                                 jitter=0.0))
    assert ex.run(g) == seq
    assert ex.stats["recomputed"] == 0
    # with 12 consumers over 2 producers on a 3-worker pool, several
    # in-flight super-tasks lose the same producer value back to back;
    # the second deplost must find the relay handle already in place
    assert ex.stats["deplosts"] >= 2
    assert ex.stats["failures"] == 0


# --------------------------------------- degradation: timed partitions

def test_timed_partition_heals_without_recompute():
    """Acceptance: a live worker partitioned past the heartbeat timeout
    but inside ``suspect_grace`` is suspected, drained, healed, and its
    in-flight work reconciled — ``recomputed == 0``."""
    g = two_chains(length=6, sleep=0.05)
    seq = execute_sequential(g)
    plan = FaultPlan(seed=41).sever(window=1.2, src=1, verb="done", nth=2)
    ex = ClusterExecutor(2, channel="tcp", fault_plan=plan,
                         heartbeat_interval=0.1, heartbeat_timeout=0.4,
                         suspect_grace=5.0)
    assert ex.run(g) == seq
    assert ex.stats["recomputed"] == 0
    assert ex.stats["suspected"] >= 1
    assert ex.stats["healed"] >= 1
    assert ex.stats["failures"] == 0


def test_partition_past_grace_escalates_to_recovery():
    """The other side of the policy: a partition longer than the grace is
    a death — lineage recovery still finishes the run bit-for-bit."""
    g = two_chains(length=6, sleep=0.05)
    seq = execute_sequential(g)
    plan = FaultPlan(seed=42).sever(window=8.0, src=1, verb="done", nth=2)
    ex = ClusterExecutor(2, channel="tcp", fault_plan=plan,
                         heartbeat_interval=0.1, heartbeat_timeout=0.3,
                         suspect_grace=0.5, progress_timeout=60.0)
    assert ex.run(g) == seq
    assert ex.stats["failures"] >= 1              # escalated to death
    assert ex.stats["recomputed"] >= 1            # lineage replayed


def test_quarantine_probe_readmit_round_trip():
    """Repeated suspect-then-heal episodes quarantine a flaky worker;
    ``probe_interval`` of healthy channel re-admits it."""
    g = two_chains(length=26, sleep=0.1)
    seq = execute_sequential(g)
    plan = (FaultPlan(seed=43)
            .sever(window=0.5, src=1, dst="driver", verb="hb", nth=1)
            .sever(window=0.5, src=1, dst="driver", verb="hb", nth=25))
    ex = ClusterExecutor(3, channel="tcp", fault_plan=plan,
                         heartbeat_interval=0.05, heartbeat_timeout=0.2,
                         suspect_grace=10.0, quarantine_after=2,
                         probe_interval=0.3)
    assert ex.run(g) == seq
    assert ex.stats["recomputed"] == 0
    assert ex.stats["healed"] >= 2
    assert ex.stats["quarantined"] >= 1
    assert ex.stats["readmitted"] >= 1
    assert ex.stats["failures"] == 0


# ------------------------------------------------- simulator modeling

def wide_graph(n: int = 24) -> TaskGraph:
    """Independent unit tasks, ALL outputs — so a false death's lost
    values are values somebody still needs (the phantom-recovery term)."""
    g = TaskGraph()
    for i in range(n):
        def fn(_i=i):
            return _i + 1
        g.add_node(f"t{i}", fn, (), {}, TaskKind.PURE, deps=(), cost=1.0)
        g.mark_output(i)
    return g


def test_sim_partition_heals_inside_grace():
    from repro.core.simulator import WorkerEvent, simulate
    g = wide_graph()              # wide: every worker holds sole copies
    res = simulate(g, 3, events=[WorkerEvent(2.0, "partition", 1, 3.0)],
                   suspect_grace=5.0, seed=7)
    assert res.n_suspected == 1 and res.n_healed == 1
    assert res.n_false_deaths == 0 and res.n_recomputed == 0


def test_sim_partition_past_grace_is_false_death():
    from repro.core.simulator import WorkerEvent, simulate
    g = wide_graph()
    res = simulate(g, 3, events=[WorkerEvent(2.0, "partition", 1, 20.0)],
                   suspect_grace=5.0, seed=7)
    assert res.n_false_deaths == 1
    assert res.n_recomputed >= 1   # phantom recovery: the waste term
    res2 = simulate(g, 3, events=[WorkerEvent(2.0, "partition", 1, 20.0)],
                    suspect_grace=5.0, seed=7)
    assert res.makespan == res2.makespan and res.timeline == res2.timeline


def test_sim_search_suspect_grace():
    from repro.core.simulator import WorkerEvent, search_suspect_grace
    g = exec_dag(9, 30, 0.3)
    ev = [WorkerEvent(3.0, "partition", 0, 4.0)]
    best, results = search_suspect_grace(g, 2, [0.5, 2.0, 8.0], events=ev,
                                         seed=3)
    assert set(results) == {0.5, 2.0, 8.0}
    assert best in results
    assert results[8.0].n_healed == 1             # grace > outage: heals
    assert results[0.5].n_false_deaths == 1       # grace < outage: phantom
    with pytest.raises(ValueError):
        search_suspect_grace(g, 2, [], events=ev)


def test_phantom_recovery_cost_matches_cluster_plan():
    from repro.core.fusion import fuse
    from repro.core.lineage import (phantom_recovery_cost,
                                    recovery_plan_clusters)
    g = exec_dag(17, 40, 0.3)
    plan = fuse(g, "off")
    values = set(g.nodes)
    suspect = {5, 11, 23}
    cost = phantom_recovery_cost(plan, suspect, values)
    assert cost == recovery_plan_clusters(plan, suspect, values - suspect)
    assert cost   # losing live values is never free on this DAG


# ------------------------------------------------- shm lease (PR-7 fix)

# a pid in the kernel's valid range (< 2**22) that cannot exist: pids
# this high require pid_max raised to its ceiling AND full saturation
_GHOST_PID_HEX = f"{(1 << 22) - 1:x}"


def _seg(tmp_path, uuid8):
    """A bare run segment name as the executor mints them:
    ``rr<driver-pid:x><uuid8>``."""
    p = tmp_path / f"rr{_GHOST_PID_HEX}{uuid8}"
    p.write_bytes(b"x")
    return p, f"rr{_GHOST_PID_HEX}{uuid8}"


def test_sweep_respects_resume_lease(tmp_path):
    from repro.cluster import serde
    d = str(tmp_path)
    dead, _ = _seg(tmp_path, "aaaaaaaa")
    leased, leased_prefix = _seg(tmp_path, "bbbbbbbb")
    serde.write_resume_lease(leased_prefix, "run1", window=30.0,
                             shm_dir=d)
    expired, expired_prefix = _seg(tmp_path, "cccccccc")
    serde.write_resume_lease(expired_prefix, "run2", window=-120.0,
                             shm_dir=d)

    serde.sweep_stale_segments(d)
    assert not dead.exists()          # dead pid, no lease: swept
    assert leased.exists()            # live lease: protected
    assert not expired.exists()       # expired lease: reaped + swept
    assert not (tmp_path / f".rrlease-{expired_prefix}").exists()
    serde.clear_resume_lease(leased_prefix, shm_dir=d)
    assert not (tmp_path / f".rrlease-{leased_prefix}").exists()


def test_sweep_ignores_foreign_hex_names(tmp_path):
    """A foreign all-hex file name used to parse to a pid above the OS
    maximum and blow up ``os.kill`` with OverflowError."""
    from repro.cluster import serde
    foreign = tmp_path / ("rr" + "f" * 24)
    foreign.write_bytes(b"x")
    serde.sweep_stale_segments(str(tmp_path))      # must not raise
    assert foreign.exists()           # unparseable owner: left alone
