"""Threaded executor vs sequential oracle, effect ordering, lineage."""
import random
import threading

import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (task, io_task, trace, execute_sequential,
                        ThreadedExecutor, TaskGraph, TaskKind,
                        recovery_plan, recover, lineage_depth,
                        NonIdempotentReplay, checkpoint_barrier)
from repro.core.tracing import RemappedRef as _Ref


def exec_dag(seed: int, n: int, p: float) -> TaskGraph:
    """Random dag whose nodes do real (cheap, deterministic) arithmetic."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return _i + sum(xs) * 7 % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


@given(st.integers(0, 5000), st.integers(2, 40), st.floats(0.0, 0.5),
       st.integers(2, 8))
@settings(max_examples=40, deadline=None)
def test_threaded_matches_sequential(seed, n, p, workers):
    g = exec_dag(seed, n, p)
    seq = execute_sequential(g)
    par = ThreadedExecutor(workers).run(g)
    assert seq == par


def test_failure_injection_recovers_and_matches():
    g = exec_dag(123, 30, 0.3)
    seq = execute_sequential(g)
    failed = set()

    def fail_some(worker, tid):
        if tid % 7 == 3 and tid not in failed:
            failed.add(tid)
            return True
        return False

    ex = ThreadedExecutor(4, fail_task=fail_some)
    par = ex.run(g)
    assert par == seq
    assert ex.stats["recomputed"] >= len(failed) > 0


def test_io_tasks_serialized_under_concurrency():
    lock = threading.Lock()
    seen = []

    @io_task(cost=0.0)
    def io_step(i):
        with lock:
            seen.append(i)
        return i

    @task(cost=0.0)
    def work(i):
        return i * i

    def driver():
        outs = []
        for i in range(10):
            outs.append(io_step(i))
            outs.append(work(i))
        return outs

    graph, _ = trace(driver)
    for _ in range(3):
        seen.clear()
        ThreadedExecutor(6).run(graph)
        assert seen == list(range(10))       # program order, always


# ---------------------------------------------------------------- lineage

def chain_graph(k: int) -> TaskGraph:
    g = TaskGraph()
    prev = None
    for i in range(k):
        deps = [prev] if prev is not None else []
        g.add_node(f"c{i}", (lambda x=0: x + 1) if prev is None
                   else (lambda x: x + 1), (_Ref(prev),) if prev is not None
                   else (), {}, TaskKind.PURE, deps=deps)
        prev = i
    g.mark_output(k - 1)
    return g


def test_recovery_plan_minimal_on_chain():
    g = chain_graph(10)
    all_results = set(range(10))
    # lose the tail only -> recompute just the tail
    assert recovery_plan(g, {9}, all_results - {9}) == {9}
    # lose 5 with 0..4 available -> recompute 5 only
    assert recovery_plan(g, {5}, {0, 1, 2, 3, 4}) == {5}
    # lose 5 with nothing available -> recompute 0..5
    assert recovery_plan(g, {5}, set()) == {0, 1, 2, 3, 4, 5}


def test_recover_executes_and_restores_values():
    g = chain_graph(6)
    res = execute_sequential(g)
    want = dict(res)
    plan = recover(g, [3, 4], res)
    assert plan == {3, 4}
    assert res == want


def test_barrier_cuts_lineage():
    @task(cost=1.0)
    def inc(x):
        return x + 1

    def driver():
        a = inc(0)
        b = inc(a)
        cp = checkpoint_barrier(b)
        c = inc(cp)
        return inc(c)

    g, _ = trace(driver)
    res = execute_sequential(g)
    barrier_tid = next(n.tid for n in g if n.kind is TaskKind.BARRIER)
    final = g.outputs[0]
    # losing everything after the barrier never recomputes before it
    plan = recovery_plan(g, {final}, {barrier_tid})
    assert all(t > barrier_tid for t in plan)
    assert lineage_depth(g, final, set(res)) == 1


def test_non_idempotent_io_refuses_replay():
    @io_task(cost=1.0)
    def send_email():
        return "sent"

    @io_task(cost=1.0, meta={"idempotent": True})
    def write_log():
        return "logged"

    g, _ = trace(lambda: (send_email(), write_log()))
    email_tid, log_tid = 0, 1
    with pytest.raises(NonIdempotentReplay):
        recovery_plan(g, {email_tid}, set(), allow_effect_replay=False)
    # idempotent IO is fine
    assert recovery_plan(g, {log_tid}, {email_tid},
                         allow_effect_replay=False) == {log_tid}
