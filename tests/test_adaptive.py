"""Profile-guided adaptive replanning (docs/adaptive.md).

Three contracts under test:

1. **Scale-invariance** — every adaptive decision consumes ratios of
   measured seconds, so uniformly rescaling time (a faster machine)
   changes no decision: corrected costs, calibrated fusion gates, skew,
   the re-fusion trigger, and the derived speculation threshold.
2. **Determinism** — a fixed recorded trace replayed through the
   simulator yields bit-identical replanning decisions; re-fusion plan
   surgery preserves the member partition and the cluster-DAG shape.
3. **Agreement** — the simulator's trigger model and the live executor
   run the same ``CostModel``/``RefuseGovernor`` predicate, so they
   agree about whether re-fusion fires on a workload, and the live
   adaptive run stays bit-for-bit equal to ``execute_sequential``
   (healthy and across a driver SIGKILL + resume replaying the
   journaled re-fusions).
"""
import random
import time
import types

import pytest
from _propcheck import given, settings, st

from repro.config import ClusterConfig
from repro.cluster import ClusterExecutor, DriverKilled
from repro.core import TaskGraph, TaskKind, execute_sequential, simulate
from repro.core.adaptive import (MAX_REFUSIONS, MIN_FRONTIER, MIN_OBS,
                                 CostModel, RefuseGovernor, RunTrace,
                                 fn_key, refusion_due)
from repro.core.fusion import fuse, refuse_frontier, splice_plan
from repro.core.simulator import (WorkerEvent, search_policy,
                                  search_collective_arity,
                                  search_suspect_grace)
from repro.core.tracing import RemappedRef as _Ref


# ------------------------------------------------------------ workloads

def heavy_fn(x, s):
    time.sleep(s)
    return x * 3 + 1


def cheap_fn(x, s):
    time.sleep(s)
    return x + 1


def comb(*xs):
    return sum(int(x) for x in xs) % 1_000_003


def lopsided(width=24, n_heavy=6, heavy_s=0.05, cheap_s=0.001,
             miscosted=True) -> TaskGraph:
    """Two wide epochs pinched through dual-gate reductions; the first
    ``n_heavy`` tasks per epoch sleep ~50x longer than the rest while
    (when ``miscosted``) declaring the same ``cost=1.0`` — epoch 1 is
    calibration data, epoch 2 the re-fusable frontier.  The dual gates
    give every layer task two consumers so single-consumer contraction
    cannot absorb the layers (same shape as benchmarks/bench_adaptive)."""
    hc = 1.0 if miscosted else heavy_s / cheap_s
    g = TaskGraph()

    def layer(dep):
        tids = []
        for i in range(width):
            heavy = i < n_heavy
            t = len(g.nodes)
            fn = heavy_fn if heavy else cheap_fn
            s = heavy_s if heavy else cheap_s
            g.add_node(f"w{t}", fn,
                       (_Ref(dep), s) if dep is not None else (i, s), {},
                       TaskKind.PURE,
                       deps=[dep] if dep is not None else [],
                       cost=hc if heavy else 1.0)
            tids.append(t)
        return tids

    def gatepair(tids):
        a = g.add_node("ga", comb, tuple(_Ref(t) for t in tids), {},
                       TaskKind.PURE, deps=tids, cost=1.0)
        b = g.add_node("gb", comb, tuple(_Ref(t) for t in tids), {},
                       TaskKind.PURE, deps=tids, cost=1.0)
        return g.add_node("gc", comb, (_Ref(a), _Ref(b)), {},
                          TaskKind.PURE, deps=[a, b], cost=1.0)

    g.mark_output(gatepair(layer(gatepair(layer(None)))))
    return g


def sim_dag(seed: int, n: int = 40, p: float = 0.2) -> TaskGraph:
    """Fn-less random DAG for pure simulator sweeps."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-4:]
        g.add_node(f"t{i}", None, (), {}, TaskKind.PURE, deps=deps,
                   cost=rng.uniform(0.1, 4.0))
    g.mark_output(n - 1)
    return g


def _adaptive_cfg(**kw) -> ClusterConfig:
    return ClusterConfig(n_workers=kw.pop("n_workers", 4), channel="pipe",
                         fuse="auto", adaptive="auto",
                         progress_timeout=120.0, **kw)


# ------------------------------------------- property: scale invariance

def _tmpl_a(x):
    return x


def _tmpl_b(x):
    return x


@given(st.tuples(st.integers(0, 10_000), st.sampled_from(
    [1e-3, 0.1, 3.0, 250.0, 1e4])))
@settings(max_examples=30, deadline=None)
def test_cost_model_decisions_are_scale_invariant(params):
    """Feeding the same run with all wall clocks multiplied by k changes
    no decision: corrected units, calibrated gates (in units), skew, cv,
    the derived speculation threshold, and every re-fusion verdict."""
    seed, k = params
    rng = random.Random(seed)
    a, b = CostModel(), CostModel()
    gov_a, gov_b = RefuseGovernor(), RefuseGovernor()
    node_a = types.SimpleNamespace(cost=2.0, fn=_tmpl_a)
    node_b = types.SimpleNamespace(cost=0.7, fn=_tmpl_b)
    for i in range(1, 25):
        units = rng.uniform(0.5, 4.0)
        wall = rng.uniform(0.002, 0.05) * (40.0 if rng.random() < 0.25
                                           else 1.0)
        key = rng.choice([fn_key(node_a), fn_key(node_b), None])
        a.observe(units, wall, fn_units=((key, units),))
        b.observe(units, wall * k, fn_units=((key, units),))
        a.observe_dispatch(0.0004 * i, i)
        b.observe_dispatch(0.0004 * i * k, i)

        assert a.skew() == pytest.approx(b.skew())
        assert a.cv() == pytest.approx(b.cv())
        for node in (node_a, node_b):
            assert a.corrected_units(node) == pytest.approx(
                b.corrected_units(node))
        assert a.fuse_gates(30.0, 6.0) == pytest.approx(
            b.fuse_gates(30.0, 6.0))
        da = a.derived_speculate_after()
        db = b.derived_speculate_after()
        assert (da is None) == (db is None)
        if da is not None:
            assert da == pytest.approx(db)

        n_frontier = rng.randint(0, 12)
        fire_a = refusion_due(a, gov_a, n_frontier)
        assert fire_a == refusion_due(b, gov_b, n_frontier)
        if fire_a:
            gov_a.note_fired(a)
            gov_b.note_fired(b)
    assert gov_a.fired == gov_b.fired <= MAX_REFUSIONS


def test_governor_hysteresis_and_caps():
    """No decision before MIN_OBS fresh observations, no fire below
    MIN_FRONTIER, window reset after a fire, hard cap at MAX_REFUSIONS."""
    m, gov = CostModel(), RefuseGovernor()
    for _ in range(MIN_OBS - 1):
        m.observe(1.0, 0.001)
    m.observe(1.0, 1.0)                       # one huge outlier
    assert not refusion_due(m, gov, MIN_FRONTIER - 1)   # frontier too small
    assert refusion_due(m, gov, MIN_FRONTIER)
    gov.note_fired(m)
    # the outlier is *history* now: the fresh window must re-earn a fire
    assert not refusion_due(m, gov, 10)
    fires = 1
    while fires < MAX_REFUSIONS + 2:
        for _ in range(MIN_OBS - 1):
            m.observe(1.0, 0.001)
        m.observe(1.0, 1.0)
        if refusion_due(m, gov, 10):
            gov.note_fired(m)
            fires += 1
        else:
            break
    assert gov.fired == fires == MAX_REFUSIONS


# --------------------------------- property: plan surgery is structure-safe

@given(st.tuples(st.integers(0, 5_000), st.integers(2, 6)))
@settings(max_examples=20, deadline=None)
def test_refuse_frontier_splice_preserves_partition(params):
    """Re-fusing the full frontier under a different parallelism floor
    must keep the member partition exact (every task in exactly one
    cluster), keep the cluster DAG acyclic/valid, and report consumer
    deltas that reconcile the old and new consumer indexes."""
    seed, kp = params
    g = sim_dag(seed, n=50, p=0.25)
    plan = fuse(g, "auto", keep_parallelism=8)
    old_consumers = {v: len(cs) for v, cs in plan.consumers.items()}
    frontier = sorted(plan.cgraph.nodes)        # nothing dispatched yet
    out = refuse_frontier(plan, frontier, keep_parallelism=kp,
                          cost_of=lambda n: n.cost * 3.0)
    if out is None:                              # partition unchanged
        return
    retired, new_clusters = out
    delta = splice_plan(plan, retired, new_clusters)
    # exact partition of the task set
    seen = [m for ms in plan.members.values() for m in ms]
    assert sorted(seen) == sorted(g.nodes)
    assert set(plan.members) == set(plan.cgraph.nodes)
    for cid, ms in plan.members.items():
        for m in ms:
            assert plan.cluster_of[m] == cid
    plan.cgraph.validate()
    assert plan.cgraph.topo_order()              # acyclic, deps present
    # consumer-index delta reconciles old -> new
    new_consumers = {v: len(cs) for v, cs in plan.consumers.items()}
    for v in set(old_consumers) | set(new_consumers) | set(delta):
        assert (old_consumers.get(v, 0) + delta.get(v, 0)
                == new_consumers.get(v, 0)), v


# ------------------------------------ determinism: trace-driven simulator

def _fixed_trace(g: TaskGraph, skewed: bool) -> RunTrace:
    """Honest trace: member seconds proportional to declared cost (ratio
    constant -> no skew).  Skewed trace: every 7th task runs ~100x its
    proportional share."""
    tasks = {t: g.nodes[t].cost * (0.4 if skewed and t % 7 == 0
                                   else 0.004)
             for t in g.nodes}
    return RunTrace(tasks=tasks, n_workers=4, unit_s=0.01,
                    dispatch_s=0.0004)


def test_fixed_trace_gives_deterministic_replan_decisions():
    g1, g2 = sim_dag(11, n=60), sim_dag(11, n=60)
    tr = _fixed_trace(g1, skewed=True)
    kw = dict(fuse="auto", adaptive="auto", trace=tr,
              dispatch_overhead=tr.dispatch_s)
    r1 = simulate(g1, 4, **kw)
    r2 = simulate(g2, 4, **kw)
    assert r1.makespan == r2.makespan
    assert r1.refusions == r2.refusions >= 1
    assert r1.refusion_times == r2.refusion_times
    # honest costs, uniform durations: the governor must stay quiet
    quiet = simulate(sim_dag(11, n=60), 4, fuse="auto", adaptive="auto",
                     trace=_fixed_trace(g1, skewed=False),
                     dispatch_overhead=0.0004)
    assert quiet.refusions == 0


def test_run_trace_roundtrip(tmp_path):
    g = sim_dag(3, n=12)
    tr = _fixed_trace(g, skewed=True)
    path = str(tmp_path / "trace.json")
    tr.save(path)
    back = RunTrace.load(path)
    assert back == tr
    members = sorted(g.nodes)[:5]
    assert back.cluster_seconds(members, g.nodes) == pytest.approx(
        tr.cluster_seconds(members, g.nodes))


# --------------------------------------- live executor <-> sim agreement

def test_adaptive_refuses_midrun_and_matches_oracle_and_sim_agrees():
    """The tentpole differential: on the mis-costed lopsided workload the
    live adaptive run must re-fuse mid-run, stay bit-for-bit equal to the
    sequential oracle, and the simulator fed the recorded trace must
    agree that re-fusion fires."""
    g = lopsided()
    seq = execute_sequential(lopsided())
    ex = ClusterExecutor(config=_adaptive_cfg())
    got = ex.run(g)
    ex.close()
    assert got == seq
    assert ex.stats["refusions"] >= 1
    assert ex.stats["replan_triggers"] >= 1
    assert ex.stats["cost_unit_s"] > 0
    assert ex.stats["adaptive_skew"] > 4.0
    trace = ex.last_trace
    assert trace is not None and trace.unit_s > 0
    res = simulate(lopsided(), 4, fuse="auto", adaptive="auto",
                   trace=trace, dispatch_overhead=trace.dispatch_s)
    assert res.refusions >= 1


def test_adaptive_stays_quiet_when_costs_are_honest():
    """Well-costed control: honest hints -> balanced static plan -> the
    governor must not fire, and results still match the oracle."""
    g = lopsided(miscosted=False)
    seq = execute_sequential(lopsided(miscosted=False))
    ex = ClusterExecutor(config=_adaptive_cfg())
    got = ex.run(g)
    ex.close()
    assert got == seq
    assert ex.stats["refusions"] == 0


def test_resume_replays_journaled_refusions(tmp_path):
    """Kill the driver after re-fusion fired; the resumed incarnation
    must replay the journaled splices (refusions_replayed) before
    adopting done-claims, and finish bit-for-bit."""
    g = lopsided()
    seq = execute_sequential(lopsided())
    ex = ClusterExecutor(config=_adaptive_cfg(
        checkpoint_dir=str(tmp_path), checkpoint_interval=0.0,
        fail_driver=14))
    with pytest.raises(DriverKilled):
        ex.run(g)
    ex.close()
    assert ex.stats["refusions"] >= 1
    ex2 = ClusterExecutor(config=_adaptive_cfg(
        checkpoint_dir=str(tmp_path), checkpoint_interval=0.0,
        resume=ex.run_id))
    got = ex2.run(lopsided())
    ex2.close()
    assert got == seq
    assert ex2.stats["refusions_replayed"] >= 1


def test_static_knobs_override_derivation():
    """Explicit --keep-parallelism/--speculate-after always win over the
    adaptive derivation: the derived threshold is never engaged and the
    pinned floor shapes the plan exactly as static fusion would."""
    g = lopsided(n_heavy=0, width=16)            # uniform: no refusion
    static_clusters = len(fuse(lopsided(n_heavy=0, width=16), "auto",
                               keep_parallelism=6).cgraph.nodes)
    ex = ClusterExecutor(config=_adaptive_cfg(
        keep_parallelism=6, speculate_after=5.0))
    got = ex.run(g)
    ex.close()
    assert got == execute_sequential(lopsided(n_heavy=0, width=16))
    assert ex.stats["n_clusters"] == static_clusters
    assert ex.stats["adaptive_speculate_after"] == 0.0   # never derived


# ------------------------------------------------- offline search front door

def test_search_policy_wrappers_are_equivalent():
    g = sim_dag(21, n=50)
    ev = [WorkerEvent(time=2.0, kind="partition", worker=0, factor=4.0)]
    b1, r1 = search_suspect_grace(g, 3, [0.5, 2.0, 8.0], events=ev)
    b2, r2 = search_policy("suspect_grace", g, 3, [0.5, 2.0, 8.0],
                           events=ev)
    assert b1 == b2
    assert {c: r.makespan for c, r in r1.items()} == \
        {c: r.makespan for c, r in r2.items()}
    b3, _ = search_collective_arity(g, 3, [2, 4])
    b4, _ = search_policy("collective_arity", g, 3, [2, 4])
    assert b3 == b4


def test_search_policy_knobs_and_errors():
    g = sim_dag(5, n=40)
    tr = _fixed_trace(g, skewed=True)
    for knob, grid in (("speculate_after", [1.5, 4.0]),
                       ("keep_parallelism", [2, 8]),
                       ("fanin_cost", [1.0, 30.0]),
                       ("group_cost", [1.0, 6.0])):
        best, results = search_policy(knob, g, 4, grid, trace=tr)
        assert best in grid and set(results) == set(grid)
        assert all(r.makespan > 0 for r in results.values())
    with pytest.raises(ValueError, match="unknown policy knob"):
        search_policy("nope", g, 4, [1])
    with pytest.raises(ValueError, match="need at least one candidate"):
        search_policy("speculate_after", g, 4, [])
    with pytest.raises(ValueError, match="partition events"):
        search_policy("suspect_grace", g, 4, [1.0])
