"""Model correctness beyond smoke: prefill+decode == full forward, VLM
frontend stitching, MoE routing invariants, zamba2 shared-block caching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.models import frontends

KEY = jax.random.PRNGKey(7)
B, S = 2, 12


def _decode_consistency(cfg, atol=2e-2):
    """last-token logits from (prefill S-1 tokens, decode 1 token) must match
    the full-sequence forward — the KV/SSM cache path against the oracle."""
    cfg = cfg.reduced(compute_dtype="float32", param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    full_logits, _, _ = TF.forward(params, toks, cfg)
    want = np.asarray(full_logits[:, -1, :], np.float32)

    prefill = TF.make_prefill_step(cfg, max_len=S + 4)
    decode = TF.make_decode_step(cfg)
    _, cache = prefill(params, toks[:, :-1])
    got, cache = decode(params, cache, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=1e-3, atol=atol)
    assert int(cache["pos"]) == S


@pytest.mark.parametrize("arch", ["qwen3-14b", "yi-9b", "granite-20b"])
def test_decode_matches_forward_dense(arch):
    _decode_consistency(get_config(arch))


def test_decode_matches_forward_ssm():
    _decode_consistency(get_config("falcon-mamba-7b"))


def test_decode_matches_forward_hybrid_shared_attn():
    cfg = get_config("zamba2-7b").reduced(
        n_layers=4, shared_attn_every=2,
        compute_dtype="float32", param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = TF.forward(params, toks, cfg)
    _, cache = TF.make_prefill_step(cfg, max_len=S + 4)(params, toks[:, :-1])
    got, _ = TF.make_decode_step(cfg)(params, cache, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full_logits[:, -1, :], np.float32),
                               rtol=1e-3, atol=2e-2)


def test_decode_matches_forward_moe():
    # capacity_factor=E makes the dispatch provably dropless, so decode and
    # full forward must agree EXACTLY (capacity drops are group-composition
    # dependent by design — GShard semantics — and would differ otherwise)
    cfg = get_config("dbrx-132b")
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    _decode_consistency(cfg)


def test_moe_capacity_drops_are_bounded():
    """With the published capacity factor, the share of dropped tokens on a
    random router stays modest (sanity on the ceil-capacity formula)."""
    cfg = get_config("dbrx-132b").reduced(compute_dtype="float32",
                                          param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    logits, _, _ = TF.forward(params, toks, cfg)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_multi_step_decode_progression():
    """Decoding token-by-token tracks the full-forward logits at each step."""
    cfg = get_config("yi-9b").reduced(compute_dtype="float32",
                                      param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    seq = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    _, cache = TF.make_prefill_step(cfg, max_len=16)(params, seq[:, :4])
    decode = TF.make_decode_step(cfg)
    for t in range(4, 8):
        got, cache = decode(params, cache, seq[:, t:t + 1])
        full, _, _ = TF.forward(params, seq[:, :t + 1], cfg)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(full[:, -1, :], np.float32), rtol=1e-3, atol=2e-2)


def test_vlm_patch_embeds_override_prefix():
    cfg = get_config("llava-next-34b").reduced(n_patches=4)
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pe1 = frontends.synth_patches(cfg, B)
    pe2 = pe1 + 1.0
    l1, _, _ = TF.forward(params, toks, cfg, patch_embeds=pe1)
    l2, _, _ = TF.forward(params, toks, cfg, patch_embeds=pe2)
    # prefix change must propagate (causal: all positions >= 0 see patches)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


def test_moe_aux_loss_and_balance():
    cfg = get_config("dbrx-132b").reduced()
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    _, _, aux = TF.forward(params, toks, cfg, train=True)
    assert np.isfinite(float(aux)) and float(aux) >= 0.0


def test_encdec_decode_matches_forward():
    cfg = get_config("whisper-tiny").reduced(
        compute_dtype="float32", param_dtype="float32")
    params = ED.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    frames = frontends.synth_frames(cfg, B)
    enc = ED.encode(params, frames, cfg)
    xkv = ED.cross_kv(params, enc, cfg)
    full, _ = ED.decoder_forward(params, toks, xkv, cfg)
    _, cache = ED.make_prefill_step(cfg, max_len=S + 2)(
        params, toks[:, :-1], frames)
    got, _ = ED.make_decode_step(cfg)(params, cache, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full[:, -1, :], np.float32),
                               rtol=1e-3, atol=2e-2)


def test_grouped_gqa_matches_repeat():
    """gqa_grouped=True (repeat-free einsum, §Perf cell B) is numerically
    identical to the repeat_kv reference, incl. causal + kv_len masking."""
    from repro.models.layers import attention_scores
    ks = jax.random.split(KEY, 3)
    Bb, H, KH, S, D = 2, 8, 2, 32, 16
    q = jax.random.normal(ks[0], (Bb, S, H, D))
    k = jax.random.normal(ks[1], (Bb, S, KH, D))
    v = jax.random.normal(ks[2], (Bb, S, KH, D))
    qpos = jnp.tile(jnp.arange(S)[None], (Bb, 1))
    for kwargs in ({"causal": False}, {"causal": True},
                   {"causal": True, "q_pos": qpos,
                    "kv_len": jnp.array([20, 8])}):
        a = attention_scores(q, k, v, **kwargs)
        b = attention_scores(q, k, v, grouped=True, **kwargs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_ssd_bf16_close_to_f32():
    """ssd_bf16 (§Perf cell C) stays close to the f32 SSD path."""
    import dataclasses
    cfg = get_config("zamba2-7b").reduced(compute_dtype="float32",
                                          param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    l32, _, _ = TF.forward(params, toks, cfg)
    lbf, _, _ = TF.forward(params, toks,
                           dataclasses.replace(cfg, ssd_bf16=True))
    np.testing.assert_allclose(np.asarray(l32, np.float32),
                               np.asarray(lbf, np.float32),
                               rtol=0.1, atol=0.15)


def test_int8_kv_cache_decode_close_to_bf16():
    """kv_cache_dtype='int8' tracks the full-precision decode closely
    (per-token-head symmetric quantization, §Perf cell B follow-up)."""
    import dataclasses
    cfg = get_config("yi-9b").reduced(compute_dtype="float32",
                                      param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _, _ = TF.forward(params, toks, cfg)
    want = np.asarray(full[:, -1, :], np.float32)

    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    _, cache = TF.make_prefill_step(cfg8, max_len=S + 4)(params, toks[:, :-1])
    assert cache["layers"]["k"].dtype == jnp.int8
    got, cache = TF.make_decode_step(cfg8)(params, cache, toks[:, -1:])
    assert int(cache["pos"]) == S
    # int8 KV error is small relative to logit scale
    err = np.abs(np.asarray(got, np.float32) - want)
    assert err.max() < 0.15 * max(np.abs(want).max(), 1.0), err.max()


def test_unrolled_matches_scanned():
    """scan_layers=False (dry-run cost probes) is numerically identical."""
    import dataclasses
    cfg = get_config("qwen2-7b").reduced(compute_dtype="float32",
                                         param_dtype="float32")
    params = TF.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    l_scan, _, _ = TF.forward(params, toks, cfg)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    l_unroll, _, _ = TF.forward(params, toks, cfg_u)
    np.testing.assert_allclose(np.asarray(l_scan, np.float32),
                               np.asarray(l_unroll, np.float32),
                               rtol=1e-5, atol=1e-5)
