"""Graph-level collectives: tracing, tree lowering, scheduling price,
cluster execution, and failure recovery.

The contract under test everywhere: a collective node is its own dense
point-to-point fallback (``execute_sequential`` and ``collectives="off"``
run the node's fn), and :func:`lower_collectives` replaces it with staged
tree hops that compute the **same bits** — ``tree_fold``'s bracketing is
part of the value, so float non-associativity cannot tell the two apart.
"""
import argparse

import numpy as np
import pytest

from repro.core import (TaskGraph, TaskKind, execute_sequential,
                        ThreadedExecutor, task, trace,
                        all_reduce, gather, broadcast, scatter)
from repro.core.collectives import (DEFAULT_ARITY, add_all_reduce,
                                    add_broadcast, add_gather, add_scatter,
                                    collective_stages, lower_collectives,
                                    parse_collectives_spec, resolve_op,
                                    tree_depth, tree_fold, _chunk_bounds)
from repro.core.fusion import fuse as fuse_graph
from repro.core.lineage import recovery_plan_clusters
from repro.core.scheduler import collective_comm_cost
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor


# ----------------------------------------------------------------- helpers

def same(got, want):
    """Bit-for-bit dict equality that understands arrays and tuples."""
    assert got.keys() == want.keys()
    for k in want:
        a, b = got[k], want[k]
        if isinstance(a, tuple) and isinstance(b, tuple):
            assert len(a) == len(b), k
            for x, y in zip(a, b):
                _same_value(x, y, k)
        else:
            _same_value(a, b, k)


def _same_value(a, b, k):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        assert (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                and a.dtype == b.dtype and np.array_equal(a, b)), k
    else:
        assert a == b, k


def producers_graph(n, elems=64):
    """n float32 producers whose sums are order-sensitive in float32."""
    g = TaskGraph()
    tids = []
    for i in range(n):
        def p(_i=i, _n=elems):
            # irrational-ish scale: float32 addition order changes bits
            return (np.arange(1, _n + 1, dtype=np.float32)
                    * np.float32(0.1 + 0.7 * _i))
        tids.append(g.add_node(f"p{i}", p, (), {}, TaskKind.PURE,
                               deps=(), out_bytes=elems * 4))
    return g, tids


def lowered_results(g, spec="auto"):
    """Sequential results of the lowered graph, keyed by ORIGINAL tid."""
    low, o2n = lower_collectives(g, spec)
    res = execute_sequential(low)
    if o2n is None:
        return res
    return {old: res[new] for old, new in o2n.items()}


# ------------------------------------------------------------- unit: spec

def test_parse_collectives_spec():
    assert parse_collectives_spec(None) == "off"
    assert parse_collectives_spec(False) == "off"
    assert parse_collectives_spec("off") == "off"
    assert parse_collectives_spec("none") == "off"
    assert parse_collectives_spec(True) == "auto"
    assert parse_collectives_spec("auto") == "auto"
    assert parse_collectives_spec(3) == 3
    assert parse_collectives_spec(" 8 ") == 8
    for bad in (1, 0, -2, "1", "junk", 2.5):
        with pytest.raises(ValueError):
            parse_collectives_spec(bad)


def test_resolve_op():
    for name in ("sum", "max", "min", "concat"):
        got_name, fn = resolve_op(name)
        assert got_name == name and callable(fn)
    name, fn = resolve_op(lambda a, b: a * b)
    assert callable(fn)
    with pytest.raises(ValueError):
        resolve_op("median")


def test_tree_fold_and_depth():
    for n in (1, 2, 3, 5, 9, 17):
        vals = list(range(1, n + 1))
        for arity in (2, 3, 4):
            assert tree_fold(vals, lambda a, b: a + b, arity) == sum(vals)
            d = tree_depth(n, arity)
            assert d >= 0
            # depth is the number of non-root levels the lowering emits
            m, want = n, 0
            while m > arity:
                m = -(-m // arity)
                want += 1
            assert d == want
    with pytest.raises(ValueError):
        tree_fold([], lambda a, b: a + b, 2)


def test_tree_fold_bracketing_is_its_own_semantics():
    """float32 sums depend on bracketing: the tree fold and the naive
    left fold genuinely differ on this data, which is exactly why the
    lowered stages must reproduce tree_fold and not 'a sum'."""
    rng = np.random.RandomState(7)
    vals = [rng.randn(257).astype(np.float32) * (10.0 ** (i % 7 - 3))
            for i in range(17)]
    _, add = resolve_op("sum")
    tree = tree_fold(vals, add, 2)
    flat = vals[0]
    for v in vals[1:]:
        flat = flat + v
    assert not np.array_equal(tree, flat)   # non-associativity is real
    again = tree_fold(list(vals), add, 2)
    assert np.array_equal(tree, again)      # but the tree is deterministic


def test_chunk_bounds_match_array_split():
    for length in (0, 1, 7, 12, 13):
        for n in (1, 2, 3, 5):
            x = np.arange(length)
            want = [a.tolist() for a in np.array_split(x, n)]
            got = [x[a:b].tolist() for a, b in _chunk_bounds(length, n)]
            assert got == want, (length, n)


# ------------------------------------------- lowering: bit-equality sweep

@pytest.mark.parametrize("arity", [2, 3, 4])
@pytest.mark.parametrize("n", [1, 2, 3, 5, 9, 17])
def test_all_reduce_lowering_bit_equal(n, arity):
    g, tids = producers_graph(n)
    ar = add_all_reduce(g, tids, "sum", arity=arity, out_bytes=64 * 4)
    g.mark_output(ar)
    dense = execute_sequential(g)
    low = lowered_results(g)
    _same_value(low[ar], dense[ar], ("all_reduce", n, arity))
    # an integer spec must NOT reshape a reduction in executor mode —
    # the traced bracketing IS the value, so the override stays bit-equal
    low3 = lowered_results(g, spec=3)
    _same_value(low3[ar], dense[ar], ("override", n, arity))


def test_sim_mode_reshapes_reduce_trees_executor_mode_does_not():
    g, tids = producers_graph(17)
    ar = add_all_reduce(g, tids, "sum", arity=2, out_bytes=64 * 4)
    g.mark_output(ar)
    exec_low, _ = lower_collectives(g, 8)
    sim_low, _ = lower_collectives(g, 8, reshape_reductions=True)
    arity2, _ = lower_collectives(g, "auto")
    # executor mode keeps the traced arity-2 tree under a spec of 8 ...
    assert len(exec_low.nodes) == len(arity2.nodes)
    # ... while the simulator's reshape really is an arity-8 tree
    assert len(sim_low.nodes) < len(exec_low.nodes)


def test_gather_lowering_preserves_order():
    for n in (1, 2, 5, 9):
        g, tids = producers_graph(n)
        gt = add_gather(g, tids, arity=2, out_bytes=64 * 4 * n)
        g.mark_output(gt)
        dense = execute_sequential(g)
        low = lowered_results(g)
        assert isinstance(low[gt], tuple) and len(low[gt]) == n
        for a, b in zip(low[gt], dense[gt]):
            assert np.array_equal(a, b)


def test_broadcast_copy_tree_rewires_consumers():
    g, tids = producers_graph(3)
    ar = add_all_reduce(g, tids, "sum", arity=2, out_bytes=64 * 4)
    bc = add_broadcast(g, ar, arity=2, out_bytes=64 * 4)
    cons = []
    for j in range(10):
        def c(x, _j=j):
            return float((x * np.float32(_j + 1)).sum())
        cons.append(g.add_node(f"c{j}", c, (_Ref(bc),), {}, TaskKind.PURE,
                               deps=(bc,)))
    for t in cons:
        g.mark_output(t)
    dense = execute_sequential(g)
    low, o2n = lower_collectives(g, "auto")
    copies = collective_stages(low, bc)
    assert copies, "10 consumers over arity 2 must grow a copy tree"
    # each consumer reads a copy node, never the root; <= arity consumers
    # per copy
    root_new = o2n[bc]
    fanout = {}
    for t in cons:
        (dep,) = low.nodes[o2n[t]].deps
        assert dep != root_new
        assert dep in copies
        fanout[dep] = fanout.get(dep, 0) + 1
    assert all(k <= 2 for k in fanout.values())
    res = execute_sequential(low)
    for t in cons:
        assert res[o2n[t]] == dense[t]


def test_scatter_projections_become_direct_chunk_reads():
    @task(cost=1.0)
    def seed():
        return np.arange(13, dtype=np.float32) * np.float32(1.7)

    @task(cost=1.0)
    def consume(part, j):
        return float(part.sum()) + j

    def driver():
        x = seed()
        parts = scatter(x, 4, arity=4)
        return [consume(parts[i], i) for i in range(4)]

    g, _ = trace(driver)
    dense = execute_sequential(g)
    low, o2n = lower_collectives(g, "auto")
    # the lowered graph reads chunks straight off the source: no node
    # depends on the dense scatter tuple any more
    scatter_new = [o2n[t] for t, n in g.nodes.items()
                   if n.meta.get("collective", {}).get("op") == "scatter"]
    (sc,) = scatter_new
    assert all(sc not in n.deps for n in low.nodes.values())
    res = execute_sequential(low)
    for old, new in o2n.items():
        if old in g.outputs:
            assert res[new] == dense[old]
    # uneven split: chunk sizes follow np.array_split (4+3+3+3)
    chunks = [res[o2n[t]] for t, n in g.nodes.items()
              if n.kind is TaskKind.PROJECTION]
    assert sorted(len(c) for c in chunks) == [3, 3, 3, 4]


def test_lowering_identity_when_off_or_collective_free():
    g, tids = producers_graph(3)
    ar = add_all_reduce(g, tids, "sum", arity=2)
    g.mark_output(ar)
    same_g, o2n = lower_collectives(g, "off")
    assert same_g is g and o2n is None
    g2, _ = producers_graph(3)
    same_g2, o2n2 = lower_collectives(g2, "auto")
    assert same_g2 is g2 and o2n2 is None


def test_lowering_is_deterministic():
    def build():
        g, tids = producers_graph(9)
        ar = add_all_reduce(g, tids, "sum", arity=2, out_bytes=64 * 4)
        bc = add_broadcast(g, ar, arity=2, out_bytes=64 * 4)
        for j in range(6):
            def c(x, _j=j):
                return float(x.sum()) * (_j + 1)
            g.add_node(f"c{j}", c, (_Ref(bc),), {}, TaskKind.PURE,
                       deps=(bc,))
        g.mark_output(ar)
        return g

    a, _ = lower_collectives(build(), "auto")
    b, _ = lower_collectives(build(), "auto")
    assert [(t, n.name, n.kind.value, n.deps, n.cost)
            for t, n in sorted(a.nodes.items())] == \
           [(t, n.name, n.kind.value, n.deps, n.cost)
            for t, n in sorted(b.nodes.items())]


def test_duplicate_ref_participates_twice():
    g, tids = producers_graph(2)
    # a + a + b: the same ref twice must fold twice, like the dense fn
    ar = add_all_reduce(g, [tids[0], tids[0], tids[1]], "sum", arity=2)
    g.mark_output(ar)
    dense = execute_sequential(g)
    low = lowered_results(g)
    _same_value(low[ar], dense[ar], "dup-ref")


def test_collective_stages_are_singleton_fusion_clusters():
    g, tids = producers_graph(9)
    ar = add_all_reduce(g, tids, "sum", arity=2, out_bytes=64 * 4)
    g.mark_output(ar)
    low, o2n = lower_collectives(g, "auto")
    plan = fuse_graph(low, "auto")
    for t in collective_stages(low, ar) + [o2n[ar]]:
        assert plan.members[plan.cluster_of[t]] == (t,), \
            "collective hops must stay their own super-task"


# ----------------------------------------------------- tracing-level API

def test_traced_collectives_end_to_end():
    @task(cost=1.0)
    def seed(i):
        return np.arange(32, dtype=np.float32) * np.float32(0.3 * (i + 1))

    @task(cost=1.0)
    def use(x, j):
        return float(x.sum()) * (j + 1)

    def driver():
        xs = [seed(i) for i in range(5)]
        total = all_reduce(xs, "sum", arity=2)
        copy = broadcast(total, arity=2)
        parts = gather(xs, arity=2)
        return [use(copy, j) for j in range(5)], parts

    g, _ = trace(driver)
    dense = execute_sequential(g)
    low = lowered_results(g)
    same({t: low[t] for t in g.outputs}, {t: dense[t] for t in g.outputs})
    # threaded executor runs the dense collective nodes unchanged
    thr = ThreadedExecutor(2).run(g)
    same({t: thr[t] for t in g.outputs}, {t: dense[t] for t in g.outputs})


def test_collectives_outside_trace_raise():
    with pytest.raises(RuntimeError):
        all_reduce([])
    with pytest.raises(RuntimeError):
        broadcast(None)


# ------------------------------------------------------------- rendering

def test_to_dot_and_summary_render_collectives():
    g, tids = producers_graph(9)
    ar = add_all_reduce(g, tids, "sum", arity=2, out_bytes=64 * 4)
    g.mark_output(ar)
    dot = g.to_dot()
    assert "doubleoctagon" in dot
    assert "all_reduce(n=9, arity=2)" in dot
    assert "collectives={'all_reduce': 1}" in g.summary()
    low, _ = lower_collectives(g, "auto")
    ldot = low.to_dot()
    assert f"stage L0 of #{ar}" in ldot
    assert "collectives={'all_reduce': 1}" in low.summary()


# ------------------------------------------------------ scheduling price

def test_collective_comm_cost_beats_point_to_point_when_wide():
    p2p = 16 * 32 * 1024 / 1e6
    tree = collective_comm_cost(16, 32, 1024, 1e6, arity=4)
    assert 0 < tree < p2p / 2
    # single consumer, tiny n: point-to-point is not worse (the doc's
    # "when point-to-point still wins" case)
    assert collective_comm_cost(2, 1, 1024, 1e6) >= 2 * 1 * 1024 / 1e6
    # host boundaries are priced: crossing hosts costs more than one host
    one = collective_comm_cost(16, 8, 1024, 1e6, n_hosts=1)
    four = collective_comm_cost(16, 8, 1024, 1e6, n_hosts=4,
                                cross_host_penalty=4.0)
    assert four > one
    assert collective_comm_cost(8, 4, 1024, 0.0) == 0.0


# ---------------------------------------------------- simulator modeling

def test_sim_models_collective_lowering():
    from repro.core.simulator import simulate
    g, tids = producers_graph(16)
    ar = add_all_reduce(g, tids, "sum", arity=4, out_bytes=64 * 4)
    g.mark_output(ar)
    off = simulate(g, 4, collectives="off", seed=3)
    auto = simulate(g, 4, collectives="auto", seed=3)
    assert off.makespan > 0 and auto.makespan > 0
    # lowering adds schedulable stages: the sim must see more tasks
    assert len(auto.task_worker) > len(off.task_worker)


def test_sim_search_collective_arity():
    from repro.core.simulator import search_collective_arity
    g, tids = producers_graph(16)
    ar = add_all_reduce(g, tids, "sum", arity=4, out_bytes=64 * 4)
    bc = add_broadcast(g, ar, arity=4, out_bytes=64 * 4)
    for j in range(8):
        def c(x, _j=j):
            return float(x.sum()) * (_j + 1)
        g.add_node(f"c{j}", c, (_Ref(bc),), {}, TaskKind.PURE, deps=(bc,))
    g.mark_output(ar)
    best, results = search_collective_arity(g, 4, [2, 4, 8], seed=5)
    assert set(results) == {2, 4, 8}
    assert best in results
    # deterministic: same search, same verdict
    best2, _ = search_collective_arity(g, 4, [2, 4, 8], seed=5)
    assert best == best2
    with pytest.raises(ValueError):
        search_collective_arity(g, 4, [], seed=5)


# ------------------------------------------------- lineage: subtree replan

def _deep_reduce_graph(n=8, arity=2):
    g, tids = producers_graph(n)
    ar = add_all_reduce(g, tids, "sum", arity=arity, out_bytes=64 * 4)
    g.mark_output(ar)
    return g, ar


def test_mid_tree_loss_replans_only_the_subtree():
    g, ar = _deep_reduce_graph(8, 2)
    low, o2n = lower_collectives(g, "auto")
    plan = fuse_graph(low, "auto")
    stages = collective_stages(low, ar)
    by_level = {}
    for t in stages:
        by_level.setdefault(
            low.nodes[t].meta["collective_stage"]["level"], []).append(t)
    root_new = o2n[ar]
    all_vals = set(low.nodes)

    # one dead level-0 aggregator, leaves alive: replay exactly that stage
    mid = sorted(by_level[0])[0]
    rec = recovery_plan_clusters(plan, {mid}, all_vals - {mid})
    members = {v for cid in rec for v in plan.members[cid]}
    assert members == {mid}

    # a dead chain up one side of the tree: replay that path only — the
    # sibling subtrees' partials are alive and must NOT be recomputed
    path = {mid, sorted(by_level[1])[0], root_new}
    rec = recovery_plan_clusters(plan, {root_new}, all_vals - path)
    members = {v for cid in rec for v in plan.members[cid]}
    assert members == path
    assert sorted(by_level[0])[1] not in members
    assert sorted(by_level[1])[1] not in members
    # the whole blast radius is bounded by the root's own stage set
    assert members <= set(stages) | {root_new}


# ------------------------------------------- cluster: differential + kill

def wide_collective_graph(n=9, m=6, elems=4096, arity=2):
    g, tids = producers_graph(n, elems)
    ar = add_all_reduce(g, tids, "sum", arity=arity, out_bytes=elems * 4)
    bc = add_broadcast(g, ar, arity=arity, out_bytes=elems * 4)
    cons = []
    for j in range(m):
        def c(x, _j=j):
            return float((x * np.float32(_j + 1)).sum())
        cons.append(g.add_node(f"c{j}", c, (_Ref(bc),), {}, TaskKind.PURE,
                               deps=(bc,)))
    def red(*xs):
        return float(sum(xs))
    out = g.add_node("out", red, tuple(_Ref(d) for d in cons), {},
                     TaskKind.PURE, deps=tuple(cons))
    g.mark_output(out)
    return g


@pytest.mark.parametrize("spec", ["off", "auto", 3])
def test_cluster_differential_vs_oracle(spec):
    g = wide_collective_graph()
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, collectives=spec, progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    same(got, seq)
    assert ex.stats["collective_roots"] == 2
    if spec == "off":
        assert ex.stats["collective_stages"] == 0
    else:
        assert ex.stats["collective_stages"] > 0


def test_cluster_tcp_channel_differential():
    g = wide_collective_graph()
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, channel="tcp", collectives="auto",
                         progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    same(got, seq)


def test_cluster_sigkill_mid_tree_recovers_bounded():
    g = wide_collective_graph(n=9, m=6, elems=4096, arity=2)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, collectives="auto", fuse="auto",
                         fail_worker=(1, 3), progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    same(got, seq)
    assert ex.stats["failures"] == 1
    assert ex.stats["recomputed"] >= 1
    # bounded: a one-worker loss must never cascade into a full replay
    low, _ = lower_collectives(g, "auto")
    assert ex.stats["recomputed"] < len(low.nodes)


def test_cluster_faultplan_on_collective_hops_no_double_reduce():
    """Drop/delay/dup on the data and control planes while a lowered
    reduction is in flight: RetryPolicy-driven retries must not apply a
    combine twice — bit-equality against the oracle is the proof."""
    from repro.faults import FaultPlan, RetryPolicy
    g = wide_collective_graph(n=9, m=6, elems=4096, arity=2)
    seq = execute_sequential(g)
    plan = (FaultPlan(seed=23)
            .fail_fetch(nth=1)
            .delay(0.01, prob=0.3)
            .duplicate(prob=0.3))
    ex = ClusterExecutor(2, collectives="auto", fault_plan=plan,
                         transport="sock", shm_threshold=64,
                         fetch_retry=RetryPolicy(attempts=3,
                                                 base_delay=0.01,
                                                 jitter=0.0),
                         progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    same(got, seq)
    assert ex.stats["failures"] == 0      # owner stayed alive
    assert ex.stats["recomputed"] == 0    # retried, not replayed


def test_cluster_resume_meta_records_collectives(tmp_path):
    g = wide_collective_graph(n=5, m=3, elems=512, arity=2)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, collectives=3,
                         checkpoint_dir=str(tmp_path / "ck"),
                         progress_timeout=120.0)
    got = ex.run(g)
    ex.close()
    same(got, seq)
    assert ex.collectives == 3


# ----------------------------------------------------- launcher plumbing

def _args(**over):
    from repro.launch.backend import add_backend_args
    ap = argparse.ArgumentParser()
    add_backend_args(ap)
    args = ap.parse_args([])
    for k, v in over.items():
        setattr(args, k, v)
    return args


def test_launcher_collectives_flag_validation():
    from repro.launch.backend import validate_backend_args
    validate_backend_args(_args())                              # defaults
    validate_backend_args(_args(collectives="off"))
    validate_backend_args(_args(backend="process", collectives="4"))
    with pytest.raises(SystemExit):
        validate_backend_args(_args(collectives="sideways"))
    with pytest.raises(SystemExit):
        validate_backend_args(_args(collectives="1"))
    with pytest.raises(SystemExit):     # arity override needs a cluster
        validate_backend_args(_args(collectives="4"))


def test_make_executor_rejects_collectives_on_thread_backend():
    from repro.core import make_executor
    with pytest.raises(ValueError, match="collectives"):
        make_executor("thread", 2, collectives="auto")
