"""Make ``repro`` importable from a plain ``pytest`` invocation (no
PYTHONPATH needed) and keep the tests directory itself importable so suites
can share helpers like ``_propcheck``."""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_SRC, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)
