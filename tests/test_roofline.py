"""Unit tests for the dry-run HLO parsers and roofline math."""
import pytest

from repro.launch.dryrun import (_shape_bytes, _parse_groups, _wire_bytes,
                                 parse_collectives)


def test_shape_bytes():
    assert _shape_bytes("f32[16,4096,5120]") == 16 * 4096 * 5120 * 4
    assert _shape_bytes("bf16[8,8]") == 128
    assert _shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("token[]") == 0


def test_parse_groups_brace():
    line = "x = f32[4] all-reduce(y), replica_groups={{0,1},{2,3}}, to_apply=add"
    assert _parse_groups(line) == [[0, 1], [2, 3]]


def test_parse_groups_iota():
    line = ("x = f32[4] all-gather(y), "
            "replica_groups=[2,4]<=[8], dimensions={0}")
    assert _parse_groups(line) == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_parse_groups_iota_transposed():
    # mesh (2,2): groups over the FIRST axis via transpose
    line = ("x = f32[4] all-reduce(y), "
            "replica_groups=[2,2]<=[2,2]T(1,0), to_apply=add")
    assert _parse_groups(line) == [[0, 2], [1, 3]]


def test_wire_bytes_factors():
    b, g = 1000.0, 4
    assert _wire_bytes("all-gather", b, g) == pytest.approx(750.0)
    assert _wire_bytes("all-reduce", b, g) == pytest.approx(1500.0)
    assert _wire_bytes("reduce-scatter", b, g) == pytest.approx(3000.0)
    assert _wire_bytes("all-to-all", b, g) == pytest.approx(750.0)
    assert _wire_bytes("collective-permute", b, g) == pytest.approx(1000.0)
    assert _wire_bytes("all-reduce", b, 1) == 0.0


def test_parse_collectives_end_to_end():
    hlo = "\n".join([
        "%ar = f32[256] all-reduce(%x), replica_groups={{0,1,2,3}}, "
        "to_apply=%add",
        # promoted bf16 AR counted at half width
        "%arp = f32[256] all-reduce(%y), replica_groups={{0,1,2,3}}, "
        "to_apply=%add.clone_promoted",
        "%ag = bf16[512] all-gather(%z), replica_groups=[2,2]<=[4], "
        "dimensions={0}",
        "%cp = f32[64] collective-permute(%w), "
        "source_target_pairs={{0,1},{1,0}}",
    ])
    out = parse_collectives(hlo)
    assert out["_n_ops"] == 4
    assert out["all-reduce"] == 1024 + 512       # second at half width
    assert out["all-gather"] == 1024
    # wire: AR 2·b·3/4 (=1536+768), AG b/2, permute b
    assert out["_wire_ici_bytes"] == pytest.approx(
        1536 + 768 + 512 + 256)


def test_dcn_attribution():
    hlo = ("%ar = f32[256] all-reduce(%x), replica_groups={{0,300}}, "
           "to_apply=%add")
    out = parse_collectives(hlo, pod_boundary=256)
    assert out["_wire_dcn_bytes"] > 0
    assert out["_wire_ici_bytes"] == 0
    out2 = parse_collectives(hlo, pod_boundary=512)
    assert out2["_wire_dcn_bytes"] == 0


def test_model_flops_sane():
    from benchmarks.roofline import model_flops, _param_counts
    total, active = _param_counts("qwen2-7b")
    assert 6e9 < total < 9e9
    assert total == active
    t_moe, a_moe = _param_counts("dbrx-132b")
    assert 1.2e11 < t_moe < 1.45e11
    assert 3.0e10 < a_moe < 4.5e10          # top-4 of 16 experts
    t_l4, a_l4 = _param_counts("llama4-maverick-400b-a17b")
    assert 3.7e11 < t_l4 < 4.3e11
    assert 1.0e10 < a_l4 < 2.2e10           # "a17b"
    # train counts fwd+bwd (6ND), decode counts 2ND on 1 token/seq
    assert model_flops("qwen2-7b", "train_4k") == \
        6 * total * 4096 * 256
    assert model_flops("qwen2-7b", "decode_32k") == 2 * total * 128
