"""Gradient compression: quantization error bounds, error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (Int8BlockCompressor,
                                        compress_with_feedback,
                                        init_residual, compression_ratio)

KEY = jax.random.PRNGKey(0)


def test_roundtrip_error_bounded_by_scale():
    comp = Int8BlockCompressor(block=256)
    x = jax.random.normal(KEY, (1000,)) * 5.0
    out = comp.roundtrip(x)
    # per-block max-abs / 127 is the quantization step; error <= step/2 + eps
    err = np.abs(np.asarray(out - x))
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_roundtrip_preserves_shape_and_zeros():
    comp = Int8BlockCompressor(block=64)
    for shape in [(7,), (33, 5), (4, 4, 4)]:
        x = jnp.zeros(shape)
        out = comp.roundtrip(x)
        assert out.shape == shape
        np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_error_feedback_mean_converges():
    """With error feedback, the time-average of compressed grads converges
    to the time-average of true grads (residual stays bounded)."""
    comp = Int8BlockCompressor(block=256)
    g = {"w": jax.random.normal(KEY, (512,)) * 0.01}
    res = init_residual(g)
    total_true = jnp.zeros((512,))
    total_comp = jnp.zeros((512,))
    for i in range(50):
        approx, res = compress_with_feedback(g, res, comp)
        total_true += g["w"]
        total_comp += approx["w"]
    # cumulative compressed sum differs from true sum by at most the residual
    np.testing.assert_allclose(np.asarray(total_comp + res["w"]),
                               np.asarray(total_true), rtol=1e-4, atol=1e-4)
    assert float(jnp.max(jnp.abs(res["w"]))) < 0.01   # bounded residual


def test_compression_ratio():
    assert compression_ratio(4) < 0.26   # int8 vs f32 ≈ 4×
    assert compression_ratio(2) < 0.52   # vs bf16 ≈ 2×
