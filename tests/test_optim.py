"""Optimizers: convergence, state shapes, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamW, Adafactor, SGD
from repro.optim.schedules import cosine_schedule, linear_warmup


def quad_loss(params):
    return sum(jnp.sum((p - 3.0) ** 2) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("opt", [AdamW(lr=0.1), Adafactor(lr=0.5),
                                 SGD(lr=0.05, momentum=0.9)])
def test_optimizer_converges_on_quadratic(opt):
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    grad_fn = jax.grad(quad_loss)

    @jax.jit
    def step(params, state):
        g = grad_fn(params)
        updates, state = opt.update(g, state, params)
        return jax.tree.map(lambda p, u: p + u, params, updates), state

    for _ in range(200):
        params, state = step(params, state)
    final = float(quad_loss(params))
    assert final < 0.05, final


def test_adamw_state_mirrors_params_f32():
    params = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
    st = AdamW().init(params)
    assert st["m"]["w"].dtype == jnp.float32
    assert st["m"]["w"].shape == (8, 8)
    assert int(st["step"]) == 0


def test_adafactor_factored_state_is_small():
    opt = Adafactor(min_dim_factored=128)
    params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((4, 4)),
              "vec": jnp.zeros((1024,))}
    st = opt.init(params)
    assert set(st["v"]["big"]) == {"vr", "vc"}
    assert st["v"]["big"]["vr"].shape == (512,)
    assert st["v"]["big"]["vc"].shape == (256,)
    assert set(st["v"]["small"]) == {"v"}       # too small to factor
    assert set(st["v"]["vec"]) == {"v"}         # 1-D never factored
    # factored state is ~(n+m)/(n·m) of Adam's
    n_fact = sum(x.size for x in jax.tree.leaves(st["v"]["big"]))
    assert n_fact == 512 + 256


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    st = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    updates, _ = opt.update(huge, st, params)
    # post-clip global norm is 1 -> per-step update magnitude is bounded by lr·O(1)
    assert float(jnp.max(jnp.abs(updates["w"]))) < 10.0


def test_cosine_schedule_shape():
    sched = cosine_schedule(peak=1e-3, warmup_steps=100, total_steps=1000,
                            floor=0.1)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0, abs=1e-8)
    assert float(sched(jnp.asarray(100))) == pytest.approx(1e-3, rel=1e-2)
    assert float(sched(jnp.asarray(1000))) == pytest.approx(1e-4, rel=1e-2)
    mid = float(sched(jnp.asarray(550)))
    assert 1e-4 < mid < 1e-3
    warm = linear_warmup(1e-3, 10)
    assert float(warm(jnp.asarray(5))) == pytest.approx(5e-4, rel=1e-3)


def test_optimizer_update_is_jit_safe_with_schedule():
    opt = AdamW(lr=cosine_schedule(1e-3, 10, 100))
    params = {"w": jnp.ones((4,))}
    st = opt.init(params)
    g = {"w": jnp.ones((4,))}

    @jax.jit
    def step(st):
        return opt.update(g, st, params)

    _, st2 = step(st)
    assert int(st2["step"]) == 1
