"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per assignment: sweep shapes/dtypes per kernel and assert_allclose against
``kernels/ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def allclose(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **tol(dtype))


# ------------------------------------------------------------------ matmul

@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 512, 128),
                                   (384, 256, 512), (512, 1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(M, K, N, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (M, K), dtype)
    y = jax.random.normal(k2, (K, N), dtype)
    out = ops.matmul(x, y, interpret=True)
    assert out.shape == (M, N) and out.dtype == dtype
    allclose(out, ref.matmul(x, y), dtype)


@given(st.sampled_from([64, 128, 256]), st.sampled_from([64, 128, 256]),
       st.sampled_from([64, 128]))
@settings(max_examples=8, deadline=None)
def test_matmul_block_shape_independent(bm, bn, bk):
    """Property: result does not depend on the BlockSpec tiling."""
    from repro.kernels.matmul_pallas import matmul
    x = jax.random.normal(KEY, (256, 256), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    out = matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
    allclose(out, ref.matmul(x, y), jnp.float32)


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("B,H,KH,S,D", [
    (1, 4, 4, 256, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA 2:1
    (1, 8, 1, 512, 128),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KH, S, D, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KH, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KH, S, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=128, bk=128,
                              interpret=True)
    assert out.shape == q.shape and out.dtype == dtype
    allclose(out, ref.attention(q, k, v, causal=causal), dtype)


def test_flash_attention_cross_shaped_kv():
    """Sq != Sk (cross-attention shape)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 4, 384, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 4, 384, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, bq=128, bk=128,
                              interpret=True)
    allclose(out, ref.attention(q, k, v, causal=False), jnp.float32)


@given(st.sampled_from([64, 128, 256]), st.sampled_from([64, 128, 256]))
@settings(max_examples=6, deadline=None)
def test_flash_attention_block_shape_independent(bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                              interpret=True)
    allclose(out, ref.attention(q, k, v, causal=True), jnp.float32)


# ------------------------------------------------------------- ssm scan

@pytest.mark.parametrize("Bsz,S,D,N", [(1, 64, 64, 8), (2, 128, 128, 16),
                                       (1, 256, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_matches_ref(Bsz, S, D, N, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bsz, S, D), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, D), dtype)) * 0.1
    B = jax.random.normal(ks[2], (Bsz, S, N), dtype)
    C = jax.random.normal(ks[3], (Bsz, S, N), dtype)
    A = -jax.nn.softplus(jax.random.normal(ks[4], (D, N), jnp.float32))
    out = ops.ssm_scan(x, dt, B, C, A, chunk=32, bd=64, interpret=True)
    assert out.shape == x.shape
    # recurrences accumulate error in bf16 — loosen
    t = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.ssm_scan(x, dt, B, C, A), np.float32), **t)


@given(st.sampled_from([16, 32, 64]))
@settings(max_examples=4, deadline=None)
def test_ssm_scan_chunk_independent(chunk):
    """Property: chunked scan == step-by-step scan for any chunk size."""
    ks = jax.random.split(KEY, 5)
    Bsz, S, D, N = 1, 128, 64, 8
    x = jax.random.normal(ks[0], (Bsz, S, D), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, D))) * 0.1
    B = jax.random.normal(ks[2], (Bsz, S, N))
    C = jax.random.normal(ks[3], (Bsz, S, N))
    A = -jax.nn.softplus(jax.random.normal(ks[4], (D, N)))
    out = ops.ssm_scan(x, dt, B, C, A, chunk=chunk, bd=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ssm_scan(x, dt, B, C, A)),
                               rtol=1e-4, atol=1e-4)
