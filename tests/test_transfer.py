"""Zero-copy data plane: differential tests vs the sequential oracle over
every transport (shm / sock / driver), SIGKILL mid-transfer, /dev/shm leak
checks, replica-set bookkeeping, serialization-failure surfacing, and the
transfer-cost-aware scheduler extensions.

Array payloads are deterministic (arange-based) so "bit-for-bit" is a real
assertion: values must round-trip shared memory / peer sockets with exact
bytes AND exact dtypes.  ``shm_threshold=1`` forces even small values
through the zero-copy path, exercising it densely on 200+-node DAGs
without moving gigabytes.
"""
import glob
import random

import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import (TaskGraph, TaskKind, execute_sequential, run_graph,
                        TaskFailed)
from repro.core.scheduler import list_schedule
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, DriverObjectStore, serde

try:
    import ml_dtypes
    BFLOAT16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:             # pragma: no cover — ships with jax
    BFLOAT16 = None

TRANSPORTS = ["driver"]
if serde.shm_available():
    TRANSPORTS.append("shm")
import socket as _socket                                     # noqa: E402
if hasattr(_socket, "AF_UNIX"):
    TRANSPORTS.append("sock")


def deep_equal(a, b) -> bool:
    """Bit-for-bit pytree equality: exact dtype and exact bytes."""
    if isinstance(b, np.ndarray):
        return (isinstance(a, np.ndarray) and a.dtype == b.dtype
                and a.shape == b.shape and np.array_equal(a, b))
    if isinstance(b, dict):
        return (isinstance(a, dict) and a.keys() == b.keys()
                and all(deep_equal(a[k], b[k]) for k in b))
    if isinstance(b, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(deep_equal(x, y) for x, y in zip(a, b)))
    return a == b


def results_equal(got, want) -> bool:
    return (set(got) == set(want)
            and all(deep_equal(got[t], want[t]) for t in want))


def array_dag(seed: int, n: int, p: float, elems: int,
              dtype=np.float32) -> TaskGraph:
    """Random DAG over float arrays: sources emit ``arange`` ramps, inner
    nodes combine their deps elementwise — deterministic and dtype-stable."""
    rng = random.Random(seed)
    dt = np.dtype(dtype)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i, _e=elems, _dt=dt):
            acc = (np.arange(_e) % 97).astype(_dt) * _dt.type(_i % 7 + 1)
            for x in xs:
                acc = (acc + x).astype(_dt)
            return acc

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def pytree_dag() -> TaskGraph:
    """Nested dict/list/tuple payloads with array leaves crossing workers."""
    g = TaskGraph()

    def make():
        return {"w": np.arange(50_000, dtype=np.float32),
                "meta": {"step": 3, "tags": ("a", "b")},
                "hist": [np.ones(7, dtype=np.int64), 2.5]}

    def bump(tree):
        return {"w": tree["w"] * np.float32(2),
                "meta": dict(tree["meta"], step=tree["meta"]["step"] + 1),
                "hist": [tree["hist"][0] + 1, tree["hist"][1]]}

    def merge(a, b):
        return (a["w"] + b["w"], a["meta"]["step"] + b["meta"]["step"],
                [a["hist"][0], b["hist"][0]])

    g.add_node("make", make, (), {}, TaskKind.PURE, deps=())
    g.add_node("bump1", bump, (_Ref(0),), {}, TaskKind.PURE, deps=[0])
    g.add_node("bump2", bump, (_Ref(1),), {}, TaskKind.PURE, deps=[1])
    g.add_node("merge", merge, (_Ref(1), _Ref(2)), {}, TaskKind.PURE,
               deps=[1, 2])
    g.mark_output(3)
    return g


def int_dag(seed: int, n: int, p: float) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def assert_no_segments(prefix: str) -> None:
    assert prefix, "executor did not record a segment prefix"
    leftovers = glob.glob(f"/dev/shm/{prefix}*")
    assert not leftovers, f"leaked shm segments: {leftovers}"


# ----------------------------------------------------------- differential

@pytest.mark.parametrize("transport", TRANSPORTS)
def test_large_float32_arrays_bit_identical(transport):
    """1 MiB float32 payloads over every transport, vs the oracle."""
    g = array_dag(7, 24, 0.35, elems=1 << 18)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, transport=transport)
    res = ex.run(g)
    assert results_equal(res, seq)
    # backend parity: callers can mutate returned arrays, as with
    # thread/sequential results
    assert all(res[t].flags.writeable for t in res)
    if transport != "driver":
        assert ex.stats["transfers_direct"] > 0
        assert ex.stats["bytes_direct"] > ex.stats["bytes_driver"]
    assert_no_segments(ex.seg_prefix)


@pytest.mark.skipif(BFLOAT16 is None, reason="ml_dtypes unavailable")
def test_bfloat16_arrays_bit_identical():
    """Non-native dtypes must survive the out-of-band buffer path: exact
    bytes and the exact bfloat16 dtype on the far side."""
    g = array_dag(11, 16, 0.4, elems=1 << 17, dtype=BFLOAT16)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, transport="shm" if "shm" in TRANSPORTS
                         else "driver")
    res = ex.run(g)
    assert results_equal(res, seq)
    assert res[len(g.nodes) - 1].dtype == BFLOAT16


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_pytree_payloads(transport):
    g = pytree_dag()
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, transport=transport)
    assert results_equal(ex.run(g), seq)


def test_200_node_dag_dense_zero_copy():
    """210 nodes with shm_threshold=1: every cross-worker value takes the
    zero-copy path, and the run still matches the oracle exactly."""
    g = int_dag(42, 210, 0.25)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, transport="shm" if "shm" in TRANSPORTS
                         else "driver", shm_threshold=1)
    assert ex.run(g) == seq
    assert ex.stats["dispatched"] >= 210
    assert_no_segments(ex.seg_prefix)


@given(st.integers(0, 2000), st.integers(2, 3))
@settings(max_examples=4, deadline=None)
def test_random_array_dags_match_oracle(seed, workers):
    g = array_dag(seed, 14 + seed % 9, 0.3, elems=1 << 14)
    assert results_equal(ClusterExecutor(workers).run(g),
                         execute_sequential(g))


# ------------------------------------------------------ kill mid-transfer

@pytest.mark.parametrize("transport", TRANSPORTS)
def test_sigkill_mid_transfer_recovers(transport):
    """SIGKILL the busiest worker while 1 MiB transfers are in flight: the
    run must degrade to lineage recovery and still match the oracle."""
    g = array_dag(13, 20, 0.45, elems=1 << 18)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, transport=transport, fail_worker=(0, 2))
    assert results_equal(ex.run(g), seq)
    assert ex.stats["failures"] == 1
    assert_no_segments(ex.seg_prefix)


def test_sigkill_outputs_only_gc_with_arrays():
    """GC mode: segments are unlinked eagerly as consumers drain, a kill
    recovers through dropped ancestors, and nothing leaks."""
    g = array_dag(17, 30, 0.35, elems=1 << 16)
    seq = execute_sequential(g)
    want = {t: seq[t] for t in g.outputs}
    ex = ClusterExecutor(3, outputs_only=True, fail_worker=(1, 3))
    res = ex.run(g)
    assert results_equal(res, want)
    assert ex.stats["failures"] == 1
    assert ex.stats["dropped"] > 0
    assert_no_segments(ex.seg_prefix)


def test_no_shm_segments_survive_shutdown():
    """Leak check across healthy + killed runs: no segment with this run's
    prefix (or any rr prefix left by them) survives executor shutdown."""
    if "shm" not in TRANSPORTS:
        pytest.skip("no shared memory in this environment")
    before = set(glob.glob("/dev/shm/rr*"))
    prefixes = []
    for fail in (None, (0, 1)):
        ex = ClusterExecutor(2, transport="shm", shm_threshold=1,
                             fail_worker=fail)
        ex.run(int_dag(3, 60, 0.3))
        prefixes.append(ex.seg_prefix)
    for prefix in prefixes:
        assert_no_segments(prefix)
    assert set(glob.glob("/dev/shm/rr*")) <= before


# ------------------------------------------------- replica-set bookkeeping

def test_replica_survives_owner_death():
    """A value replicated onto a second worker by a transfer is NOT lost
    when the original producer dies (the single-owner bug)."""
    g = int_dag(1, 5, 0.9)
    store = DriverObjectStore(g)
    store.add_worker(0)
    store.add_worker(1)
    store.record(0, 0, nbytes=8)
    store.record_replica(0, 1)          # post-transfer replica
    store.record(1, 0, nbytes=8)        # only on worker 0
    lost = store.drop_worker(0)
    assert lost == {1}                  # tid 0 lives on via worker 1
    assert store.locations(0) == {1}
    assert store.available({1}) >= {0}
    # and the replica holder dying too finally loses it
    assert store.drop_worker(1) == {0}


def test_durable_handle_prevents_loss():
    """A value published to shared memory (durable handle) survives its
    last replica's death; a peer handle does not."""
    g = int_dag(2, 4, 0.9)
    store = DriverObjectStore(g)
    store.add_worker(0)
    store.record(0, 0)
    store.set_handle(0, serde.Encoded(b"x", [], 1))
    assert store.drop_worker(0) == set()        # durable: not lost
    store2 = DriverObjectStore(g)
    store2.add_worker(0)
    store2.record(1, 0)
    store2.set_handle(1, serde.PeerRef("/nowhere", 1, 8, 0))
    assert store2.drop_worker(0) == {1}         # peer handle died with it
    assert 1 not in store2.handles


def test_invalidate_clears_every_trace():
    g = int_dag(4, 4, 0.9)
    store = DriverObjectStore(g)
    store.add_worker(0)
    store.add_worker(1)
    store.record(2, 0, nbytes=64)
    store.record_replica(2, 1)
    store.cache_value(2, 123)
    store.set_handle(2, serde.Encoded(b"x", [], 1))
    store.invalidate({2})
    assert store.locations(2) == set()
    assert 2 not in store.cache and 2 not in store.handles
    assert 2 not in store.known[0] and 2 not in store.known[1]


# ------------------------------------------- serialization-failure surface

def test_unpicklable_result_is_task_error_not_worker_death():
    """A result that cannot be serialized surfaces as TaskFailed on the
    run/future; the worker must NOT be treated as dead (no recovery loop)."""
    g = TaskGraph()
    g.add_node("bad", lambda: (lambda x: x), (), {}, TaskKind.PURE, deps=())
    g.mark_output(0)
    for transport in TRANSPORTS:
        ex = ClusterExecutor(2, transport=transport, progress_timeout=30.0)
        with pytest.raises(TaskFailed, match="SerializationError"):
            ex.run(g)
        assert ex.stats["failures"] == 0


def test_unpicklable_transfer_input_is_task_error():
    """Same contract when the unpicklable value is an *input* a consumer on
    another worker needs (forced remote by pinning one worker per task)."""
    g = TaskGraph()
    g.add_node("mk", lambda: (lambda x: x), (), {}, TaskKind.PURE, deps=())
    g.add_node("use", lambda f: 1, (_Ref(0),), {}, TaskKind.PURE, deps=[0])
    g.mark_output(1)
    ex = ClusterExecutor(2, progress_timeout=30.0)
    with pytest.raises(TaskFailed):
        ex.run(g)
    assert ex.stats["failures"] == 0


# ------------------------------------------------- serde unit behaviours

def test_encode_decode_roundtrip_inline_and_shm():
    value = {"a": np.arange(100_000, dtype=np.float32), "b": [1, "two"]}
    inline = serde.encode(value, transport="driver")
    assert not inline.shm_refs()
    assert deep_equal(serde.decode(inline), value)
    if "shm" in TRANSPORTS:
        enc = serde.encode(value, transport="shm", threshold=1024)
        assert enc.shm_refs()
        assert enc.pipe_nbytes() < 4096 < enc.direct_nbytes()
        assert deep_equal(serde.decode(enc), value)         # copy path
        keeper = serde.SegmentKeeper()
        view = serde.decode(enc, keeper)                    # zero-copy path
        assert deep_equal(view, value)
        serde.release(enc)
        assert deep_equal(view, value)      # mapping outlives the unlink
        with pytest.raises(serde.TransferLost):
            serde.decode(enc)               # new attach fails post-release


def test_payload_nbytes_estimates():
    assert serde.payload_nbytes(np.zeros(1000, dtype=np.float64)) == 8000
    assert serde.payload_nbytes(b"abcd") == 4
    nested = {"x": np.zeros(100, dtype=np.int32), "y": [b"12345678"]}
    assert serde.payload_nbytes(nested) >= 408


def test_resolve_transport_fallbacks():
    assert serde.resolve_transport("driver") == "driver"
    assert serde.resolve_transport("auto") in ("shm", "sock", "driver")
    with pytest.raises(ValueError):
        serde.resolve_transport("warp")
    with pytest.raises(ValueError):
        ClusterExecutor(2, transport="warp")


# ------------------------------------- scheduler + report plumbing

def test_scheduler_transfer_cost_placement():
    """With data sizes + known owners, the replan puts the consumer of a
    huge completed value on the worker that already holds it."""
    g = TaskGraph()
    g.add_node("big", lambda: 0, (), {}, TaskKind.PURE, deps=(), cost=1.0)
    g.add_node("use", lambda x: x, (_Ref(0),), {}, TaskKind.PURE,
               deps=[0], cost=1.0)
    g.add_node("other", lambda: 1, (), {}, TaskKind.PURE, deps=(), cost=1.0)
    g.mark_output(1)
    g.mark_output(2)
    sched = list_schedule(
        g, 2, done={0: 0.0}, placed={0: 1},
        data_sizes={0: 1 << 30}, bandwidth=float(1 << 20))
    assert sched.placements[1].worker == 1      # stays next to the bytes
    # without the transfer-cost term both workers look identical
    base = list_schedule(g, 2, done={0: 0.0})
    assert base.placements[1].start <= sched.placements[1].start


def test_run_graph_with_report_carries_data_plane_stats():
    g = int_dag(6, 40, 0.3)
    seq = execute_sequential(g)
    res, report = run_graph(g, n_workers=2, backend="process",
                            with_report=True, shm_threshold=1)
    assert res == seq
    assert report["backend"] == "process"
    assert report["transport"] in ("shm", "sock", "driver")
    for key in ("bytes_moved", "bytes_driver", "bytes_direct",
                "transfers_direct", "transfers_driver"):
        assert key in report["stats"]
    res2, report2 = run_graph(g, with_report=True)
    assert res2 == seq and report2["backend"] == "sequential"


def test_future_carries_stats_snapshot():
    g = int_dag(8, 50, 0.3)
    fut = ClusterExecutor(2).submit(g, label="stats")
    res = fut.result(timeout=120)
    assert res == execute_sequential(g)
    assert fut.stats.get("dispatched", 0) >= 50
    assert fut.wall_time > 0
