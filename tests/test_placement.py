"""Auto-sharding placement: rule tables, spec derivation, cost refinement."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (standard_rules, sequence_parallel_rules,
                        logical_to_spec, ValueInfo, refine_placements,
                        resharding_bytes, total_resharding_bytes,
                        spec_shards, TaskGraph, TaskKind)
from repro.core.placement import candidate_specs


class FakeMesh:
    """Duck-typed mesh (axis_names + shape) — placement never touches
    devices, so tests run without multi-device jax."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_rule_table_modes():
    for mode in ("dp", "dp_tp", "fsdp_tp", "dp_tp_ep"):
        rules = standard_rules(mode, pod_axis=None)
        spec = logical_to_spec(("batch", "seq", "d_model"), rules, MESH)
        assert spec[0] == ("data",) or spec[0] == "data"
    with pytest.raises(ValueError):
        standard_rules("nope")


def test_first_match_wins_and_no_axis_reuse():
    rules = [("batch", ("data",)), ("heads", "model"), ("batch", None),
             ("weird", ("data", "model"))]
    # batch resolves to data (first match), heads to model
    spec = logical_to_spec(("batch", "heads"), rules, MESH)
    assert spec == P("data", "model")
    # a mesh axis never appears twice: second "data" use is dropped
    spec = logical_to_spec(("batch", "weird"), rules, MESH)
    assert spec == P("data", "model")


def test_pod_axis_extends_batch():
    rules = standard_rules("fsdp_tp", pod_axis="pod")
    spec = logical_to_spec(("batch", "seq"), rules, POD_MESH)
    assert spec == P(("pod", "data"))
    # without pod in the mesh the pod axis is dropped
    spec = logical_to_spec(("batch", "seq"), rules, MESH)
    assert spec == P("data")


def test_sequence_parallel_rules():
    rules = sequence_parallel_rules(standard_rules("dp_tp", pod_axis=None))
    spec = logical_to_spec(("batch", "seq", "d_model"), rules, MESH)
    assert spec == P("data", "model")


def test_spec_shards():
    assert spec_shards(P("data", "model"), MESH) == 256
    assert spec_shards(P(("data", "model")), MESH) == 256
    assert spec_shards(P(None, "model"), MESH) == 16
    assert spec_shards(P(), MESH) == 1


def test_resharding_cost_model_properties():
    info = ValueInfo((1024, 1024), 4, ("batch", "d_model"))
    same = P("data", None)
    assert resharding_bytes(info, same, same, MESH) == 0.0
    # replicated -> sharded is free (local slice)
    assert resharding_bytes(info, P(), P("data"), MESH) == 0.0
    # sharded -> replicated costs ~full size
    c = resharding_bytes(info, P("data"), P(), MESH)
    assert 0 < c <= 1024 * 1024 * 4


def _diamond_graph():
    g = TaskGraph()
    a = g.add_node("a", None, (), {}, TaskKind.PURE, deps=[])
    b = g.add_node("b", None, (), {}, TaskKind.PURE, deps=[a])
    c = g.add_node("c", None, (), {}, TaskKind.PURE, deps=[a])
    d = g.add_node("d", None, (), {}, TaskKind.PURE, deps=[b, c])
    g.mark_output(d)
    return g


def test_refinement_never_worse_than_rules():
    g = _diamond_graph()
    rules = standard_rules("dp_tp", pod_axis=None)
    info = {t: ValueInfo((256, 4096), 4, ("batch", "d_model"))
            for t in g.nodes}
    # make node b's natural layout conflict: logical axes transposed
    info[1] = ValueInfo((4096, 256), 4, ("d_model", "batch"))
    init = {t: logical_to_spec(info[t].logical_axes, rules, MESH)
            for t in g.nodes}
    refined = refine_placements(g, info, rules, MESH)
    assert total_resharding_bytes(g, info, refined, MESH) <= \
        total_resharding_bytes(g, info, init, MESH) + 1e-9


def test_candidate_specs_contains_rule_spec_and_replicated():
    rules = standard_rules("dp_tp", pod_axis=None)
    info = ValueInfo((256, 4096), 4, ("batch", "d_model"))
    cands = candidate_specs(info, rules, MESH)
    assert P() in cands
    assert logical_to_spec(info.logical_axes, rules, MESH) in cands
    # every candidate's shard counts divide the dims
    for c in cands:
        parts = list(c) + [None] * (2 - len(c))
        for dim, part in zip(info.shape, parts):
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            n = int(np.prod([MESH.shape[a] for a in axes]))
            assert dim % n == 0


# _fit_sharding's non-divisible-drop behaviour needs a >1-way mesh; it is
# covered in tests/test_spmd.py (8-device subprocess).
