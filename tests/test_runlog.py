"""Run-log checkpoint: record roundtrip, torn-tail repair, replay
semantics, fingerprints, and the flat-in-worker-count byte cost.

These exercise :mod:`repro.checkpoint.runlog` in isolation — the
driver-restart differentials that *use* the log live in
``test_cluster.py`` (pipe channel) and ``test_multihost.py`` (TCP
rejoin, real SIGKILL).
"""
import os
import pickle
import random
import struct

import pytest

from _propcheck import given, settings, st
from repro.checkpoint.runlog import (RunLog, load_run, latest_run,
                                     graph_fingerprint, plan_fingerprint)
from repro.core import TaskGraph, TaskKind
from repro.core.fusion import fuse
from repro.core.tracing import RemappedRef as _Ref


def _log(tmp_path, name="r1"):
    return os.path.join(str(tmp_path), f"{name}.log")


def _begin(run_id="r1", **extra):
    meta = {"run_id": run_id, "graph_fp": "g", "plan_fp": "p",
            "seg_prefix": "rrtest0", "address": None}
    meta.update(extra)
    return ("begin", meta)


def _dag(seed: int, n: int) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < 0.3][-3:]
        g.add_node(f"t{i}", lambda *xs, _i=i: _i + sum(xs),
                   tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=1.0)
    g.mark_output(n - 1)
    return g


# ----------------------------------------------------------- writer/loader

def test_roundtrip_all_record_kinds(tmp_path):
    path = _log(tmp_path)
    log = RunLog(path, interval=0.0)
    log.append(*_begin())
    log.append("worker", 0, "hostA")
    log.append("worker", 1, "hostB")
    log.append("done", 5, 0, {5: 128, 6: 64})
    log.append("hnd", 5, b"handle-bytes")
    log.append("val", 6, pickle.dumps(42))
    log.append("gc", [3, 4])
    log.append("live", [4])
    log.append("dead", 1)
    log.append("redo", [7])
    log.append("done", 7, 0, {7: 32})
    log.append("resume", {"seg_prefix": "rrtest1"})
    log.close()

    st_ = load_run(path)
    assert st_.meta["run_id"] == "r1"
    assert st_.seg_prefixes == ["rrtest0", "rrtest1"]
    assert st_.workers == {0: "hostA", 1: "hostB"}
    assert st_.dead == {1}
    assert st_.live_workers == {0: "hostA"}
    assert st_.done == {5: (0, {5: 128, 6: 64}), 7: (0, {7: 32})}
    assert st_.dropped == {3}           # 4 was resurrected by "live"
    assert st_.handles == {5: b"handle-bytes"}
    assert pickle.loads(st_.values[6]) == 42
    assert not st_.truncated


def test_redo_retracts_done_and_rejoin_revives_dead(tmp_path):
    path = _log(tmp_path)
    log = RunLog(path, interval=0.0)
    log.append(*_begin())
    log.append("worker", 0, "h")
    log.append("done", 1, 0, {1: 8})
    log.append("dead", 0)
    log.append("redo", [1])
    log.append("worker", 0, "h")        # re-adoption after rejoin
    log.close()
    st_ = load_run(path)
    assert st_.done == {}
    assert st_.dead == set()
    assert st_.live_workers == {0: "h"}


def test_buffered_append_defers_io_until_flush(tmp_path):
    path = _log(tmp_path)
    log = RunLog(path, interval=3600.0)
    log.append(*_begin())
    for i in range(50):
        log.append("done", i, 0, {i: 8})
    assert os.path.getsize(path) == 0           # nothing hit disk yet
    assert log.bytes_written == 0
    assert not log.maybe_flush()                # interval not elapsed
    log.flush()
    assert log.bytes_written == os.path.getsize(path) > 0
    log.close()
    assert len(load_run(path).done) == 50


def test_maybe_flush_triggers_on_buffer_pressure(tmp_path):
    log = RunLog(_log(tmp_path), interval=3600.0, max_buffer=256)
    log.append(*_begin())
    while not log.maybe_flush():
        log.append("done", 0, 0, {0: 8})
    assert log.bytes_written > 0
    log.close()


@pytest.mark.parametrize("cut", ["prefix", "payload", "garbage"])
def test_torn_tail_detected_and_repaired(tmp_path, cut):
    path = _log(tmp_path)
    log = RunLog(path, interval=0.0)
    log.append(*_begin())
    for i in range(10):
        log.append("done", i, 0, {i: 8})
    log.close()
    clean = os.path.getsize(path)

    with open(path, "ab") as f:
        if cut == "prefix":
            f.write(b"\x00\x00")                        # short length
        elif cut == "payload":
            f.write(struct.pack(">I", 999) + b"short")  # short payload
        else:
            f.write(struct.pack(">I", 4) + b"\xff\xff\xff\xff")  # bad pickle

    st_ = load_run(path, repair=False)
    assert st_.truncated and len(st_.done) == 10
    assert os.path.getsize(path) > clean        # repair=False left the tear

    st_ = load_run(path)                        # repair=True truncates...
    assert st_.truncated and len(st_.done) == 10
    assert os.path.getsize(path) == clean

    with open(path, "ab") as f:                 # ...so appends are clean
        rec = pickle.dumps(("done", 99, 1, {99: 1}))
        f.write(struct.pack(">I", len(rec)) + rec)
    st_ = load_run(path)
    assert not st_.truncated and 99 in st_.done


def test_torn_mid_record_loses_at_most_the_tail(tmp_path):
    """Cut the file at EVERY byte offset: the loader must never crash,
    never invent records, and keep the longest clean prefix."""
    path = _log(tmp_path)
    log = RunLog(path, interval=0.0)
    log.append(*_begin())
    for i in range(6):
        log.append("done", i, 0, {i: 8})
    log.close()
    blob = open(path, "rb").read()

    seen = []
    for cut in range(1, len(blob) + 1):
        p = _log(tmp_path, f"cut{cut}")
        with open(p, "wb") as f:
            f.write(blob[:cut])
        try:
            st_ = load_run(p, repair=False)
        except ValueError:
            continue                            # begin record itself torn
        seen.append(len(st_.done))
    assert seen and max(seen) == 6
    assert seen == sorted(seen)                 # monotone in cut point


def test_load_run_requires_begin(tmp_path):
    path = _log(tmp_path)
    log = RunLog(path, interval=0.0)
    log.append("done", 1, 0, {1: 8})            # no begin
    log.close()
    with pytest.raises(ValueError):
        load_run(path)


def test_unknown_record_kinds_are_skipped(tmp_path):
    path = _log(tmp_path)
    log = RunLog(path, interval=0.0)
    log.append(*_begin())
    log.append("future-kind", {"x": 1})
    log.append("done", 1, 0, {1: 8})
    log.close()
    st_ = load_run(path)
    assert st_.done == {1: (0, {1: 8})} and st_.n_records == 3


def test_latest_run_picks_newest_and_handles_missing_dir(tmp_path):
    assert latest_run(str(tmp_path / "nope")) is None
    for i, name in enumerate(["aaa", "bbb"]):
        p = _log(tmp_path, name)
        RunLog(p, interval=0.0).close()
        os.utime(p, (1000 + i, 1000 + i))
    assert latest_run(str(tmp_path)) == "bbb"


# --------------------------------------------------------------- property

@given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 3),
                          st.booleans()), max_size=60),
       st.integers(0, 2**32 - 1))
@settings(max_examples=40)
def test_replay_equals_dict_semantics(events, seed):
    """Replaying (done | redo | gc | live) events matches a plain
    last-writer-wins dict/set model, for any interleaving."""
    import tempfile
    rng = random.Random(seed)
    model_done, model_dropped = {}, set()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.log")
        log = RunLog(path, interval=0.0)
        log.append(*_begin())
        for cid, wid, flag in events:
            r = rng.random()
            if r < 0.6:
                log.append("done", cid, wid, {cid: 8})
                model_done[cid] = (wid, {cid: 8})
            elif r < 0.8:
                log.append("redo", [cid])
                model_done.pop(cid, None)
            elif flag:
                log.append("gc", [cid])
                model_dropped.add(cid)
            else:
                log.append("live", [cid])
                model_dropped.discard(cid)
        log.close()
        st_ = load_run(path)
    assert st_.done == model_done
    assert st_.dropped == model_dropped


@given(st.integers(0, 2**31 - 1), st.integers(5, 30))
@settings(max_examples=15)
def test_fingerprints_deterministic_and_shape_sensitive(seed, n):
    g1, g2 = _dag(seed, n), _dag(seed, n)
    assert graph_fingerprint(g1) == graph_fingerprint(g2)
    p1 = fuse(g1, "auto")
    p2 = fuse(g2, "auto")
    assert plan_fingerprint(p1) == plan_fingerprint(p2)
    # perturb the shape: add one node feeding nothing
    g2.add_node("extra", lambda: 0, (), {}, TaskKind.PURE, deps=[], cost=1.0)
    assert graph_fingerprint(g1) != graph_fingerprint(g2)


def test_plan_fingerprint_distinguishes_fuse_specs():
    g = _dag(3, 24)
    off = fuse(g, "off")
    auto = fuse(g, "auto")
    if off.members != auto.members:
        assert plan_fingerprint(off) != plan_fingerprint(auto)


# ------------------------------------------------- flat-in-workers claim

def test_bytes_per_completion_flat_in_worker_count(tmp_path):
    """Design constraint #1: the hot-path record is a delta keyed by the
    completion event, so doubling the worker count must not change the
    bytes written per cluster (beyond the one-off adoption records)."""
    per_done = {}
    for n_workers in (2, 64):
        path = _log(tmp_path, f"w{n_workers}")
        log = RunLog(path, interval=0.0)
        log.append(*_begin())
        for w in range(n_workers):
            log.append("worker", w, f"host{w}")
        log.flush()
        adoption = log.bytes_written
        for cid in range(200):
            log.append("done", cid, cid % n_workers, {cid: 128})
        log.close()
        per_done[n_workers] = (log.bytes_written - adoption) / 200
    assert per_done[64] <= per_done[2] * 1.05
