"""Multi-process ClusterExecutor: differential vs the sequential oracle,
SIGKILL lineage recovery, GC-mode deep recovery, elastic join, futures.

Task payloads are cheap deterministic integer arithmetic so 200+-node DAGs
run in seconds; fork-started workers inherit the graph (no pickling of
closures needed).
"""
import random

import pytest

from _propcheck import given, settings, st

from repro.core import (TaskGraph, TaskKind, execute_sequential,
                        make_executor, run_graph, Executor, TaskFailed,
                        recovery_plan, trace, io_task)
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, gather


def exec_dag(seed: int, n: int, p: float) -> TaskGraph:
    """Random DAG whose nodes do real (cheap, deterministic) arithmetic."""
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


# ------------------------------------------------------------ differential

def test_cluster_matches_sequential_on_200_node_dag():
    """Acceptance: >=2 process workers, 200+-node random DAG, bit-identical
    to the sequential oracle."""
    g = exec_dag(42, 220, 0.25)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3)
    assert ex.run(g) == seq
    assert ex.stats["recomputed"] == 0
    assert ex.stats["dispatched"] >= 220


@given(st.tuples(st.integers(0, 5000), st.integers(2, 60),
                 st.floats(0.0, 0.5)), st.integers(2, 4))
@settings(max_examples=8, deadline=None)
def test_cluster_matches_sequential_random(params, workers):
    seed, n, p = params
    g = exec_dag(seed, n, p)
    assert ClusterExecutor(workers).run(g) == execute_sequential(g)


def test_cluster_satisfies_executor_protocol_and_run_graph():
    g = exec_dag(3, 30, 0.3)
    ex = make_executor("process", 2)
    assert isinstance(ex, Executor)
    assert run_graph(g, n_workers=2, backend="process") == \
        execute_sequential(g)
    with pytest.raises(ValueError):
        make_executor("quantum", 2)


def test_cluster_inputs_and_io_ordering():
    """placeholder inputs resolve in workers; token edges still order IO."""
    from repro.core import placeholder, task

    @task(cost=0.1)
    def double(x):
        return x * 2

    @io_task(cost=0.1)
    def log(x):
        return x + 1

    def driver():
        x = placeholder("x")
        a = log(double(x))
        b = log(a)          # token edge: must run after the first log
        return b

    g, _ = trace(driver)
    seq = execute_sequential(g, inputs={"x": 21})
    assert ClusterExecutor(2).run(g, inputs={"x": 21}) == seq
    # missing-input contract matches the thread/sequential backends:
    # MissingInput is a caller error, never wrapped in TaskFailed
    from repro.core.executor import MissingInput
    with pytest.raises(MissingInput):
        ClusterExecutor(2).run(g)


def test_cluster_task_failure_propagates():
    g = TaskGraph()

    def boom():
        raise ValueError("worker-side failure")

    g.add_node("boom", boom, (), {}, TaskKind.PURE, deps=())
    g.mark_output(0)
    with pytest.raises(TaskFailed, match="boom"):
        ClusterExecutor(2).run(g)


# ------------------------------------------------------- lineage recovery

def test_sigkill_recovery_matches_oracle_and_plan_size():
    """Acceptance: SIGKILL one worker mid-run; results still match and
    stats['recomputed'] equals the lineage recovery-plan size, which the
    test recomputes independently from the recorded loss event."""
    g = exec_dag(123, 200, 0.25)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, fail_worker=(1, 5))
    assert ex.run(g) == seq
    assert ex.stats["failures"] == 1
    assert len(ex.recovery_events) >= 1
    total_plan = 0
    for ev in ex.recovery_events:
        # the executor's plan is exactly lineage.recovery_plan of what died
        assert ev["plan"] == recovery_plan(g, ev["needed"], ev["available"])
        # full-results mode: every lost value is needed, so plan == lost
        assert ev["plan"] == ev["lost"]
        total_plan += len(ev["plan"])
    assert ex.stats["recomputed"] == total_plan > 0


def test_outputs_only_gc_recovers_dropped_ancestors():
    """In outputs_only mode intermediates are GC'd once consumed, so a kill
    forces recovery THROUGH dropped ancestors: plan ⊇ needed, and the plan
    still matches recovery_plan exactly."""
    g = exec_dag(5, 150, 0.25)
    seq = execute_sequential(g)
    want = {t: seq[t] for t in g.outputs}
    ex = ClusterExecutor(3, outputs_only=True, fail_worker=(0, 8))
    res = ex.run(g)
    assert res == want
    assert ex.stats["dropped"] > 0
    assert ex.stats["failures"] == 1
    for ev in ex.recovery_events:
        assert ev["plan"] == recovery_plan(g, ev["needed"], ev["available"])
    assert ex.stats["recomputed"] == \
        sum(len(ev["plan"]) for ev in ex.recovery_events)


def test_two_failures_still_recover():
    g = exec_dag(9, 120, 0.3)
    seq = execute_sequential(g)
    ex = ClusterExecutor(4, fail_worker=(2, 3))
    assert ex.run(g) == seq
    ex2 = ClusterExecutor(3, fail_worker=(0, 10))
    assert ex2.run(g) == seq


def test_organic_worker_death_recovers(tmp_path):
    """A worker that dies WITHOUT the driver killing it (the task SIGKILLs
    its own process mid-execution) must be detected via the pipe EOF /
    liveness check and recovered — the un-injected failure path."""
    import os
    import signal

    flag = tmp_path / "already-died"

    def suicide(x):
        if not flag.exists():
            flag.write_text("1")
            os.kill(os.getpid(), signal.SIGKILL)
        return x + 1

    g = TaskGraph()
    g.add_node("a", lambda: 10, (), {}, TaskKind.PURE, deps=())
    g.add_node("kill", suicide, (_Ref(0),), {}, TaskKind.PURE, deps=[0])
    for i in range(2, 12):

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 3) % 997

        g.add_node(f"t{i}", fn, (_Ref(i - 1),), {}, TaskKind.PURE,
                   deps=[i - 1])
    g.mark_output(11)
    ex = ClusterExecutor(2)
    res = ex.run(g)
    assert ex.stats["failures"] == 1
    # safe now: the flag exists, so the oracle's suicide() just returns
    assert res == execute_sequential(g)


# ------------------------------------------------------------- elasticity

def test_elastic_join_mid_run():
    g = exec_dag(11, 150, 0.2)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, join_after=(30, 2))
    assert ex.run(g) == seq
    assert ex.stats["joins"] == 2


def test_kill_then_elastic_replacement():
    g = exec_dag(13, 150, 0.2)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, fail_worker=(0, 5), join_after=(40, 1))
    assert ex.run(g) == seq
    assert ex.stats["failures"] == 1
    assert ex.stats["joins"] == 1


# ---------------------------------------------------------------- futures

def test_submit_gather_two_graphs():
    g1, g2 = exec_dag(21, 60, 0.3), exec_dag(22, 60, 0.3)
    f1 = ClusterExecutor(2).submit(g1, label="g1")
    f2 = ClusterExecutor(2).submit(g2, label="g2")
    r1, r2 = gather(f1, f2, timeout=120)
    assert r1 == execute_sequential(g1)
    assert r2 == execute_sequential(g2)
    assert f1.done() and f2.done()


def test_submit_twice_same_executor_serializes_safely():
    """Two submissions to ONE executor queue behind its run lock and both
    still match the oracle (stats are per-run, so runs may not overlap)."""
    g1, g2 = exec_dag(31, 50, 0.3), exec_dag(32, 50, 0.3)
    ex = ClusterExecutor(2)
    f1, f2 = ex.submit(g1), ex.submit(g2)
    r1, r2 = gather(f1, f2, timeout=120)
    assert r1 == execute_sequential(g1)
    assert r2 == execute_sequential(g2)


def test_future_carries_error():
    g = TaskGraph()
    g.add_node("bad", lambda: 1 / 0, (), {}, TaskKind.PURE, deps=())
    g.mark_output(0)
    f = ClusterExecutor(2).submit(g)
    assert isinstance(f.exception(timeout=60), TaskFailed)
    with pytest.raises(TaskFailed):
        f.result(1)


# ------------------------------------------------------- driver restart

def _ckpt_run(tmp_path, g, kill_after, workers=3, **kw):
    """Run until the emulated driver SIGKILL fires; return the run id."""
    from repro.cluster import DriverKilled
    ex = ClusterExecutor(workers, checkpoint_dir=str(tmp_path),
                         checkpoint_interval=0.0, fail_driver=kill_after,
                         **kw)
    with pytest.raises(DriverKilled):
        ex.run(g)
    assert ex.run_id
    return ex.run_id


def test_driver_kill_then_resume_matches_oracle(tmp_path):
    """Tentpole acceptance (pipe channel): kill the driver mid-run, resume
    a NEW executor from the run log, results bit-identical to the oracle
    with bounded recomputation — at most one driver-outage recovery pass,
    and its plan is exactly what lineage says the checkpoint was missing."""
    g = exec_dag(77, 200, 0.25)
    seq = execute_sequential(g)
    run_id = _ckpt_run(tmp_path, g, kill_after=25)

    ex2 = ClusterExecutor(3, checkpoint_dir=str(tmp_path), resume=run_id)
    assert ex2.run(g) == seq
    assert ex2.stats["resumed_clusters"] > 0
    outage = [e for e in ex2.recovery_events if e["worker"] == "driver-outage"]
    assert len(outage) <= 1
    for ev in outage:
        assert ev["plan"] == recovery_plan(g, ev["needed"], ev["available"])


def test_driver_kill_resume_with_fusion_and_gc(tmp_path):
    """Same drill with fused clusters + outputs_only GC: the log's redo /
    gc / live records must reconcile (a resumed run may have to recompute
    THROUGH values the first incarnation legitimately dropped)."""
    g = exec_dag(88, 180, 0.25)
    seq = execute_sequential(g)
    run_id = _ckpt_run(tmp_path, g, kill_after=12, fuse="auto",
                       outputs_only=True)
    ex2 = ClusterExecutor(3, checkpoint_dir=str(tmp_path), resume=run_id,
                          fuse="auto", outputs_only=True)
    got = ex2.run(g)
    assert got == {t: seq[t] for t in got}
    assert set(g.outputs) <= set(got)


def test_resume_validates_graph_fingerprint(tmp_path):
    g = exec_dag(5, 60, 0.3)
    run_id = _ckpt_run(tmp_path, g, kill_after=5)
    other = exec_dag(6, 61, 0.3)            # different shape, same fuse
    ex2 = ClusterExecutor(3, checkpoint_dir=str(tmp_path), resume=run_id)
    with pytest.raises(ValueError, match="does not match the interrupted"):
        ex2.run(other)


def test_resume_requires_checkpoint_dir_and_fail_driver_validates():
    with pytest.raises(ValueError):
        ClusterExecutor(2, resume="abc123")
    with pytest.raises(ValueError):
        ClusterExecutor(2, checkpoint_dir="/tmp", fail_driver=0)


def test_fresh_run_with_checkpointing_is_bit_identical(tmp_path):
    """Checkpointing on, no crash: the log must be write-only overhead —
    same results, no recomputation, and the log replays to a complete
    claim set (every cluster claimed done, nothing left dropped)."""
    from repro.checkpoint.runlog import load_run
    import os
    g = exec_dag(9, 120, 0.3)
    ex = ClusterExecutor(3, checkpoint_dir=str(tmp_path),
                         checkpoint_interval=0.0)
    assert ex.run(g) == execute_sequential(g)
    assert ex.stats["recomputed"] == 0
    st_ = load_run(os.path.join(str(tmp_path), f"{ex.run_id}.log"))
    claimed = {t for _, sizes in st_.done.values() for t in sizes}
    assert claimed | st_.dropped >= set(g.nodes)


def test_sim_driver_kill_deterministic_and_counts_outage_deaths():
    """64-worker what-if: a driver outage that also takes 2 workers down.
    The model must be deterministic (same seed, same makespan/recompute)
    and charge exactly the outage deaths as failures."""
    from repro.core.simulator import ClusterSim
    from test_scheduler import random_dag
    g = random_dag(11, 400, 0.2)
    kw = dict(driver_kill=g.total_work() / 200, driver_dead_workers=[1, 2],
              driver_resume_latency=2.0, seed=7)
    a = ClusterSim(g, 64, **kw).run()
    b = ClusterSim(g, 64, **kw).run()
    assert a.makespan == b.makespan and a.n_recomputed == b.n_recomputed
    assert a.n_failures == 2
    marks = [m for _, m in a.timeline]
    assert "driver killed" in marks and "driver resumed" in marks
    assert sum("(outage)" in m for m in marks) == 2
    # the outage must cost wall-clock: no-kill baseline is strictly faster
    base = ClusterSim(g, 64, seed=7).run()
    assert a.makespan > base.makespan


def test_resume_with_torn_checkpoint_tail_replays_via_lineage(tmp_path):
    """A SIGKILL mid-fsync leaves a torn final record: the resume loader
    truncates it and the claims it lost are simply recomputed — a
    performance cost, never a correctness one."""
    import os
    g = exec_dag(44, 160, 0.25)
    seq = execute_sequential(g)
    run_id = _ckpt_run(tmp_path, g, kill_after=30)
    path = os.path.join(str(tmp_path), f"{run_id}.log")
    with open(path, "ab") as f:         # torn tail: short length prefix
        f.write(b"\x00\x00\x01")
    ex2 = ClusterExecutor(3, checkpoint_dir=str(tmp_path), resume=run_id)
    assert ex2.run(g) == seq
    assert ex2.stats["resumed_clusters"] > 0
