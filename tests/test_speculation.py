"""Speculative re-execution of stragglers in ClusterExecutor.

Purity makes duplication free — these tests pin the parts that are NOT
free: winner election under both orderings, the interaction with SIGKILL
recovery (a dead original must not double-recover a task its twin still
owns), duplicate-publish reconciliation under the ``outputs_only`` GC,
disabled-by-default stats, and the policy itself — the runtime and the
discrete-event simulator share :func:`repro.core.simulator.pick_speculation`
and must agree on *which* tasks get speculated.

Straggler injection: the task's value is deterministic, but its *first*
execution (the ``O_EXCL`` sentinel creator) sleeps — a speculative twin
launched after the original is asleep sees the sentinel and returns fast.
Non-straggler tasks sleep a small base duration so the runtime EWMA
calibration sees realistic expected durations.
"""
import os
import time

import pytest

from repro.core import TaskGraph, TaskKind, execute_sequential
from repro.core.simulator import ClusterSim, WorkerEvent, pick_speculation
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor


def add_sleep_task(g: TaskGraph, name: str, deps, sleep_s: float,
                   salt: int) -> int:
    def fn(*xs, _s=sleep_s, _salt=salt):
        if _s:
            time.sleep(_s)
        return (_salt + sum(xs) * 7) % 1_000_003

    return g.add_node(name, fn, tuple(_Ref(d) for d in deps), {},
                      TaskKind.PURE, deps=list(deps), cost=1.0)


def add_straggler(g: TaskGraph, name: str, deps, marker_dir: str,
                  creator_sleep: float, twin_sleep: float,
                  salt: int) -> int:
    """First execution (sentinel creator) sleeps ``creator_sleep``; any
    re-execution sleeps ``twin_sleep``.  The value is identical either
    way."""
    path = os.path.join(marker_dir, f"straggler-{name}")

    def fn(*xs, _p=path, _c=creator_sleep, _t=twin_sleep, _salt=salt):
        try:
            fd = os.open(_p, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            fd = -1
        if fd >= 0:
            os.close(fd)
            time.sleep(_c)
        elif _t:
            time.sleep(_t)
        return (_salt + sum(xs) * 7) % 1_000_003

    return g.add_node(name, fn, tuple(_Ref(d) for d in deps), {},
                      TaskKind.PURE, deps=list(deps), cost=1.0)


def spec_stats(ex) -> dict:
    return {k: v for k, v in ex.stats.items() if "spec" in k}


# ------------------------------------------------------------------ policy

def test_pick_speculation_fixed_trace():
    """The shared policy on a hand-written trace: most-overdue wins, ties
    to the lower tid, nothing under the threshold."""
    running = {7: (3.0, 1.0),    # 3.0x overdue
               2: (9.0, 1.0),    # 9.0x — most overdue
               5: (1.0, 1.0)}    # on time
    assert pick_speculation(running, 2.0) == 2
    assert pick_speculation(running, 10.0) is None
    assert pick_speculation({}, 1.0) is None
    # equal overdue ratios: deterministic tie to the lower tid
    assert pick_speculation({4: (6.0, 1.0), 9: (6.0, 1.0)}, 2.0) == 4
    # at-threshold is NOT overdue (strictly greater, like the simulator)
    assert pick_speculation({1: (2.0, 1.0)}, 2.0) is None


def test_sim_and_runtime_agree_on_speculated_set(tmp_path):
    """Cross-validation hook: same policy knobs, same graph shape — the
    simulator and the real executor must speculate on the SAME task."""
    # simulator: 8 unit-cost sources, worker 0 permanently 50x slow -> its
    # first task (t0) is the straggler; worker 1 drains everything else,
    # idles, and the shared policy picks t0
    gs = TaskGraph()
    for i in range(8):
        add_sleep_task(gs, f"t{i}", (), 0.0, i)
    gs.mark_output(7)
    sim = ClusterSim(gs, 2, worker_speed=[0.02, 1.0],
                     speculate_after=3.0, seed=0).run()
    assert sim.n_speculative >= 1
    assert sim.speculated == {0}

    # runtime: the same 8 sources with t0 as the injected straggler;
    # pipeline_depth=1 so nothing queues behind the sleeping original
    # (head-of-line tasks are legitimately speculatable, but here we pin
    # the policy pick, not the queueing behaviour)
    gr = TaskGraph()
    add_straggler(gr, "t0", (), str(tmp_path), 1.5, 0.0, 0)
    for i in range(1, 8):
        add_sleep_task(gr, f"t{i}", (), 0.1, i)
    gr.mark_output(7)
    ex = ClusterExecutor(2, speculate_after=3.0, pipeline_depth=1,
                         progress_timeout=60.0)
    got = ex.run(gr)
    ex.close()
    assert got == execute_sequential(gs)    # same values, sleep-free graph
    assert {e["tid"] for e in ex.speculation_events} == sim.speculated


# -------------------------------------------------------- winner election

def test_twin_wins_and_result_is_oracle(tmp_path):
    """Ordering 1: the original straggles, the twin (seeing the sentinel)
    finishes first and wins; the late original is reconciled, not raised."""
    g = TaskGraph()
    calib = add_sleep_task(g, "calib", (), 0.1, 1)
    strag = add_straggler(g, "strag", (), str(tmp_path), 1.2, 0.0, 2)
    for j in range(4):
        add_sleep_task(g, f"c{j}", (calib, strag), 0.05, 10 + j)
    g.mark_output(5)
    seq = execute_sequential(g)     # consumes tmp_path's sentinel...
    os.unlink(os.path.join(str(tmp_path), "straggler-strag"))  # ...reset

    ex = ClusterExecutor(2, speculate_after=2.0, progress_timeout=60.0)
    got = ex.run(g)
    ex.close()
    assert got == seq
    assert ex.stats["n_speculative"] >= 1, spec_stats(ex)
    assert ex.stats["speculative_wins"] >= 1, spec_stats(ex)


def test_original_wins_twin_is_wasted(tmp_path):
    """Ordering 2: the 'straggler' is merely slow-ish and finishes first;
    the twin (launched strictly later, same fixed duration) loses and its
    work is accounted as waste."""
    g = TaskGraph()
    calib = add_sleep_task(g, "calib", (), 0.15, 1)
    slow = add_sleep_task(g, "slow", (), 1.0, 2)    # fixed sleep, no sentinel
    add_sleep_task(g, "c0", (calib, slow), 0.05, 3)
    g.mark_output(2)
    seq = execute_sequential(g)

    ex = ClusterExecutor(2, speculate_after=2.0, progress_timeout=60.0)
    got = ex.run(g)
    ex.close()
    assert got == seq
    assert ex.stats["n_speculative"] >= 1, spec_stats(ex)
    assert ex.stats["speculative_wins"] == 0, spec_stats(ex)
    assert ex.stats["speculative_wasted_s"] > 0.0, spec_stats(ex)


# ------------------------------------------------- SIGKILL mid-speculation

def test_sigkill_original_while_twin_runs_no_double_recovery(tmp_path):
    """SIGKILL the original's worker while the twin runs: the survivor
    owns the task — no lineage recompute, no re-queue, exactly one
    effective execution."""
    g = TaskGraph()
    calib = add_sleep_task(g, "calib", (), 0.1, 1)
    strag = add_straggler(g, "strag", (), str(tmp_path), 3.0, 0.5, 2)
    add_sleep_task(g, "c0", (calib, strag), 0.05, 3)
    last = add_sleep_task(g, "c1", (calib, strag), 0.05, 4)
    g.mark_output(last)
    gs = TaskGraph()                  # sleep-free twin graph: the oracle
    add_sleep_task(gs, "calib", (), 0.0, 1)
    add_sleep_task(gs, "strag", (), 0.0, 2)
    add_sleep_task(gs, "c0", (0, 1), 0.0, 3)
    add_sleep_task(gs, "c1", (0, 1), 0.0, 4)
    gs.mark_output(last)
    seq = execute_sequential(gs)

    ex = ClusterExecutor(2, speculate_after=2.0, progress_timeout=60.0)
    fut = ex.submit(g)
    deadline = time.monotonic() + 20.0
    while not ex.speculation_events:
        assert time.monotonic() < deadline, "twin never launched"
        assert not fut.done(), f"run finished without speculating: " \
                               f"{fut.exception(0)}"
        time.sleep(0.005)
    ev = ex.speculation_events[0]
    assert ev["tid"] == strag
    ex.kill_worker(ev["primary"])     # original dies mid-sleep

    got = fut.result(timeout=60.0)
    ex.close()
    assert got == seq
    stats = fut.stats
    assert stats["failures"] == 1, stats
    assert stats["recomputed"] == 0, stats          # no double recovery
    assert stats["speculative_wins"] == 1, stats    # the twin's completion
    # every task ran exactly once, plus the one speculative twin
    assert stats["dispatched"] == len(g.nodes) + 1, stats


# ------------------------------------------------------------ GC + default

def test_speculation_disabled_by_default_stats_zero():
    g = TaskGraph()
    prev = add_sleep_task(g, "t0", (), 0.0, 0)
    for i in range(1, 20):
        prev = add_sleep_task(g, f"t{i}", (prev,), 0.0, i)
    g.mark_output(prev)
    ex = ClusterExecutor(2, progress_timeout=60.0)
    got = ex.run(g)
    ex.close()
    assert got == execute_sequential(g)
    assert ex.stats["n_speculative"] == 0
    assert ex.stats["speculative_wins"] == 0
    assert ex.stats["speculative_swept"] == 0
    assert ex.stats["speculative_wasted_s"] == 0.0


def test_gc_mode_sweeps_loser_publish(tmp_path):
    """``outputs_only=True``: the straggler's value is consumed and
    GC-dropped while the loser is still asleep; the loser's late publish
    must be swept (the worker told to drop it), never resurrected as a
    replica of a collected value."""
    g = TaskGraph()
    calib = add_sleep_task(g, "calib", (), 0.05, 1)
    strag = add_straggler(g, "strag", (), str(tmp_path), 0.8, 0.0, 2)
    c = add_sleep_task(g, "consume", (calib, strag), 0.05, 3)
    prev = c
    for i in range(6):                # tail keeps the run alive past the
        prev = add_sleep_task(g, f"tail{i}", (prev,), 0.15, 10 + i)
    g.mark_output(prev)               # loser's wake-up at 0.8s
    gs_oracle = execute_sequential(g)     # consumes the sentinel...
    os.unlink(os.path.join(str(tmp_path), "straggler-strag"))  # ...reset

    ex = ClusterExecutor(2, outputs_only=True, speculate_after=2.0,
                         progress_timeout=60.0)
    got = ex.run(g)
    ex.close()
    assert got == {prev: gs_oracle[prev]}
    assert ex.stats["speculative_wins"] >= 1, spec_stats(ex)
    assert ex.stats["dropped"] >= 1, ex.stats
    assert ex.stats["speculative_swept"] >= 1, spec_stats(ex)


def test_speculate_after_validation():
    with pytest.raises(ValueError):
        ClusterExecutor(2, speculate_after=0.0)
    with pytest.raises(ValueError):
        ClusterExecutor(2, speculate_after=-1.5)


# -------------------------------------------- cooperative mid-task cancel

def _append_marker(x, _p=None):
    with open(_p, "ab") as f:       # one byte per execution
        f.write(b"x")
    return (x * 7 + 5) % 1_000_003


def test_cancel_aborts_fused_loser_at_member_boundary(tmp_path):
    """A speculation loser running a FUSED chain honors the cancel between
    members: the original straggles inside the first member, the twin wins
    the whole chain, and the loser aborts at the boundary — the tail
    member never executes a second time (counted via an append-only
    side-channel) and the abandoned partial wall is charged to
    ``speculative_wasted_s``."""
    from functools import partial
    marker = os.path.join(str(tmp_path), "tail-runs")

    g = TaskGraph()
    calib = add_sleep_task(g, "calib", (), 0.1, 1)
    strag = add_straggler(g, "strag", (), str(tmp_path), 2.5, 0.05, 2)
    from repro.core.tracing import RemappedRef
    tail = g.add_node("tail", partial(_append_marker, _p=marker),
                      (RemappedRef(strag),), {}, TaskKind.PURE,
                      deps=[strag], cost=1.0)
    for j in range(4):              # fan-out: keeps strag+tail a pair
        add_sleep_task(g, f"c{j}", (calib, tail), 0.05, 10 + j)
    g.mark_output(6)
    seq = execute_sequential(g)     # consumes the sentinel + marker...
    os.unlink(os.path.join(str(tmp_path), "straggler-strag"))  # ...reset
    os.unlink(marker)

    ex = ClusterExecutor(2, fuse="auto", speculate_after=2.0,
                         progress_timeout=60.0)
    got = ex.run(g)
    ex.close()
    assert got == seq
    assert ex.stats["tasks_fused"] >= 1         # the chain really fused
    assert ex.stats["n_speculative"] >= 1, spec_stats(ex)
    assert ex.stats["speculative_wins"] >= 1, spec_stats(ex)
    # the loser aborted before its tail member: exactly one execution
    # (the winner's) wrote the marker
    assert os.path.getsize(marker) == 1
    assert ex.stats["speculative_wasted_s"] > 0.0, spec_stats(ex)
