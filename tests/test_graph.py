"""TaskGraph IR + tracing unit tests (incl. fusion and purity inference)."""
import jax.numpy as jnp
import pytest

from repro.core import (task, io_task, trace, placeholder, TaskGraph,
                        GraphError, fuse_cheap_chains, execute_sequential,
                        infer_purity, checkpoint_barrier)


@task(cost=1.0)
def f(x):
    return x + 1


@task(cost=1.0)
def g(x):
    return x * 2


@task(cost=10.0)
def big(x):
    return x - 3


def test_topo_and_cycle_detection():
    gr = TaskGraph()
    a = gr.add_node("a", lambda: 1, (), {}, kind=__import__(
        "repro.core.graph", fromlist=["TaskKind"]).TaskKind.PURE,
        deps=())
    with pytest.raises(GraphError):
        gr.add_node("b", None, (), {}, kind=gr.nodes[a].kind, deps=(99,))


def test_trace_builds_linear_chain_and_fusion():
    def driver(x0):
        return big(f(g(f(x0))))

    graph, _ = trace(driver, 5)
    assert len(graph) == 4
    fused = fuse_cheap_chains(graph, threshold=5.0)
    # f,g,f fuse into one node; big stays
    assert len(fused) == 2
    r1 = execute_sequential(graph)[graph.outputs[0]]
    r2 = execute_sequential(fused)[fused.outputs[0]]
    assert r1 == r2 == ((5 + 1) * 2 + 1) - 3


def test_fusion_preserves_driver_outputs():
    def driver(x0):
        a = f(x0)          # also an output: must not be fused past
        b = g(a)
        return a, b

    graph, _ = trace(driver, 3)
    fused = fuse_cheap_chains(graph, threshold=5.0)
    ra = execute_sequential(fused)
    vals = sorted(ra[t] for t in fused.outputs)
    assert vals == [4, 8]


def test_critical_path_and_parallelism():
    def driver():
        xs = [f(i) for i in range(8)]
        return g(sum_task(*xs))

    @task(cost=2.0, name="sum")
    def sum_task(*xs):
        return sum(xs)

    graph, _ = trace(driver)
    assert graph.total_work() == pytest.approx(8 * 1.0 + 2.0 + 1.0)
    assert graph.critical_path_length() == pytest.approx(1 + 2 + 1)
    assert graph.max_parallelism() == pytest.approx(11.0 / 4.0)


def test_placeholder_inputs():
    def driver():
        x = placeholder("x")
        return f(x)

    graph, _ = trace(driver)
    res = execute_sequential(graph, inputs={"x": 10})
    assert res[graph.outputs[0]] == 11
    with pytest.raises(KeyError):
        execute_sequential(graph, inputs={})


def test_purity_inference_from_jaxpr():
    def pure_fn(x):
        return jnp.sin(x) * 2

    def impure_fn(x):
        jax.debug.print("side effect {}", x)   # ordered effect in jaxpr
        return x

    import jax
    assert infer_purity(pure_fn, jnp.ones(3))
    assert not infer_purity(impure_fn, jnp.ones(3))


def test_effect_token_chain_orders_all_io():
    order = []

    @io_task
    def io1():
        order.append(1)

    @io_task
    def io2():
        order.append(2)

    @io_task
    def io3():
        order.append(3)

    def driver():
        a = io1()
        b = io2()
        c = io3()
        return c

    graph, _ = trace(driver)
    toks = [n for n in graph if n.token_deps]
    assert len(toks) == 2               # io2 after io1, io3 after io2
    execute_sequential(graph)
    assert order == [1, 2, 3]


def test_barrier_node():
    def driver(x0):
        a = f(x0)
        cp = checkpoint_barrier(a)
        return g(cp)

    graph, _ = trace(driver, 1)
    kinds = [n.kind.value for n in graph]
    assert "barrier" in kinds
    res = execute_sequential(graph)
    assert res[graph.outputs[0]] == 4


def test_dot_export():
    graph, _ = trace(lambda: g(f(1)))
    dot = graph.to_dot()
    assert "digraph" in dot and "->" in dot
