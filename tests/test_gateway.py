"""Multi-tenant gateway differentials: concurrent tenants on one shared
resident pool, each bit-for-bit equal to the sequential oracle; typed
quota rejection; fault and disconnect isolation between tenants; session
restore from the run log.

Graph node fns must survive pickling into the gateway process, so the
DAGs come from ``test_multihost.picklable_dag`` (partial over
module-level fns)."""
import pickle
import threading
import time
from functools import partial

import pytest

import repro
from repro.config import ClusterConfig
from repro.core.graph import TaskGraph, TaskKind
from repro.core.executor import execute_sequential, run_graph
from repro.core.tracing import RemappedRef as _Ref
from repro.gateway import (GatewayService, GatewayError, QuotaExceeded,
                           SessionClosed, TenantQuota, connect)

from test_multihost import _mh_combine, picklable_dag, results_equal

TOKEN = "gw-test-token"


@pytest.fixture(scope="module")
def gateway():
    """One shared 2-worker gateway for the whole module; each test uses
    its own tenant names so accounting stays independent."""
    cfg = ClusterConfig(n_workers=2, token=TOKEN, fuse="auto",
                        progress_timeout=60.0)
    gw = GatewayService(cfg, quotas={
        "tiny": TenantQuota(max_inflight_clusters=1),
        "thin": TenantQuota(max_store_bytes=10),
    }).start()
    yield gw
    gw.stop()


# ------------------------------------------------- concurrent tenants

def test_two_tenants_concurrent_bit_for_bit(gateway):
    """Two tenants hammer the shared pool from separate sessions; every
    result must equal the sequential oracle for that tenant's graph."""
    ga = picklable_dag(1, 40, 0.3)
    gb = picklable_dag(2, 35, 0.35)
    seq_a, seq_b = execute_sequential(ga), execute_sequential(gb)
    out, errs = {}, []

    def tenant(name, g, priority):
        try:
            with connect(gateway.address, token=TOKEN, tenant=name,
                         priority=priority) as c:
                futs = [c.submit(g, label=f"{name}{i}") for i in range(3)]
                out[name] = [f.result(60) for f in futs]
                out[name + "_stats"] = futs[0].stats
        except BaseException as e:       # surface into the test thread
            errs.append(e)

    threads = [threading.Thread(target=tenant, args=("alpha", ga, 1.0)),
               threading.Thread(target=tenant, args=("beta", gb, 2.0))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(90)
    assert not errs, errs
    assert all(results_equal(r, seq_a) for r in out["alpha"])
    assert all(results_equal(r, seq_b) for r in out["beta"])
    st = out["beta_stats"]
    assert st["tenant"] == "beta"
    assert st["submit_to_gather_s"] >= st["submit_to_first_dispatch_s"] >= 0

    s = gateway.stats()
    assert s["alpha"]["completed"] >= 3 and s["beta"]["completed"] >= 3
    slo = s["beta"]["slo"]["submit_to_gather_s"]
    assert slo["p50"] is not None and slo["p99"] >= slo["p50"]
    assert "pool" in s and s["pool"]["n_workers"] == 2


def test_run_graph_connect_oneliner(gateway):
    g = picklable_dag(3, 25, 0.3)
    res, rep = run_graph(g, connect=gateway.address, token=TOKEN,
                         with_report=True)
    assert results_equal(res, execute_sequential(g))
    assert rep["backend"] == "gateway"
    assert rep["stats"]["submit_to_gather_s"] > 0


# ----------------------------------------------------- admission gate

def test_cluster_quota_is_a_typed_client_error(gateway):
    """Over-quota submits come back as QuotaExceeded with the admission
    attributes intact — not a stringly RuntimeError."""
    with connect(gateway.address, token=TOKEN, tenant="tiny") as c:
        fut = c.submit(picklable_dag(4, 10, 0.0))
        err = fut.exception(30)
        assert isinstance(err, QuotaExceeded), err
        assert err.tenant == "tiny"
        assert err.resource == "inflight_clusters"
        assert err.limit == 1 and err.requested > 1
        # the typed error survives another pickle hop (supervisors relay)
        again = pickle.loads(pickle.dumps(err))
        assert isinstance(again, QuotaExceeded) and again.limit == 1
    assert gateway.stats()["tiny"]["rejected"] >= 1
    assert gateway.stats()["tiny"]["inflight_clusters"] == 0


def test_store_bytes_quota_uses_declared_bytes(gateway):
    g = TaskGraph()
    g.add_node("big", partial(_mh_combine, 9), (), {}, TaskKind.PURE,
               deps=(), out_bytes=1 << 20)
    g.mark_output(0)
    with connect(gateway.address, token=TOKEN, tenant="thin") as c:
        err = c.submit(g).exception(30)
        assert isinstance(err, QuotaExceeded), err
        assert err.resource == "store_bytes" and err.limit == 10


def test_pool_level_knob_rejected_before_unpickle(gateway):
    """A submit smuggling a non-TENANT_FIELDS option is refused server
    side (forged on the wire: the client API never sends one)."""
    from repro.cluster.channel import _send_frame
    from repro.cluster.futures import ClusterFuture

    with connect(gateway.address, token=TOKEN, tenant="alpha") as c:
        fut = ClusterFuture("forged")
        with c._lock:
            c._pending[9999] = fut
        blob = pickle.dumps((picklable_dag(5, 4, 0.0), {}), protocol=5)
        _send_frame(c._sock,
                    pickle.dumps(("submit", 9999, blob,
                                  {"transport": "tcp"}), protocol=5),
                    lock=c._send_lock)
        err = fut.exception(30)
        assert isinstance(err, GatewayError), err
        assert "not tenant-settable" in str(err)


# ------------------------------------------------------- isolation

def test_disconnect_cancels_only_that_tenants_jobs(gateway):
    """A hard socket drop (no bye) fails the dropper's futures with
    SessionClosed and must not perturb the surviving tenant."""
    g_fast = picklable_dag(6, 30, 0.3)
    seq = execute_sequential(g_fast)
    c1 = connect(gateway.address, token=TOKEN, tenant="dropper")
    c2 = connect(gateway.address, token=TOKEN, tenant="stayer")
    try:
        f1 = c1.submit(picklable_dag(7, 60, 0.2, slow=True))
        f2 = c2.submit(g_fast)
        c1._sock.close()                       # hard drop, no bye
        assert results_equal(f2.result(60), seq), "survivor perturbed"
        assert isinstance(f1.exception(10), SessionClosed)
    finally:
        c2.close()
        c1.close()
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:         # server cancel is async
        if gateway.stats()["dropper"]["inflight_jobs"] == 0:
            break
        time.sleep(0.05)
    assert gateway.stats()["dropper"]["inflight_jobs"] == 0


def test_sigkilled_worker_task_does_not_perturb_other_tenant():
    """The acceptance differential: one tenant's task dies with the
    worker (SIGKILL mid-run); both tenants still gather bit-for-bit."""
    cfg = ClusterConfig(n_workers=2, token=TOKEN, progress_timeout=60.0)
    ga = picklable_dag(8, 30, 0.3, slow=True)   # victim: long enough to hit
    gb = picklable_dag(9, 30, 0.3)
    seq_a, seq_b = execute_sequential(ga), execute_sequential(gb)
    with GatewayService(cfg) as gw:
        with connect(gw.address, token=TOKEN, tenant="victim") as ca, \
                connect(gw.address, token=TOKEN, tenant="bystander") as cb:
            fa = ca.submit(ga)
            fbs = [cb.submit(gb) for _ in range(2)]
            deadline = time.monotonic() + 20
            while time.monotonic() < deadline:  # wait until work is live
                st = gw.stats().get("victim", {})
                if st.get("inflight_clusters", 0) > 0:
                    break
                time.sleep(0.02)
            gw.executor.kill_worker(1)          # SIGKILL mid-run
            assert results_equal(fa.result(120), seq_a)
            assert all(results_equal(f.result(120), seq_b) for f in fbs)
        s = gw.stats()
        assert s["victim"]["failed"] == 0       # recovered, not failed
        assert s["bystander"]["failed"] == 0


# --------------------------------------------------------- restore

def test_resume_restores_sessions_on_a_fresh_run(tmp_path):
    """Open sessions journal to the run log; a gateway restarted with
    resume= re-creates their quotas/weights on a FRESH pool run id."""
    from repro.checkpoint.runlog import latest_run, load_run

    cfg = ClusterConfig(n_workers=2, token=TOKEN,
                        checkpoint_dir=str(tmp_path),
                        checkpoint_interval=0.05)
    g = picklable_dag(10, 20, 0.3)
    seq = execute_sequential(g)

    gw1 = GatewayService(cfg, quotas={
        "alpha": TenantQuota(max_inflight_clusters=64)}).start()
    c_open = connect(gw1.address, token=TOKEN, tenant="alpha",
                     priority=3.0)
    try:
        assert results_equal(c_open.submit(g).result(60), seq)
        with connect(gw1.address, token=TOKEN, tenant="gone") as c2:
            assert results_equal(c2.submit(g).result(60), seq)
        time.sleep(0.3)              # let the sessionend record flush
    finally:
        gw1.stop()                   # crash-equivalent: no client bye
        c_open.close()

    run1 = latest_run(str(tmp_path))
    state = load_run(str(tmp_path / f"{run1}.log"))
    assert "alpha" in state.sessions            # still open at shutdown
    assert "gone" not in state.sessions         # closed cleanly
    assert state.sessions["alpha"]["quota"]["max_inflight_clusters"] == 64
    assert state.sessions["alpha"]["priority"] == 3.0
    assert not state.jobs, f"jobs should all be retired: {state.jobs}"

    with GatewayService(cfg.replace(resume=run1)) as gw2:
        s = gw2.stats()
        assert s["alpha"]["quota"]["max_inflight_clusters"] == 64
        assert gw2.executor.run_id != run1      # fresh incarnation
        with connect(gw2.address, token=TOKEN, tenant="alpha") as c:
            assert results_equal(c.submit(g).result(60), seq)
