"""Graph compilation (repro.core.fusion) + the fused cluster runtime.

Three layers of pinning:

* the **pass** — clustering rules, determinism, plan invariants, the
  identity plan's cid==tid contract, cluster-granularity lineage;
* the **runtime** — fused execution bit-identical to the sequential
  oracle on every backend×transport×channel, including under SIGKILL
  mid-super-task, with GC, and combined with speculation;
* the **control plane** — batch frames roundtrip on both channel
  families, the new observability stats exist and move the right way,
  and the same-host DualRef data-plane fast path picks by host id.
"""
import pickle
import random

import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import (TaskGraph, TaskKind, execute_sequential,
                        make_executor, run_graph)
from repro.core.fusion import (FUSABLE_KINDS, FusedPlan, fuse, identity_plan,
                               parse_fuse_spec)
from repro.core.lineage import recovery_plan, recovery_plan_clusters
from repro.core.simulator import ClusterSim
from repro.core.tracing import RemappedRef as _Ref
from repro.cluster import ClusterExecutor, serde
from repro.cluster.channel import host_id

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ------------------------------------------------------------ graph builders

def chain_graph(n: int, arrays: bool = False) -> TaskGraph:
    g = TaskGraph()
    prev = None
    for i in range(n):
        deps = [prev] if prev is not None else []
        if arrays:
            def fn(*xs, _i=i):
                base = xs[0] if xs else np.arange(256, dtype=np.float32)
                return base * np.float32(1.001) + np.float32(_i)
        else:
            def fn(*xs, _i=i):
                return (_i + sum(xs) * 7) % 1_000_003
        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps)
        prev = i
    g.mark_output(n - 1)
    return g


def exec_dag(seed: int, n: int, p: float) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]

        def fn(*xs, _i=i):
            return (_i + sum(xs) * 7) % 1_000_003

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def wide_map_graph(width: int = 64) -> TaskGraph:
    """src -> width tiny siblings -> reduce (the map shape sibling packing
    exists for)."""
    g = TaskGraph()

    def src():
        return np.arange(128, dtype=np.float32)

    g.add_node("src", src, (), {}, TaskKind.PURE, deps=())
    for i in range(width):
        def m(x, _i=i):
            return x * np.float32(_i + 1)
        g.add_node(f"m{i}", m, (_Ref(0),), {}, TaskKind.PURE, deps=(0,))

    def red(*xs):
        return float(sum(float(x.sum()) for x in xs))

    deps = list(range(1, width + 1))
    g.add_node("red", red, tuple(_Ref(d) for d in deps), {},
               TaskKind.PURE, deps=deps)
    g.mark_output(width + 1)
    return g


def pytree_shuffle_graph(producers: int = 4, consumers: int = 8) -> TaskGraph:
    """Producers emit pytrees (dict of arrays); consumers combine strided
    pairs — cross-cluster edges carry structured values."""
    g = TaskGraph()
    for i in range(producers):
        def produce(_i=i):
            return {"w": np.full((64,), np.float32(_i + 1)),
                    "b": np.arange(32, dtype=np.float32) * np.float32(_i)}
        g.add_node(f"p{i}", produce, (), {}, TaskKind.PURE, deps=())
    outs = []
    for j in range(consumers):
        deps = [j % producers, (j * 3 + 1) % producers]

        def combine(a, b, _j=j):
            return {"w": a["w"] + b["w"] + np.float32(_j), "b": a["b"] - b["b"]}

        outs.append(g.add_node(
            f"c{j}", combine, tuple(_Ref(d) for d in deps), {},
            TaskKind.PURE, deps=deps))
    for o in outs:
        g.mark_output(o)
    return g


def tree_equal(a, b) -> bool:
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and \
            all(tree_equal(a[k], b[k]) for k in a)
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                and a.dtype == b.dtype and a.shape == b.shape
                and np.array_equal(a, b))
    return a == b


def results_equal(got, want) -> bool:
    return got.keys() == want.keys() and \
        all(tree_equal(got[k], want[k]) for k in got)


# ------------------------------------------------------------------ the pass

def test_parse_fuse_spec_vocabulary():
    assert parse_fuse_spec("off") == "off"
    assert parse_fuse_spec(None) == "off"
    assert parse_fuse_spec(False) == "off"
    assert parse_fuse_spec(1) == "off"          # 1-member clusters = identity
    assert parse_fuse_spec("auto") == "auto"
    assert parse_fuse_spec(True) == "auto"
    assert parse_fuse_spec("16") == 16
    assert parse_fuse_spec(8) == 8
    with pytest.raises(ValueError):
        parse_fuse_spec("sideways")


def test_identity_plan_is_the_graph_itself():
    g = exec_dag(7, 40, 0.3)
    p = fuse(g, "off")
    assert p.identity and p.cgraph is g
    assert p.members == {t: (t,) for t in g.nodes}
    assert p.cluster_of == {t: t for t in g.nodes}
    assert p.ext_deps == {t: n.all_deps for t, n in g.nodes.items()}
    assert p.n_fused == 0
    view = p.worker_view(set(g.nodes))
    assert view.keep == view.members        # identity keeps everything


def test_chain_fuses_with_member_cap():
    g = chain_graph(100)
    p = fuse(g, "auto")
    assert p.n_clusters <= 4                    # 100 / 32-member cap
    assert max(len(m) for m in p.members.values()) <= 32
    p8 = fuse(g, 8)
    assert max(len(m) for m in p8.members.values()) <= 8
    assert p8.n_clusters >= 13
    # chain contraction loses no ordering: cgraph is a chain of clusters
    assert all(len(n.all_deps) <= 1 for n in p.cgraph.nodes.values())


def test_fusion_is_deterministic():
    g = exec_dag(11, 150, 0.25)
    a, b = fuse(g, "auto"), fuse(g, "auto")
    assert a.members == b.members
    assert a.ext_deps == b.ext_deps
    assert a.outputs == b.outputs


def test_barrier_and_io_nodes_stay_singletons():
    g = TaskGraph()
    g.add_node("a", lambda: 1, (), {}, TaskKind.PURE, deps=())
    g.add_node("io", lambda x: x, (_Ref(0),), {}, TaskKind.EFFECTFUL,
               deps=(0,))
    g.add_node("bar", lambda x: x, (_Ref(1),), {}, TaskKind.BARRIER,
               deps=(1,))
    g.add_node("b", lambda x: x + 1, (_Ref(2),), {}, TaskKind.PURE,
               deps=(2,))
    g.mark_output(3)
    p = fuse(g, "auto")
    for cid, ms in p.members.items():
        kinds = {g.nodes[m].kind for m in ms}
        if not kinds <= set(FUSABLE_KINDS):
            assert len(ms) == 1     # EFFECTFUL/BARRIER never share a cluster


def test_sibling_packing_keeps_parallelism():
    g = wide_map_graph(64)
    p = fuse(g, "auto")
    # the 64 siblings pack, but never below the parallelism floor
    depth1 = [cid for cid, ms in p.members.items()
              if any(1 <= m <= 64 for m in ms)]
    assert 8 <= len(depth1) < 64
    assert results_equal(
        {k: v for k, v in
         ClusterExecutor(2, fuse="auto").run(g).items()},
        execute_sequential(g))


@given(st.tuples(st.integers(0, 5000), st.integers(2, 80),
                 st.floats(0.0, 0.5)))
@settings(max_examples=20, deadline=None)
def test_plan_invariants_random(params):
    seed, n, p = params
    g = exec_dag(seed, n, p)
    plan = fuse(g, "auto")
    plan.cgraph.validate()
    # members partition the graph, in topo order within each cluster
    seen = [m for cid in plan.cgraph.topo_order()
            for m in plan.members[cid]]
    assert sorted(seen) == sorted(g.nodes)
    for cid, ms in plan.members.items():
        assert list(ms) == sorted(ms)
        for m in ms:
            assert plan.cluster_of[m] == cid
    # every external dep is an output of its producer cluster (the
    # invariant dispatch relies on: boundary values are always kept)
    for cid, deps in plan.ext_deps.items():
        for v in deps:
            pc = plan.cluster_of[v]
            assert pc != cid
            assert v in plan.outputs[pc]
    # cost is conserved and graph outputs stay reachable
    assert abs(plan.cgraph.total_work() - g.total_work()) < 1e-9
    assert {plan.cluster_of[o] for o in g.outputs} == set(plan.cgraph.outputs)


def test_recovery_plan_clusters_matches_task_level_on_identity():
    g = exec_dag(3, 60, 0.3)
    p = identity_plan(g)
    for needed in ({30}, {10, 45}, {59}):
        available = set(range(0, 25))
        assert recovery_plan_clusters(p, needed, available) == \
            recovery_plan(g, needed, available)


def test_recovery_plan_clusters_walks_cluster_boundaries():
    g = chain_graph(20)
    p = fuse(g, 5)
    # lose the last value with nothing else available: every cluster on
    # the lineage walk re-runs
    plan = recovery_plan_clusters(p, {19}, set())
    assert plan == set(p.cgraph.nodes)
    # with the producer cluster's boundary value available, the walk stops
    boundary = p.ext_deps[p.cluster_of[19]]
    plan2 = recovery_plan_clusters(p, {19}, set(boundary))
    assert plan2 == {p.cluster_of[19]}


def test_worker_view_is_picklable_and_minimal():
    g = chain_graph(40)
    p = fuse(g, "auto")
    view = p.worker_view(set(g.outputs))        # outputs_only shape
    blob = pickle.dumps(view, protocol=5)
    assert pickle.loads(blob).members == view.members
    for cid, keep in view.keep.items():
        assert set(keep) <= set(view.members[cid])
    # interior chain values are NOT kept; boundary + output values are
    total_kept = sum(len(k) for k in view.keep.values())
    assert total_kept < len(g.nodes)
    assert 39 in {m for ks in view.keep.values() for m in ks}


# --------------------------------------------------------------- the runtime

def test_fused_differential_200_node_dag():
    g = exec_dag(42, 220, 0.25)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, fuse="auto")
    assert ex.run(g) == seq
    assert ex.stats["tasks_fused"] > 0
    assert ex.stats["n_clusters"] < 220
    assert ex.stats["dispatched"] == ex.stats["n_clusters"]
    assert ex.stats["recomputed"] == 0


@given(st.tuples(st.integers(0, 5000), st.integers(2, 60),
                 st.floats(0.0, 0.5)), st.integers(2, 4))
@settings(max_examples=6, deadline=None)
def test_fused_matches_sequential_random(params, workers):
    seed, n, p = params
    g = exec_dag(seed, n, p)
    assert ClusterExecutor(workers, fuse="auto").run(g) == \
        execute_sequential(g)


@pytest.mark.parametrize("transport", ["shm", "sock", "driver"])
def test_fused_differential_arrays_per_transport(transport):
    if transport == "shm" and not serde.shm_available():
        pytest.skip("no shared memory in this environment")
    g = pytree_shuffle_graph()
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, fuse="auto", transport=transport,
                         shm_threshold=128)
    assert results_equal(ex.run(g), seq)
    ex2 = ClusterExecutor(2, fuse=4, transport=transport,
                          shm_threshold=128)
    assert results_equal(ex2.run(g), seq)


def test_fused_differential_tcp_channel_and_transport():
    g = chain_graph(60, arrays=True)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, fuse="auto", channel="tcp", transport="tcp",
                         shm_threshold=256)
    try:
        got = ex.run(g)
    finally:
        ex.close()
    assert results_equal(got, seq)


def _spawn_step(*xs, _i=0):
    return (_i + sum(xs) * 7) % 1_000_003


def picklable_dag(seed: int, n: int, p: float) -> TaskGraph:
    """Like exec_dag but with module-level fns (spawn workers re-import)."""
    import functools
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p][-3:]
        g.add_node(f"t{i}", functools.partial(_spawn_step, _i=i),
                   tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps)
    g.mark_output(n - 1)
    return g


def test_fused_spawn_channel_differential():
    """Spawn workers get the fusion view through pickled process args."""
    g = picklable_dag(8, 40, 0.3)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, fuse="auto", start_method="spawn",
                         progress_timeout=120.0)
    assert ex.run(g) == seq


def test_fused_outputs_only_gc():
    g = exec_dag(5, 150, 0.25)
    seq = execute_sequential(g)
    want = {t: seq[t] for t in g.outputs}
    ex = ClusterExecutor(2, fuse="auto", outputs_only=True)
    assert ex.run(g) == want
    assert ex.stats["tasks_fused"] > 0


def test_fused_sigkill_recomputes_exactly_lost_clusters():
    g = exec_dag(123, 200, 0.25)
    seq = execute_sequential(g)
    ex = ClusterExecutor(3, fuse="auto", fail_worker=(1, 3))
    assert ex.run(g) == seq
    assert ex.stats["failures"] == 1
    assert len(ex.recovery_events) >= 1
    plan = fuse(g, "auto")
    total = 0
    for ev in ex.recovery_events:
        # the executor's plan is exactly the cluster-granularity lineage
        # walk of what died, recomputed independently here
        assert ev["plan"] == recovery_plan_clusters(
            plan, ev["needed"], ev["available"])
        assert ev["plan"] <= set(plan.cgraph.nodes)
        total += len(ev["plan"])
    assert ex.stats["recomputed"] == total > 0


def test_fused_sigkill_chain_mid_super_task():
    """Chains fuse hard (few big clusters), so a SIGKILL lands mid-super-
    task almost surely; the run must still match the oracle."""
    g = chain_graph(120)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, fuse="auto", fail_worker=(0, 1))
    assert ex.run(g) == seq
    assert ex.stats["failures"] == 1
    assert ex.stats["recomputed"] > 0


def test_fused_outputs_only_sigkill():
    g = exec_dag(17, 150, 0.25)
    seq = execute_sequential(g)
    want = {t: seq[t] for t in g.outputs}
    ex = ClusterExecutor(3, fuse="auto", outputs_only=True,
                         fail_worker=(0, 4))
    assert ex.run(g) == want
    assert ex.stats["failures"] == 1


def test_fusion_with_speculation(tmp_path):
    """A straggling super-task gets a twin; first completion wins and the
    result stays oracle-equal (fusion × speculation interaction)."""
    import os as _os
    import time as _time
    marker = str(tmp_path)

    g = TaskGraph()
    for i in range(4):
        def produce(_i=i, _d=marker):
            if _i == 0:
                path = _os.path.join(_d, "straggler")
                try:
                    fd = _os.open(path,
                                  _os.O_CREAT | _os.O_EXCL | _os.O_WRONLY)
                except FileExistsError:
                    fd = -1
                if fd >= 0:
                    _os.close(fd)
                    _time.sleep(1.0)
            else:
                _time.sleep(0.05)
            return np.arange(64, dtype=np.float32) * np.float32(_i + 1)
        g.add_node(f"p{i}", produce, (), {}, TaskKind.PURE, deps=())
    outs = []
    for j in range(6):
        deps = [j % 4, (j + 1) % 4]

        def comb(a, b, _j=j):
            _time.sleep(0.05)
            return a + b * np.float32(_j)

        outs.append(g.add_node(f"c{j}", comb,
                               tuple(_Ref(d) for d in deps), {},
                               TaskKind.PURE, deps=deps))
    for o in outs:
        g.mark_output(o)
    seq = execute_sequential(g)
    ex = ClusterExecutor(2, fuse="auto", speculate_after=2.0,
                         progress_timeout=120.0)
    got = ex.run(g)
    assert results_equal(got, seq)
    # the straggler is a source: it may or may not get twinned depending
    # on timing, but the interaction must never corrupt results or hang
    assert ex.stats["n_speculative"] >= 0


# --------------------------------------------------- control plane + serde

def test_send_many_batches_on_pipe_channel():
    import multiprocessing as mp
    from repro.cluster.channel import PipeChannel, WorkerPipeEndpoint
    a, b = mp.Pipe(duplex=True)
    chan = PipeChannel(a, proc=None)
    end = WorkerPipeEndpoint(b)
    chan.send_many([("run", 1, {}), ("fetch", 2), ("drop", [3])])
    batch = end.recv()
    assert batch[0] == "batch" and len(batch[1]) == 3
    # worker -> driver batches flatten inside recv_available
    end.send(("batch", [("done", 0, 1, 0.1, {1: 8}, []),
                        ("value", 0, 2, False, None)]))
    msgs = chan.recv_available()
    assert [m[0] for m in msgs] == ["done", "value"]
    chan.send_many([("stop",)])         # single message: no batch wrapper
    assert end.recv() == ("stop",)
    chan.close()
    end.close()


def test_tcp_frame_buffer_flattens_batches():
    import pickle as _pickle
    from repro.cluster.channel import _FrameBuffer, _flatten_batches, _FRAME
    fb = _FrameBuffer()
    payload = _pickle.dumps(("batch", [("hb",), ("done", 0, 1, 0.1, {}, [])]),
                            protocol=5)
    msgs = _flatten_batches(fb.feed(_FRAME.pack(len(payload)) + payload))
    assert [m[0] for m in msgs] == ["hb", "done"]


def test_control_plane_stats_observability():
    g = chain_graph(80)
    seq = execute_sequential(g)
    _, rep_off = run_graph(g, 2, backend="process", with_report=True,
                           fuse="off")
    g2 = chain_graph(80)
    res, rep_auto = run_graph(g2, 2, backend="process", with_report=True,
                              fuse="auto")
    assert res == seq
    for rep in (rep_off, rep_auto):
        s = rep["stats"]
        assert s["control_msgs"] > 0
        assert s["control_frames"] > 0
        assert s["dispatch_overhead_s"] >= 0.0
        assert s["control_frames"] <= s["control_msgs"]
    assert rep_auto["stats"]["dispatched"] < rep_off["stats"]["dispatched"]
    assert rep_auto["stats"]["n_clusters"] < rep_off["stats"]["n_clusters"]
    assert rep_auto["stats"]["tasks_fused"] > 0


def test_fused_unpicklable_value_is_task_error_not_hang():
    """A value that executes fine but cannot be serialized surfaces as a
    SerializationError TaskFailed — via the fetch_error verb, which names
    the VALUE tid (a different namespace from super-task ids under
    fusion) and must neither corrupt cluster bookkeeping nor hang."""
    from repro.core import TaskFailed
    g = TaskGraph()
    prev = None
    for i in range(6):
        deps = [prev] if prev is not None else []

        def fn(*xs, _i=i):
            if _i == 5:
                return lambda: _i       # unpicklable cluster output
            return _i + sum(xs)

        g.add_node(f"t{i}", fn, tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps)
        prev = i
    g.mark_output(5)
    ex = ClusterExecutor(2, fuse="auto", progress_timeout=30.0)
    with pytest.raises(TaskFailed, match="SerializationError"):
        ex.run(g)


def test_make_executor_thread_rejects_fuse():
    with pytest.raises(ValueError, match="fuse"):
        make_executor("thread", 2, fuse="auto")


def test_launcher_validates_fuse_flag():
    from repro.launch.backend import validate_backend_args

    class A:
        backend = "thread"
        transport = "auto"
        channel = "auto"
        speculate_after = None
        fuse = "16"

    with pytest.raises(SystemExit, match="fuse"):
        validate_backend_args(A())
    A.fuse = "auto"
    validate_backend_args(A())          # auto is the no-op default
    A.backend = "process"
    A.fuse = "16"
    validate_backend_args(A())          # process backend takes any spec
    A.fuse = "sideways"
    with pytest.raises(SystemExit, match="fuse"):
        validate_backend_args(A())


def test_simulator_models_fused_execution():
    g = chain_graph(64)
    for n in g.nodes.values():
        n.cost = 0.01
    base = ClusterSim(g, 2, dispatch_overhead=0.005).run()
    fused = ClusterSim(g, 2, fuse="auto", dispatch_overhead=0.005).run()
    # same total work, far fewer dispatch overheads on the critical path
    assert fused.makespan < base.makespan
    # and with no overhead, fusing a serial chain costs nothing
    free = ClusterSim(g, 2, fuse="auto").run()
    base_free = ClusterSim(g, 2).run()
    assert free.makespan == pytest.approx(base_free.makespan, rel=1e-9)


def test_dualref_resolves_by_host_id():
    if not serde.shm_available():
        pytest.skip("no shared memory in this environment")
    value = np.arange(4096, dtype=np.float32)
    store = {7: value}
    server = serde.PeerServer(None, store)       # TCP family
    try:
        peer = serde.PeerRef(server.path, 7, value.nbytes, 0,
                             secret=server.secret)
        shm = serde.encode(value, transport="shm", threshold=1024)
        # same host: the shm half wins (peer address poisoned to prove it)
        dead_peer = serde.PeerRef("tcp://127.0.0.1:1", 7, value.nbytes, 0,
                                  secret="0" * 32)
        dual = serde.DualRef(shm, dead_peer, host_id())
        assert np.array_equal(serde.resolve(dual), value)
        # cross host: the peer half is used (shm of "elsewhere" is not
        # even attempted — a foreign segment name would not resolve here)
        dual_far = serde.DualRef(shm, peer, "some-other-host")
        assert np.array_equal(serde.resolve(dual_far), value)
        # same host with the segment swept: graceful fallback to the peer
        swept = serde.DualRef(shm, peer, host_id())
        serde.release(swept)        # unlink authority: driver
        assert np.array_equal(serde.resolve(swept), value)
        assert not serde.is_durable(dual)       # host-scoped, not durable
        assert serde.direct_nbytes(dual) == value.nbytes
        assert serde.pipe_nbytes(dual) < 4096
    finally:
        server.close()


def test_worker_publishes_dualref_on_tcp_transport():
    """End to end: a tcp-transport run on one host moves bulk values over
    shared memory (DualRef fast path), not the TCP loopback."""
    if not serde.shm_available():
        pytest.skip("no shared memory in this environment")
    g = TaskGraph()

    def big():
        return np.arange(65536, dtype=np.float32)       # 256 KiB

    g.add_node("big", big, (), {}, TaskKind.PURE, deps=())

    def use(x):
        return float(x.sum())

    g.add_node("use", use, (_Ref(0),), {}, TaskKind.PURE, deps=(0,))
    g.mark_output(1)
    seq = execute_sequential(g)
    # force the producer and consumer apart so the value must transfer
    ex = ClusterExecutor(2, channel="tcp", transport="tcp",
                         fuse="off", pipeline_depth=1,
                         worker_speed=[1.0, 1.0])
    try:
        got = ex.run(g)
    finally:
        ex.close()
    assert results_equal(got, seq)
