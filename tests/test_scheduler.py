"""Scheduler + simulator property tests (hypothesis over random DAGs)."""
import random

import pytest
from _propcheck import given, settings, st

from repro.core import (TaskGraph, TaskKind, list_schedule, replan, simulate,
                        ClusterSim, WorkerEvent, theoretical_speedup)


def random_dag(seed: int, n: int, p_edge: float = 0.25,
               max_cost: float = 4.0) -> TaskGraph:
    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < p_edge][-4:]
        g.add_node(f"t{i}", None, (), {}, TaskKind.PURE, deps=deps,
                   cost=rng.uniform(0.1, max_cost),
                   out_bytes=rng.randint(0, 1 << 20))
    for t in range(n):
        g.mark_output(t) if rng.random() < 0.1 else None
    return g


dag_params = st.tuples(st.integers(0, 10_000), st.integers(1, 60),
                       st.floats(0.0, 0.6))


@given(dag_params, st.integers(1, 16),
       st.sampled_from(["critical_path", "fifo", "random"]))
@settings(max_examples=60, deadline=None)
def test_list_schedule_is_valid_and_bounded(params, workers, policy):
    seed, n, p = params
    g = random_dag(seed, n, p)
    s = list_schedule(g, workers, policy=policy)
    s.validate_against(g)                      # deps + no overlap
    span = g.critical_path_length()
    work = g.total_work()
    assert s.makespan >= span - 1e-9           # Brent lower bounds
    assert s.makespan >= work / workers - 1e-9
    # greedy (list scheduling) 2-approximation: T <= work/p + span
    assert s.makespan <= work / workers + span + 1e-6


@given(dag_params, st.integers(1, 32))
@settings(max_examples=40, deadline=None)
def test_simulator_executes_everything_within_greedy_bound(params, workers):
    seed, n, p = params
    g = random_dag(seed, n, p)
    r = simulate(g, workers)
    assert r.makespan >= g.critical_path_length() - 1e-9
    # work stealing keeps the greedy bound too (with no steal latency)
    assert r.makespan <= g.total_work() / workers + g.critical_path_length() + 1e-6


@given(dag_params)
@settings(max_examples=20, deadline=None)
def test_simulator_deterministic(params):
    seed, n, p = params
    g = random_dag(seed, n, p)
    r1 = simulate(g, 7, seed=3)
    r2 = simulate(g, 7, seed=3)
    assert r1.makespan == r2.makespan
    assert r1.n_steals == r2.n_steals
    assert r1.task_worker == r2.task_worker


def test_more_workers_never_hurt_much():
    g = random_dag(42, 80, 0.15)
    m = [simulate(g, w).makespan for w in (1, 2, 4, 8, 16)]
    for a, b in zip(m, m[1:]):
        assert b <= a * 1.05 + 1e-9        # small steal jitter allowed


def test_critical_path_beats_random_on_average():
    wins = 0
    for seed in range(30):
        g = random_dag(seed, 60, 0.2)
        mc = simulate(g, 4, policy="critical_path").makespan
        mr = simulate(g, 4, policy="random", seed=seed).makespan
        wins += mc <= mr + 1e-9
    assert wins >= 18                      # CP should win most of the time


def test_failure_recovery_completes_all_tasks():
    g = random_dag(7, 50, 0.25)
    ev = [WorkerEvent(time=g.total_work() / 16, kind="fail", worker=0),
          WorkerEvent(time=g.total_work() / 12, kind="fail", worker=1)]
    r = ClusterSim(g, 4, events=ev).run()
    assert r.n_failures == 2
    assert r.makespan > 0
    # makespan still bounded: remaining 2 workers do all the (re)work
    assert r.makespan <= (g.total_work() + r.n_recomputed * 4.0) / 2 \
        + g.critical_path_length() + ev[1].time


def test_straggler_speculation_helps():
    g = TaskGraph()
    for i in range(16):
        g.add_node(f"t{i}", None, (), {}, TaskKind.PURE, deps=(), cost=1.0)
    slow = [WorkerEvent(time=0.0, kind="slow", worker=0, factor=0.02)]
    base = ClusterSim(g, 4, events=list(slow), seed=1).run()
    spec = ClusterSim(g, 4, events=list(slow), speculate_after=3.0,
                      seed=1).run()
    assert spec.n_speculative >= 1
    assert spec.makespan < base.makespan


def test_elastic_join_speeds_up():
    g = random_dag(11, 120, 0.05)
    r_static = simulate(g, 2)
    r_elastic = ClusterSim(
        g, 2, events=[WorkerEvent(time=1.0, kind="join", worker=2),
                      WorkerEvent(time=1.0, kind="join", worker=3)]).run()
    assert r_elastic.makespan < r_static.makespan


def test_replan_after_worker_loss():
    g = random_dag(3, 40, 0.2)
    s1 = list_schedule(g, 8)
    t_cut = s1.makespan / 3
    done = {tid: p.end for tid, p in s1.placements.items() if p.end <= t_cut}
    s2 = replan(g, done, n_workers=4, now=t_cut)
    s2.validate_against(g) if not done else None
    placed = set(done) | set(s2.placements)
    assert placed == set(g.nodes)
    assert s2.makespan >= t_cut


@given(dag_params, st.integers(1, 12),
       st.sampled_from(["critical_path", "fifo", "random"]),
       st.sampled_from(["uniform", "hetero", "extreme"]))
@settings(max_examples=40, deadline=None)
def test_schedule_invariants_all_policies_and_speeds(params, workers,
                                                     policy, speed_kind):
    """Schedule.validate_against invariants — no dependency inversion, no
    per-worker overlap — must hold for every policy under heterogeneous
    worker speeds, and the invariants are re-checked here by hand so the
    test does not only trust the validator."""
    seed, n, p = params
    g = random_dag(seed, n, p)
    speeds = {
        "uniform": [1.0] * workers,
        "hetero": [0.5 + (w % 3) for w in range(workers)],
        "extreme": [0.05 if w == 0 else 2.0 for w in range(workers)],
    }[speed_kind]
    s = list_schedule(g, workers, policy=policy, worker_speed=speeds,
                      seed=seed)
    s.validate_against(g)
    # manual re-check 1: every task placed exactly once, on a real worker
    assert set(s.placements) == set(g.nodes)
    for p_ in s.placements.values():
        assert 0 <= p_.worker < workers
        assert p_.end >= p_.start - 1e-12
        # duration reflects the worker's speed
        want = g.nodes[p_.tid].cost / speeds[p_.worker]
        assert p_.end - p_.start == pytest.approx(want, rel=1e-9, abs=1e-12)
    # manual re-check 2: no dep inversion
    for node in g.nodes.values():
        for d in node.all_deps:
            assert s.placements[d].end <= s.placements[node.tid].start + 1e-9
    # manual re-check 3: no overlap on any worker
    by_worker = {}
    for p_ in s.placements.values():
        by_worker.setdefault(p_.worker, []).append(p_)
    for ps in by_worker.values():
        ps.sort(key=lambda q: q.start)
        for a, b in zip(ps, ps[1:]):
            assert a.end <= b.start + 1e-9
    assert 0.0 < s.utilization() <= 1.0 + 1e-9


@given(dag_params, st.sampled_from(["critical_path", "fifo", "random"]))
@settings(max_examples=20, deadline=None)
def test_validate_against_catches_violations(params, policy):
    """The validator itself must reject corrupted schedules (otherwise the
    invariant tests above prove nothing)."""
    seed, n, p = params
    g = random_dag(seed, n, p)
    if len(g.nodes) < 2:
        return
    s = list_schedule(g, 3, policy=policy)
    dep_edge = next(((d, t) for t in g.nodes
                     for d in g.nodes[t].all_deps), None)
    if dep_edge is not None:
        from repro.core import Placement
        d, t = dep_edge
        bad = dict(s.placements)
        # move the consumer to start BEFORE its dependency finishes
        orig = bad[t]
        bad[t] = Placement(t, orig.worker, bad[d].start - 1.0,
                           bad[d].start - 0.5)
        from repro.core.scheduler import Schedule
        with pytest.raises(AssertionError):
            Schedule(bad, s.n_workers).validate_against(g)


def test_replan_respects_invariants_after_worker_loss_and_join():
    for new_workers in (2, 6, 12):      # shrink and grow
        g = random_dag(17, 60, 0.2)
        s1 = list_schedule(g, 4)
        t_cut = s1.makespan / 2
        done = {tid: p.end for tid, p in s1.placements.items()
                if p.end <= t_cut}
        s2 = replan(g, done, n_workers=new_workers, now=t_cut)
        assert set(done) | set(s2.placements) == set(g.nodes)
        for p in s2.placements.values():
            assert p.start >= t_cut - 1e-9
            assert 0 <= p.worker < new_workers
        # remaining deps still respected among replanned tasks
        for tid in s2.placements:
            for d in g.nodes[tid].all_deps:
                if d in s2.placements:
                    assert s2.placements[d].end <= \
                        s2.placements[tid].start + 1e-9


def test_theoretical_speedup_monotone():
    g = random_dag(5, 60, 0.2)
    sp = [theoretical_speedup(g, w) for w in (1, 2, 4, 8, 1000)]
    assert sp[0] == pytest.approx(1.0)
    for a, b in zip(sp, sp[1:]):
        assert b >= a - 1e-9
    assert sp[-1] == pytest.approx(g.max_parallelism(), rel=1e-6)
