from .pipeline import SyntheticLMDataset, Prefetcher, make_data_source
