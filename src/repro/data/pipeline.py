"""Data pipeline: deterministic synthetic LM shards + prefetch.

Design mirrors a production host-sharded loader:
* the dataset is addressed by (step, host) so any host can (re)produce its
  shard without coordination — this is what makes checkpoint/restart and
  elastic rescaling exact: the cursor is just the step counter;
* a background :class:`Prefetcher` thread keeps ``depth`` batches ready so
  host compute overlaps device compute (double buffering);
* the loader is exposed to the auto-parallelizer as an ``@io_task`` source
  (``make_data_source``), ordered by the RealWorld token like any effect.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import io_task


class SyntheticLMDataset:
    """Zipf-ish token stream; (step, host)-addressable, deterministic."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, n_hosts: int = 1, host_id: int = 0, seed: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.host_batch = global_batch // n_hosts
        self.global_batch = global_batch
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        # zipf-ish marginal over the vocab (realistic embedding access skew)
        z = rng.zipf(1.3, size=(self.host_batch, self.seq_len + 1))
        toks = (z % (self.vocab_size - 2)) + 1
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background thread keeping ``depth`` batches ready."""

    def __init__(self, dataset: SyntheticLMDataset, start_step: int = 0,
                 depth: int = 2):
        self.dataset = dataset
        self.step = start_step
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        s = self.step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self) -> Dict[str, np.ndarray]:
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)


def make_data_source(dataset: SyntheticLMDataset):
    """Expose the loader as an effectful task (RealWorld-ordered)."""
    state = {"step": 0}

    @io_task(name="load_batch", cost=0.01, meta={"idempotent": True})
    def load_batch():
        b = dataset.batch_at(state["step"])
        state["step"] += 1
        return b

    return load_batch
