"""``repro-driver`` — run (or resume) a checkpointed cluster driver.

The driver half of the multi-host survivability story
(``docs/driver_recovery.md``).  Where ``repro-worker`` makes *worker*
processes disposable, this entrypoint makes the *driver* disposable: it
runs a :class:`~repro.cluster.ClusterExecutor` with a run-log checkpoint
under ``--checkpoint-dir``, and a SIGKILL'd driver is restarted with
``--resume`` — the new incarnation rebinds the same listening address,
re-adopts the surviving workers (their rejoin loops re-dial it), and
continues the run from the checkpointed frontier.

Start a run (the run id and address print first, flushed, so a supervisor
can capture them before any crash)::

    python -m repro.launch.driver --graph mypkg.graphs:build --arg 500 \
        --workers 8 --checkpoint-dir /var/tmp/ckpt --out results.pkl

Resume after a driver death (``--resume latest`` picks the newest log in
the checkpoint dir)::

    python -m repro.launch.driver --graph mypkg.graphs:build --arg 500 \
        --workers 8 --checkpoint-dir /var/tmp/ckpt --resume latest \
        --out results.pkl

The graph is rebuilt by re-importing ``--graph`` — the run log stores
*metadata*, not code — and the resume path fingerprint-checks that the
rebuilt graph and fusion plan match the interrupted run.  Workers are
fork-started by default: fork children survive their parent's SIGKILL
(the daemon flag only matters at clean interpreter exit), which is
exactly what lets a restarted driver find its old pool still alive.
"""
from __future__ import annotations

import argparse
import importlib
import pickle
import sys
from typing import List, Optional

from repro.checkpoint.runlog import latest_run


def _demo_node(*xs, _i=0):
    return (_i + sum(xs) * 7) % 1_000_003


def demo_graph(n: int = 200, seed: int = 0):
    """Deterministic integer-arithmetic DAG (module-level functions, so it
    pickles): the stock target for smoke tests and the CI driver-kill
    drill — ``--graph repro.launch.driver:demo_graph --arg 200``."""
    import functools
    import random

    from repro.core import TaskGraph, TaskKind
    from repro.core.tracing import RemappedRef as _Ref

    rng = random.Random(seed)
    g = TaskGraph()
    for i in range(n):
        deps = [j for j in range(i) if rng.random() < 0.25][-3:]
        g.add_node(f"t{i}", functools.partial(_demo_node, _i=i),
                   tuple(_Ref(d) for d in deps), {},
                   TaskKind.PURE, deps=deps, cost=rng.uniform(0.1, 1.0))
    g.mark_output(n - 1)
    return g


def build_graph(spec: str, args: List[int]):
    """Import ``module:function`` and call it with the ``--arg`` ints."""
    if ":" not in spec:
        raise ValueError(f"--graph must be MODULE:FUNCTION, got {spec!r}")
    mod_name, _, fn_name = spec.partition(":")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    return fn(*args)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-driver",
        description="run a checkpointed ClusterExecutor driver; a killed "
                    "driver is restarted with --resume")
    ap.add_argument("--graph", required=True, metavar="MODULE:FN",
                    help="graph builder to import and call")
    ap.add_argument("--arg", type=int, action="append", default=[],
                    help="int argument(s) for the graph builder")
    ap.add_argument("--workers", type=int, default=2,
                    help="local worker processes")
    # shared cluster knobs come from ClusterConfig field metadata — the
    # same group train.py/serve.py/repro-gateway expose (no more
    # per-launcher flag copies); tcp is the resumable channel default
    # here because the whole point of this entrypoint is driver recovery
    from repro.config import ClusterConfig
    ClusterConfig.add_flags(
        ap, names=("channel", "connect", "token", "checkpoint_dir",
                   "checkpoint_interval", "resume", "fuse", "adaptive",
                   "keep_parallelism", "refuse_skew", "outputs_only"),
        defaults={"channel": "tcp"})
    ap.add_argument("--fail-driver", type=int, default=None, metavar="N",
                    help="testing: emulate a driver SIGKILL after N "
                    "cluster completions")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="pickle the {tid: value} results here")
    args = ap.parse_args(argv)
    if not args.checkpoint_dir:
        ap.error("the following arguments are required: --checkpoint-dir")

    resume = args.resume
    if resume == "latest":
        resume = latest_run(args.checkpoint_dir)
        if resume is None:
            print(f"repro-driver: no run logs under {args.checkpoint_dir}",
                  file=sys.stderr, flush=True)
            return 2

    graph = build_graph(args.graph, args.arg)

    from repro.cluster import ClusterExecutor, DriverKilled
    cfg = ClusterConfig.from_flags(
        args, names=("channel", "connect", "token", "checkpoint_dir",
                     "checkpoint_interval", "fuse", "adaptive",
                     "keep_parallelism", "refuse_skew", "outputs_only"),
        n_workers=args.workers, resume=resume,
        fail_driver=args.fail_driver, start_method="fork")
    ex = ClusterExecutor(config=cfg)
    # first line out, flushed: a supervisor needs the run id to relaunch
    # with --resume even if this process dies an instant later
    print(f"repro-driver: {'resuming' if resume else 'run'} "
          f"{resume or 'pending'} listening {ex.address or '-'} "
          f"pid {__import__('os').getpid()}", flush=True)
    try:
        results = ex.run(graph)
    except DriverKilled as e:
        print(f"repro-driver: {e}", file=sys.stderr, flush=True)
        return 3
    print(f"repro-driver: run {ex.run_id} complete "
          f"({ex.stats.get('resumed_clusters', 0)} clusters resumed, "
          f"{ex.stats.get('recomputed', 0)} recomputed, "
          f"wall {ex.wall_time:.2f}s)", flush=True)
    if args.out:
        with open(args.out, "wb") as f:
            pickle.dump(results, f, protocol=5)
        print(f"repro-driver: results -> {args.out}", flush=True)
    ex.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
