"""Production mesh entry point (assignment §MULTI-POD DRY-RUN item 1).

Functions only — importing this module never touches jax device state.
"""
from repro.parallel.mesh import (make_production_mesh, make_mesh_for,
                                 single_device_mesh)

__all__ = ["make_production_mesh", "make_mesh_for", "single_device_mesh"]
