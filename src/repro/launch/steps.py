"""Per-cell step builders: (arch × shape × mesh) → abstract inputs,
shardings and the jit-able step function.

This is the glue the dry-run, the roofline benchmarks and the real train /
serve launchers all share, so what we compile in the dry-run is EXACTLY what
would execute on hardware.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.placement import standard_rules, logical_to_spec, tree_shardings
from repro.models.config import ModelConfig, ShapeSpec, SHAPES
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.models import frontends
from repro.optim import AdamW, Adafactor
from repro.parallel.sharding import ShardingCtx


@dataclasses.dataclass
class Case:
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Callable
    args: Tuple[Any, ...]              # ShapeDtypeStructs (dry-run safe)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    kind: str                          # train | prefill | decode
    skip_reason: Optional[str] = None


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch at 512k context: quadratic prefill / "
                "full-length KV cache out of scope per assignment "
                "(DESIGN.md §Arch-applicability)")
    return None


def make_optimizer(cfg: ModelConfig, lr: float = 3e-4):
    if cfg.name.startswith("llama4"):
        return Adafactor(lr=lr)        # Adam state cannot fit (DESIGN.md §5)
    return AdamW(lr=lr, weight_decay=0.1)


# --------------------------------------------------------------------------
# sharding helpers
# --------------------------------------------------------------------------

def make_rules(mesh: Mesh, mode: str, global_batch: int):
    pod = "pod" if "pod" in mesh.axis_names else None
    rules = standard_rules(mode, pod_axis=pod)
    batch_ways = mesh.shape["data"] * (mesh.shape["pod"] if pod else 1)
    if global_batch % batch_ways != 0:
        # tiny batches (long_500k B=1): replicate batch, keep TP/FSDP
        rules = [("batch", None), ("expert_group", None)] + \
            [r for r in rules if r[0] not in ("batch", "expert_group")]
    return rules


def opt_state_shardings(opt, params_axes, params_abs, rules, mesh):
    """m/v mirror the param specs; Adafactor's factored vr/vc drop the
    last / second-to-last logical axis (matching its init by shape)."""
    def spec(axes):
        return NamedSharding(mesh, logical_to_spec(axes, rules, mesh))

    if isinstance(opt, AdamW):
        pm = jax.tree.map(spec, params_axes,
                          is_leaf=lambda x: isinstance(x, tuple))
        return {"step": NamedSharding(mesh, P()), "m": pm, "v": pm}

    def vspec(axes, p):
        if opt._factored(p.shape):
            return {"vr": spec(axes[:-1]), "vc": spec(axes[:-2] + axes[-1:])}
        return {"v": spec(axes)}
    vt = jax.tree.map(vspec, params_axes, params_abs,
                      is_leaf=lambda x: isinstance(x, tuple))
    return {"step": NamedSharding(mesh, P()), "v": vt}


def _sh(mesh, rules, axes):
    return NamedSharding(mesh, logical_to_spec(axes, rules, mesh))


# --------------------------------------------------------------------------
# generic train step (dispatches dense-stack vs enc-dec)
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer, ctx) -> Callable:
    M = ED if cfg.is_encoder_decoder else TF
    loss_fn = M.make_loss_fn(cfg, ctx)

    g_sh = None
    if cfg.shard_grads and ctx is not None and ctx.mesh is not None:
        axes = M.logical_axes(cfg)
        g_sh = jax.tree.map(
            lambda a: NamedSharding(ctx.mesh,
                                    logical_to_spec(a, ctx.rules, ctx.mesh)),
            axes, is_leaf=lambda x: isinstance(x, tuple))

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (total, metrics), grads = grad_fn(params, batch)
        if g_sh is not None:
            # pin grads to the param layout: the DP reduction lowers as a
            # reduce-scatter to the shard each device owns (1× wire) rather
            # than an all-reduce of the full gradient (2× wire)
            grads = jax.lax.with_sharding_constraint(grads, g_sh)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        metrics = dict(metrics, total_loss=total)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# case builders
# --------------------------------------------------------------------------

def build_case(arch: str, shape_name: str, mesh: Mesh,
               mode: str = "fsdp_tp", *,
               remat: Optional[str] = None,
               serve_mode: Optional[str] = None,
               overrides: Optional[Dict[str, Any]] = None) -> Case:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return Case(arch, shape_name, cfg, None, (), (), None, (),
                    shape.kind, skip_reason=reason)

    B, S = shape.global_batch, shape.seq_len
    upd: Dict[str, Any] = {"max_cache_len": S}
    if shape.kind != "train":
        upd["param_dtype"] = "bfloat16"       # serving runs bf16 weights
        mode = serve_mode or mode
    if remat is not None:
        upd["remat"] = remat
    if overrides:
        upd.update(overrides)
    cfg = dataclasses.replace(cfg, **upd)

    rules = make_rules(mesh, mode, B)
    ctx = ShardingCtx(mesh, rules)
    is_ed = cfg.is_encoder_decoder
    M = ED if is_ed else TF

    params_abs = M.abstract_params(cfg)
    params_axes = M.logical_axes(cfg)
    params_sh = jax.tree.map(functools.partial(_sh, mesh, rules),
                             params_axes,
                             is_leaf=lambda x: isinstance(x, tuple))
    tok = jax.ShapeDtypeStruct
    tok_sh = _sh(mesh, rules, ("batch", "seq"))

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = opt_state_shardings(opt, params_axes, params_abs, rules, mesh)
        batch_abs = {"tokens": tok((B, S), jnp.int32),
                     "labels": tok((B, S), jnp.int32)}
        batch_sh = {"tokens": tok_sh, "labels": tok_sh}
        if cfg.family == "vlm":
            batch_abs["patch_embeds"] = frontends.vision_patch_spec(cfg, B)
            batch_sh["patch_embeds"] = _sh(mesh, rules, ("batch", None, None))
        if is_ed:
            batch_abs["frames"] = frontends.audio_frame_spec(cfg, B)
            batch_sh["frames"] = _sh(mesh, rules, ("batch", None, None))
        fn = make_train_step(cfg, opt, ctx)
        return Case(arch, shape_name, cfg, fn,
                    (params_abs, opt_abs, batch_abs),
                    (params_sh, opt_sh, batch_sh),
                    None, (0, 1), "train")

    cache_axes = (ED.cache_logical_axes(cfg) if is_ed
                  else TF.cache_logical_axes(cfg))
    cache_sh = jax.tree.map(functools.partial(_sh, mesh, rules),
                            cache_axes,
                            is_leaf=lambda x: isinstance(x, tuple))

    if shape.kind == "prefill":
        fn = (ED.make_prefill_step(cfg, ctx, max_len=S) if is_ed
              else TF.make_prefill_step(cfg, ctx, max_len=S))
        args: Tuple[Any, ...] = (params_abs, tok((B, S), jnp.int32))
        in_sh: Tuple[Any, ...] = (params_sh, tok_sh)
        if cfg.family == "vlm":
            args = args + (frontends.vision_patch_spec(cfg, B),)
            in_sh = in_sh + (_sh(mesh, rules, ("batch", None, None)),)
        if is_ed:
            args = args + (frontends.audio_frame_spec(cfg, B),)
            in_sh = in_sh + (_sh(mesh, rules, ("batch", None, None)),)
        logits_sh = _sh(mesh, rules, ("batch", "vocab"))
        return Case(arch, shape_name, cfg, fn, args, in_sh,
                    (logits_sh, cache_sh), (), "prefill")

    # decode: one new token against a cache of length S
    init = functools.partial(
        (ED.init_cache if is_ed else TF.init_cache), cfg, B, S)
    cache_abs = jax.eval_shape(init)
    fn = (ED.make_decode_step(cfg, ctx) if is_ed
          else TF.make_decode_step(cfg, ctx))
    token_sh = _sh(mesh, rules, ("batch", None))
    logits_sh = _sh(mesh, rules, ("batch", "vocab"))
    return Case(arch, shape_name, cfg, fn,
                (params_abs, cache_abs, tok((B, 1), jnp.int32)),
                (params_sh, cache_sh, token_sh),
                (logits_sh, cache_sh), (1,), "decode")


def _fit_sharding(abs_leaf, sh):
    """Drop mesh axes whose shard count does not divide the dim size —
    jit I/O shardings require exact divisibility (padding only applies to
    internal constraints).  E.g. whisper's vocab=51865 cannot shard 16-way;
    the embedding is replicated on that dim instead."""
    if sh is None or not isinstance(sh, NamedSharding):
        return sh
    shape = abs_leaf.shape
    spec = sh.spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    new_parts = []
    for dim, part in zip(shape, parts):
        if part is None:
            new_parts.append(None)
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        n = 1
        for a in axes:
            n *= sh.mesh.shape[a]
        new_parts.append(part if dim % n == 0 else None)
    while new_parts and new_parts[-1] is None:
        new_parts.pop()
    return NamedSharding(sh.mesh, P(*new_parts))


def fit_case_shardings(case: Case) -> Case:
    in_sh = jax.tree.map(_fit_sharding, case.args, case.in_shardings)
    out_sh = case.out_shardings
    if out_sh is not None:
        out_abs = jax.eval_shape(case.fn, *case.args)
        out_sh = jax.tree.map(_fit_sharding, out_abs, out_sh)
    return dataclasses.replace(case, in_shardings=in_sh, out_shardings=out_sh)


def lower_case(case: Case):
    case = fit_case_shardings(case)
    jitted = jax.jit(case.fn,
                     in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings,
                     donate_argnums=case.donate_argnums)
    return jitted.lower(*case.args)
