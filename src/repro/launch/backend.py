"""Runtime-backend selection for the launchers.

``--backend thread`` executes a traced driver DAG with the in-process
work-stealing :class:`~repro.core.executor.ThreadedExecutor`;
``--backend process`` uses the multi-process
:class:`~repro.cluster.ClusterExecutor` (forked workers, driver-side object
store, lineage fault tolerance).  See ``repro/cluster/__init__.py`` for the
full trade-off discussion.

JAX payloads cannot run in a *forked* worker (the child inherits a dead XLA
runtime and deadlocks), so the launchers use ``start_method="spawn"``:
workers start as fresh interpreters and the graph is pickled across.  That
is why the launcher demo tasks are module-level functions parameterized by
literals (arch name, seed, step) that rebuild their model/jit lazily inside
the worker — ship the *recipe*, not the weights, exactly like a real
multi-host deployment.  Tests and numpy-level workloads keep the cheaper
``fork`` default.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional

from repro.core import TaskGraph, make_executor
from repro.core.executor import Executor


#: data-plane transports each runtime backend actually supports.  The
#: thread backend shares one address space — there is no transport to
#: pick, so anything but the default is a user error worth naming early
#: (it used to be silently ignored; an unknown transport died as a deep
#: KeyError inside the executor instead of at the flag).
BACKEND_TRANSPORTS: Dict[str, tuple] = {
    "thread": ("auto",),
    "process": ("auto", "shm", "sock", "tcp", "driver"),
}

BACKEND_CHANNELS: Dict[str, tuple] = {
    "thread": ("auto",),
    "process": ("auto", "pipe", "spawn", "tcp"),
}


def add_backend_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--backend", default="thread",
                    choices=["thread", "process"],
                    help="runtime for --show-graph driver execution: "
                         "in-process threads or spawned cluster workers")
    ap.add_argument("--graph-workers", type=int, default=2,
                    help="worker count for the traced-driver dry-run")
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "shm", "sock", "tcp", "driver"],
                    help="process-backend data plane: zero-copy shared "
                         "memory, direct unix-socket or TCP pulls, or the "
                         "driver-relayed pipe path (A/B baseline)")
    ap.add_argument("--channel", default="auto",
                    choices=["auto", "pipe", "spawn", "tcp"],
                    help="process-backend control plane: in-host pipes "
                         "(forked/spawned workers) or the multi-host TCP "
                         "listener (workers dial in; see repro-worker)")
    ap.add_argument("--speculate-after", type=float, default=None,
                    metavar="X",
                    help="process backend: speculatively re-execute a task "
                         "running longer than X times its expected duration "
                         "on an idle worker (first completion wins; off by "
                         "default — see docs/speculation.md)")
    ap.add_argument("--fuse", default="auto", metavar="{auto,off,N}",
                    help="process backend: compile the task graph into "
                         "super-tasks before dispatch (fuse chains, small "
                         "fan-ins, sibling groups) so fine-grained graphs "
                         "stop paying one driver round-trip per node; N "
                         "caps members per super-task (default auto; see "
                         "docs/fusion.md)")
    ap.add_argument("--collectives", default="auto", metavar="{auto,off,N}",
                    help="process backend: lower broadcast/scatter/gather/"
                         "all_reduce nodes into staged tree hops over the "
                         "peer data plane instead of N×M point-to-point "
                         "edges; off executes each collective's dense "
                         "fallback on one worker, N overrides the tree "
                         "arity (default auto; see docs/collectives.md)")


def validate_backend_args(args) -> None:
    """Fail fast, with the flag's own vocabulary, when ``--transport`` /
    ``--channel`` name something the chosen ``--backend`` cannot do."""
    backend = getattr(args, "backend", "thread")
    transport = getattr(args, "transport", "auto")
    channel = getattr(args, "channel", "auto")
    supported = BACKEND_TRANSPORTS.get(backend, ("auto",))
    if transport not in supported:
        raise SystemExit(
            f"--transport {transport} is not supported by --backend "
            f"{backend}: the thread backend runs in one address space "
            f"(no data plane to choose); use --backend process for "
            f"{BACKEND_TRANSPORTS['process'][1:]}")
    if channel not in BACKEND_CHANNELS.get(backend, ("auto",)):
        raise SystemExit(
            f"--channel {channel} is not supported by --backend {backend}: "
            f"only the process backend has a worker control plane; use "
            f"--backend process for {BACKEND_CHANNELS['process'][1:]}")
    speculate = getattr(args, "speculate_after", None)
    if speculate is not None and backend != "process":
        raise SystemExit(
            f"--speculate-after {speculate} is not supported by --backend "
            f"{backend}: only the process backend duplicates stragglers "
            f"onto idle workers; use --backend process")
    fuse = getattr(args, "fuse", "auto")
    try:
        from repro.core.fusion import parse_fuse_spec
        parsed = parse_fuse_spec(fuse)
    except ValueError as e:
        raise SystemExit(f"--fuse {fuse}: {e}") from None
    if parsed not in ("off", "auto") and backend != "process":
        raise SystemExit(
            f"--fuse {fuse} is not supported by --backend {backend}: only "
            f"the process backend pays per-task dispatch round-trips worth "
            f"fusing away; use --backend process")
    coll = getattr(args, "collectives", "auto")
    try:
        from repro.core.collectives import parse_collectives_spec
        cparsed = parse_collectives_spec(coll)
    except ValueError as e:
        raise SystemExit(f"--collectives {coll}: {e}") from None
    if cparsed not in ("off", "auto") and backend != "process":
        raise SystemExit(
            f"--collectives {coll} is not supported by --backend {backend}: "
            f"the thread backend shares one address space, so there is no "
            f"data plane to shape a tree over (collective nodes run their "
            f"dense fallback); use --backend process")


def execute_traced(graph: TaskGraph, args,
                   inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
    """Run a traced driver DAG on the selected backend and report stats
    (including the data-plane counters for the process backend)."""
    validate_backend_args(args)
    kw: Dict[str, Any] = {}
    if args.backend == "process":
        kw = {"start_method": "spawn", "progress_timeout": 300.0,
              "transport": getattr(args, "transport", "auto"),
              "fuse": getattr(args, "fuse", "auto"),
              "collectives": getattr(args, "collectives", "auto")}
        channel = getattr(args, "channel", "auto")
        if channel != "auto":
            kw["channel"] = channel
        speculate = getattr(args, "speculate_after", None)
        if speculate is not None:
            kw["speculate_after"] = speculate
    ex: Executor = make_executor(args.backend, args.graph_workers, **kw)
    results = ex.run(graph, inputs)
    transport = getattr(ex, "transport_used", None)
    via = f" via {transport} transport" if transport else ""
    print(f"[{args.backend} backend{via}] executed {len(graph.nodes)} tasks "
          f"on {args.graph_workers} workers in {ex.wall_time:.3f}s "
          f"(stats {ex.stats})", flush=True)
    return results
