"""Runtime-backend selection for the launchers.

``--backend thread`` executes a traced driver DAG with the in-process
work-stealing :class:`~repro.core.executor.ThreadedExecutor`;
``--backend process`` uses the multi-process
:class:`~repro.cluster.ClusterExecutor` (forked workers, driver-side object
store, lineage fault tolerance).  See ``repro/cluster/__init__.py`` for the
full trade-off discussion.

JAX payloads cannot run in a *forked* worker (the child inherits a dead XLA
runtime and deadlocks), so the launchers use ``start_method="spawn"``:
workers start as fresh interpreters and the graph is pickled across.  That
is why the launcher demo tasks are module-level functions parameterized by
literals (arch name, seed, step) that rebuild their model/jit lazily inside
the worker — ship the *recipe*, not the weights, exactly like a real
multi-host deployment.  Tests and numpy-level workloads keep the cheaper
``fork`` default.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional

from repro.core import TaskGraph, make_executor
from repro.core.executor import Executor


def add_backend_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--backend", default="thread",
                    choices=["thread", "process"],
                    help="runtime for --show-graph driver execution: "
                         "in-process threads or spawned cluster workers")
    ap.add_argument("--graph-workers", type=int, default=2,
                    help="worker count for the traced-driver dry-run")
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "shm", "sock", "driver"],
                    help="process-backend data plane: zero-copy shared "
                         "memory, direct unix-socket pulls, or the "
                         "driver-relayed pipe path (A/B baseline)")


def execute_traced(graph: TaskGraph, args,
                   inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
    """Run a traced driver DAG on the selected backend and report stats
    (including the data-plane counters for the process backend)."""
    kw = ({"start_method": "spawn", "progress_timeout": 300.0,
           "transport": getattr(args, "transport", "auto")}
          if args.backend == "process" else {})
    ex: Executor = make_executor(args.backend, args.graph_workers, **kw)
    results = ex.run(graph, inputs)
    transport = getattr(ex, "transport_used", None)
    via = f" via {transport} transport" if transport else ""
    print(f"[{args.backend} backend{via}] executed {len(graph.nodes)} tasks "
          f"on {args.graph_workers} workers in {ex.wall_time:.3f}s "
          f"(stats {ex.stats})", flush=True)
    return results
