"""Runtime-backend selection for the launchers.

``--backend thread`` executes a traced driver DAG with the in-process
work-stealing :class:`~repro.core.executor.ThreadedExecutor`;
``--backend process`` uses the multi-process
:class:`~repro.cluster.ClusterExecutor` (forked workers, driver-side object
store, lineage fault tolerance).  See ``repro/cluster/__init__.py`` for the
full trade-off discussion.

The cluster knobs themselves (``--transport``, ``--channel``, ``--fuse``,
``--collectives``, ``--speculate-after``) are **generated from
:class:`repro.ClusterConfig` field metadata** — one source of truth for
flag names, help text and choices, shared by every launcher
(``train.py`` / ``serve.py`` / ``driver.py`` / ``repro-gateway``) instead
of the per-launcher copies this module used to carry.  Only
``--backend`` / ``--graph-workers`` stay local: they select the runtime,
they are not runtime configuration.

JAX payloads cannot run in a *forked* worker (the child inherits a dead XLA
runtime and deadlocks), so the launchers use ``start_method="spawn"``:
workers start as fresh interpreters and the graph is pickled across.  That
is why the launcher demo tasks are module-level functions parameterized by
literals (arch name, seed, step) that rebuild their model/jit lazily inside
the worker — ship the *recipe*, not the weights, exactly like a real
multi-host deployment.  Tests and numpy-level workloads keep the cheaper
``fork`` default.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional

from repro.config import ClusterConfig
from repro.core import TaskGraph, make_executor
from repro.core.executor import Executor

#: ClusterConfig fields exposed as launcher backend flags (the subset a
#: single-run launcher exercises; repro-gateway exposes the full set).
BACKEND_FLAG_FIELDS = ("transport", "channel", "speculate_after",
                       "fuse", "collectives", "adaptive",
                       "keep_parallelism", "refuse_skew")

#: launcher-facing defaults that differ from the library defaults: the
#: demo drivers trace fine-grained graphs, so fusion pays for itself
_LAUNCHER_DEFAULTS = {"fuse": "auto"}

_CFG_CHOICES: Dict[str, tuple] = {
    f.name: tuple(f.metadata["choices"] or ())
    for f in ClusterConfig.flag_fields()}

#: data-plane transports each runtime backend actually supports, derived
#: from the config metadata.  The thread backend shares one address
#: space — there is no transport to pick, so anything but the default is
#: a user error worth naming early (it used to be silently ignored; an
#: unknown transport died as a deep KeyError inside the executor instead
#: of at the flag).
BACKEND_TRANSPORTS: Dict[str, tuple] = {
    "thread": ("auto",),
    "process": _CFG_CHOICES["transport"],
}

BACKEND_CHANNELS: Dict[str, tuple] = {
    "thread": ("auto",),
    "process": _CFG_CHOICES["channel"],
}


def add_backend_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--backend", default="thread",
                    choices=["thread", "process"],
                    help="runtime for --show-graph driver execution: "
                         "in-process threads or spawned cluster workers")
    ap.add_argument("--graph-workers", type=int, default=2,
                    help="worker count for the traced-driver dry-run")
    ClusterConfig.add_flags(ap, names=BACKEND_FLAG_FIELDS,
                            defaults=_LAUNCHER_DEFAULTS)


def validate_backend_args(args) -> None:
    """Fail fast, with the flag's own vocabulary, when ``--transport`` /
    ``--channel`` name something the chosen ``--backend`` cannot do."""
    backend = getattr(args, "backend", "thread")
    transport = getattr(args, "transport", "auto")
    # the config-generated --channel parses "auto" to None (the config's
    # "infer from pool shape" spelling); both mean the default here
    channel = getattr(args, "channel", "auto") or "auto"
    supported = BACKEND_TRANSPORTS.get(backend, ("auto",))
    if transport not in supported:
        raise SystemExit(
            f"--transport {transport} is not supported by --backend "
            f"{backend}: the thread backend runs in one address space "
            f"(no data plane to choose); use --backend process for "
            f"{BACKEND_TRANSPORTS['process'][1:]}")
    if channel not in BACKEND_CHANNELS.get(backend, ("auto",)):
        raise SystemExit(
            f"--channel {channel} is not supported by --backend {backend}: "
            f"only the process backend has a worker control plane; use "
            f"--backend process for {BACKEND_CHANNELS['process'][1:]}")
    speculate = getattr(args, "speculate_after", None)
    if speculate is not None and backend != "process":
        raise SystemExit(
            f"--speculate-after {speculate} is not supported by --backend "
            f"{backend}: only the process backend duplicates stragglers "
            f"onto idle workers; use --backend process")
    fuse = getattr(args, "fuse", "auto")
    try:
        from repro.core.fusion import parse_fuse_spec
        parsed = parse_fuse_spec(fuse)
    except ValueError as e:
        raise SystemExit(f"--fuse {fuse}: {e}") from None
    if parsed not in ("off", "auto") and backend != "process":
        raise SystemExit(
            f"--fuse {fuse} is not supported by --backend {backend}: only "
            f"the process backend pays per-task dispatch round-trips worth "
            f"fusing away; use --backend process")
    coll = getattr(args, "collectives", "auto")
    try:
        from repro.core.collectives import parse_collectives_spec
        cparsed = parse_collectives_spec(coll)
    except ValueError as e:
        raise SystemExit(f"--collectives {coll}: {e}") from None
    if cparsed not in ("off", "auto") and backend != "process":
        raise SystemExit(
            f"--collectives {coll} is not supported by --backend {backend}: "
            f"the thread backend shares one address space, so there is no "
            f"data plane to shape a tree over (collective nodes run their "
            f"dense fallback); use --backend process")


def execute_traced(graph: TaskGraph, args,
                   inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
    """Run a traced driver DAG on the selected backend and report stats
    (including the data-plane counters for the process backend)."""
    validate_backend_args(args)
    if args.backend == "process":
        cfg = ClusterConfig.from_flags(
            args, names=BACKEND_FLAG_FIELDS,
            n_workers=args.graph_workers, start_method="spawn",
            progress_timeout=300.0)
        ex: Executor = make_executor("process", args.graph_workers,
                                     config=cfg)
    else:
        ex = make_executor("thread", args.graph_workers)
    results = ex.run(graph, inputs)
    transport = getattr(ex, "transport_used", None)
    via = f" via {transport} transport" if transport else ""
    print(f"[{args.backend} backend{via}] executed {len(graph.nodes)} tasks "
          f"on {args.graph_workers} workers in {ex.wall_time:.3f}s "
          f"(stats {ex.stats})", flush=True)
    return results
