"""``repro-worker`` — start cluster workers on this host and dial a driver.

This is the multi-host half of :class:`repro.cluster.ClusterExecutor`'s
TCP control plane.  A driver built with ``channel="tcp"`` (or with
``workers=[..., "remote", ...]``) binds a listening address; this
entrypoint dials it, handshakes (magic / protocol version / optional
shared ``--token`` / host identity), receives its worker id plus the run
configuration and the pickled ``(graph, inputs)`` pair in the welcome
frame, and then serves tasks exactly like a forked in-host worker —
heartbeating so the driver can tell a network partition from an idle
worker, saying an explicit goodbye on clean shutdown.

Usage (one worker per ``--n``, each its own OS process)::

    python -m repro.launch.remote --connect HOST:PORT [--token T] [--n 2]
        [--timeout 60]

Dial a driver that is still starting up: the connect retries until
``--timeout``.  A worker that dials a *live* run joins it elastically —
the driver replans onto the grown pool — so scaling out mid-job is just
starting more of these.

The graph crosses the wire by pickle, so remote runs have the same
constraint as ``start_method="spawn"``: task functions must be picklable
(module-level functions parameterized by literals — ship the recipe, not
the weights).  See ``docs/multihost.md`` for the deployment how-to and
the transport matrix.
"""
from __future__ import annotations

import argparse
import multiprocessing as mp
import sys
from typing import List, Optional

from repro.cluster import serde
from repro.cluster.channel import ChannelClosed
from repro.cluster.worker import tcp_worker_main


def _serve_one(address: str, token: Optional[str], timeout: float) -> int:
    try:
        wid = tcp_worker_main(address, token=token, timeout=timeout)
    except ChannelClosed as e:
        print(f"repro-worker: {e}", file=sys.stderr, flush=True)
        return 1
    print(f"repro-worker: worker {wid} finished cleanly", flush=True)
    return 0


def _serve_one_exit(address: str, token: Optional[str],
                    timeout: float) -> None:
    """Child-process target: a Process target's return value is discarded,
    so the status must go through sys.exit to become the exitcode."""
    sys.exit(_serve_one(address, token, timeout))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-worker",
        description="dial a ClusterExecutor driver and serve tasks")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="driver address (ClusterExecutor(...).address)")
    ap.add_argument("--token", default=None,
                    help="shared secret, if the driver requires one")
    ap.add_argument("--n", type=int, default=1,
                    help="worker processes to start on this host")
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="seconds to keep retrying the dial/handshake")
    args = ap.parse_args(argv)
    if ":" not in args.connect:
        ap.error(f"--connect must be HOST:PORT, got {args.connect!r}")
    if args.n < 1:
        ap.error("--n must be >= 1")
    # Startup residue sweep: a worker SIGKILL'd on this host never ran its
    # shutdown sweep, so its dead run's rr* segments leak in /dev/shm.
    # Scoped to runs whose driver pid is gone AND whose resume lease (if
    # any) has expired — a checkpointed run inside its rejoin window keeps
    # its segments even though its driver pid is dead, so this worker can
    # no longer race a same-host driver resume out of its recovery inputs
    # (docs/driver_recovery.md §3).
    swept = serde.sweep_stale_segments()
    if swept:
        print(f"repro-worker: swept {swept} stale shm segment(s) from "
              "dead runs", flush=True)
    if args.n == 1:
        return _serve_one(args.connect, args.token, args.timeout)
    # one OS process per worker: each dials, handshakes, and serves its own
    # store — the same isolation the driver's local spawn gives
    ctx = mp.get_context("spawn")
    procs = [ctx.Process(target=_serve_one_exit,
                         args=(args.connect, args.token, args.timeout),
                         name=f"repro-worker-{i}")
             for i in range(args.n)]
    for p in procs:
        p.start()
    rc = 0
    for p in procs:
        p.join()
        rc = rc or (p.exitcode or 0)
    return rc


if __name__ == "__main__":
    sys.exit(main())
