"""Multi-pod dry-run: AOT lower+compile every (arch × shape × mesh) cell.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
--arch qwen3-14b --shape train_4k --mesh single``.  The first two lines
create 512 placeholder CPU devices BEFORE any jax import (jax pins the
device count at first init); smoke tests / benches import repro normally
and see 1 device.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402

from repro.compat import cost_analysis_dict           # noqa: E402
from repro.configs import ARCHS                       # noqa: E402
from repro.models.config import SHAPES                # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402
from repro.launch import steps as steps_mod           # noqa: E402

# --------------------------------------------------------------------------
# v5e hardware constants (assignment §ROOFLINE)
# --------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip per direction)
DCN_BW = 25e9                # bytes/s per chip across pods (assumed, 2x slower)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(-start)?\(",
)
# replica_groups={{0,1},{2,3}}  (explicit)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{(\{.*?\})\}")
# replica_groups=[32,16]<=[2,16,16]T(1,0,2)  (iota form: 32 groups of 16)
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> Optional[list]:
    """Return replica groups as a list of id-lists, or None if absent."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(ng, gs).tolist()
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return [[int(x) for x in re.findall(r"\d+", grp)]
                for grp in m.group(1).split("},{")]
    return None


# Ring-algorithm per-device wire-byte factors, as a function of the printed
# (per-device) RESULT size b and the replica-group size g:
#   all-gather      result is the gathered buffer; wire = b·(g-1)/g
#   all-reduce      operand == result;            wire = 2·b·(g-1)/g
#   reduce-scatter  operand = b·g;                wire = b·(g-1)
#   all-to-all      operand == result;            wire = b·(g-1)/g
#   collective-permute / broadcast                wire = b
def _wire_bytes(op: str, b: float, g: int) -> float:
    if g <= 1:
        return 0.0 if op not in ("collective-permute",) else b
    if op == "all-gather":
        return b * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * b * (g - 1) / g
    if op == "reduce-scatter":
        return b * (g - 1)
    if op == "all-to-all":
        return b * (g - 1) / g
    return b   # permute / broadcast


def parse_collectives(hlo: str, pod_boundary: Optional[int] = None) -> Dict:
    """Per-device collective traffic from partitioned HLO.

    Returns raw RESULT bytes per op type (inspectable), plus modeled wire
    bytes (``_wire_ici_bytes`` / ``_wire_dcn_bytes``) using ring-algorithm
    factors and the parsed replica-group size of every op.

    ``pod_boundary``: device id where pod 1 starts (256 for the 2-pod mesh);
    an op whose replica group (or permute pair) spans the boundary is
    attributed to DCN in full (conservative — a hierarchical algorithm
    would split it; noted in EXPERIMENTS.md §Roofline).
    """
    out: Dict[str, float] = {}
    wire_ici = 0.0
    wire_dcn = 0.0
    dcn_bytes = 0.0
    n_ops = 0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op, is_start = m.group(1), m.group(2), m.group(3)
        if is_start and shape_str.startswith("("):
            # async start returns (operand, result[, scratch]) — count the
            # result only (second element)
            inner = [s for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape_str)]
            b = _shape_bytes(inner[1]) if len(inner) >= 2 else _shape_bytes(shape_str)
        else:
            b = _shape_bytes(shape_str)
        if b == 0:
            continue
        # XLA:CPU promotes bf16 all-reduces to f32 (reduction computed in
        # f32 on host); the TPU wire width is the SEMANTIC bf16 — count
        # half.  Promoted ops are tagged by their "..._promoted" reducer.
        if "promoted" in line:
            b *= 0.5
        n_ops += 1
        out[op] = out.get(op, 0.0) + b

        crosses = False
        if op == "collective-permute":
            g = 2
            pm = _PAIRS_RE.search(line)
            if pm and pod_boundary is not None:
                pairs = [[int(x) for x in re.findall(r"\d+", p)]
                         for p in pm.group(1).split("},{")]
                crosses = any(len(p) == 2 and
                              (p[0] < pod_boundary) != (p[1] < pod_boundary)
                              for p in pairs)
        else:
            groups = _parse_groups(line)
            g = len(groups[0]) if groups else 1
            if groups and pod_boundary is not None:
                crosses = any(min(grp) < pod_boundary <= max(grp)
                              for grp in groups if grp)
        w = _wire_bytes(op, b, g)
        if crosses:
            wire_dcn += w
            dcn_bytes += b
        else:
            wire_ici += w
    out["_dcn_bytes"] = dcn_bytes
    out["_wire_ici_bytes"] = wire_ici
    out["_wire_dcn_bytes"] = wire_dcn
    out["_n_ops"] = n_ops
    return out


def _probe_depths(cfg) -> tuple:
    """Layer counts for the two unrolled cost probes.  The period p is the
    smallest depth after which the layer plan repeats (zamba2's shared-attn
    cadence, llama4's interleaved MoE); probing at (p, 2p) layers makes the
    linear extrapolation to full depth exact for plan-periodic stacks."""
    import math
    p = 1
    if cfg.shared_attn_every:
        p = cfg.shared_attn_every
    if cfg.n_experts and cfg.moe_every > 1:
        p = p * cfg.moe_every // math.gcd(p, cfg.moe_every)
    L1 = p if p > 1 else 2
    return L1, 2 * L1


def probe_correction(arch: str, shape: str, mesh, mode: str,
                     overrides: Optional[Dict]) -> Dict:
    """Depth-corrected per-device cost terms.

    XLA's ``cost_analysis`` counts a while/scan body ONCE regardless of trip
    count, so the production (scanned) program under-reports FLOPs/bytes/
    collectives by ~n_layers×.  We compile two small UNROLLED models at
    depths (L1, L2) and extrapolate each cost linearly to the full depth:
    ``X(L) = X(L1) + (X(L2)-X(L1))·(L-L1)/(L2-L1)`` — exact for
    plan-periodic layer stacks since cost is affine in depth.
    """
    from repro.configs import get_config
    cfg = get_config(arch)
    L_full = cfg.n_layers
    L1, L2 = _probe_depths(cfg)
    probes = {}
    for L in (L1, L2):
        upd = dict(overrides or {})
        upd.update(n_layers=L, layer_plan=(), scan_layers=False)
        if cfg.is_encoder_decoder:
            upd["n_enc_layers"] = L
        case = build_case_for(arch, shape, mesh, mode, upd)
        with mesh:
            compiled = steps_mod.lower_case(case).compile()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        probes[L] = {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
            "collectives": parse_collectives(hlo),
        }
        del hlo, compiled

    def lerp(x1: float, x2: float) -> float:
        return x1 + (x2 - x1) * (L_full - L1) / (L2 - L1)

    p1, p2 = probes[L1], probes[L2]
    coll_keys = set(p1["collectives"]) | set(p2["collectives"])
    return {
        "probe_depths": [L1, L2],
        "flops_per_device": lerp(p1["flops_per_device"],
                                 p2["flops_per_device"]),
        "bytes_per_device": lerp(p1["bytes_per_device"],
                                 p2["bytes_per_device"]),
        "collectives": {k: lerp(p1["collectives"].get(k, 0.0),
                                p2["collectives"].get(k, 0.0))
                        for k in coll_keys},
        "probes": probes,
    }


def build_case_for(arch: str, shape: str, mesh, mode: str,
                   overrides: Optional[Dict]):
    return steps_mod.build_case(arch, shape, mesh, mode, overrides=overrides)


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             mode: str = "fsdp_tp", overrides: Optional[Dict] = None,
             tag: str = "", verbose: bool = True) -> Dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.size
    rec: Dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "chips": n_chips, "mode": mode, "tag": tag}
    t0 = time.time()
    try:
        case = steps_mod.build_case(arch, shape, mesh, mode,
                                    overrides=overrides)
        if case.skip_reason:
            rec["status"] = "SKIP"
            rec["reason"] = case.skip_reason
            return _finish(rec, out_dir, t0, verbose)
        with mesh:
            lowered = steps_mod.lower_case(case)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        rec["status"] = "OK"
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        rec["collectives"] = parse_collectives(
            hlo, pod_boundary=256 if multi else None)
        rec["hlo_lines"] = hlo.count("\n")
        del hlo, compiled, lowered
        if not multi:
            # depth-corrected costs from unrolled probes (single-pod only —
            # the roofline table reads these; multi-pod is a pass/fail +
            # DCN-attribution check)
            try:
                rec["corrected"] = probe_correction(
                    arch, shape, mesh, mode, overrides)
            except Exception as e:      # probe failure must not fail the cell
                rec["corrected_error"] = f"{type(e).__name__}: {e}"
    except Exception as e:
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _finish(rec, out_dir, t0, verbose)


def _finish(rec: Dict, out_dir: str, t0: float, verbose: bool) -> Dict:
    rec["compile_seconds"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        status = rec["status"]
        extra = ""
        if status == "OK":
            extra = (f" flops/dev={rec['flops_per_device']:.3e}"
                     f" bytes/dev={rec['bytes_per_device']:.3e}"
                     f" coll_ops={rec['collectives'].get('_n_ops', 0)}")
        elif status == "FAIL":
            extra = " " + rec["error"][:200]
        elif status == "SKIP":
            extra = " " + rec["reason"][:80]
        print(f"[{rec['compile_seconds']:7.1f}s] {rec['arch']:28s} "
              f"{rec['shape']:12s} {rec['mesh']:6s} {status}{extra}",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="fsdp_tp")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"_{args.tag}" if args.tag else ""
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_kind}{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") == "OK":
                            continue
                rec = run_cell(arch, shape, mesh_kind, args.out,
                               mode=args.mode, tag=args.tag)
                n_fail += rec["status"] == "FAIL"
    print(f"dry-run complete, {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
