"""Production training launcher.

Runs the SAME ``launch.steps`` train step the dry-run compiles, on whatever
devices exist: the full assigned configs on a real pod/multi-pod mesh, or
``--reduced`` configs on this CPU container (the end-to-end examples).

Features (assignment §large-scale runnability):
  * checkpoint/restart: sharded async save every ``--ckpt-every`` steps;
    ``--resume`` restores params+opt+data cursor (elastic: restore works
    across mesh shapes — shardings are re-derived from logical axes);
  * fault tolerance: the whole program is (step, host)-deterministic, so a
    restarted job replays the exact token stream from the cursor;
  * straggler detection: per-step wall-time EWMA; steps slower than
    ``--straggler-x`` times the EWMA are logged (on hardware this feeds the
    eviction policy — on CPU it just reports);
  * the driver loop is traced by the paper's auto-parallelizer: data loading
    is an ``@io_task`` source, the jitted SPMD step is a pure task, and
    checkpointing is an ``@io_task`` sink — ``--show-graph`` prints the DAG.

Example (CPU, ~17M-param qwen2-family, 50 steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \\
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import get_config, ARCHS
from repro.core import trace, task, io_task, checkpoint_barrier
from repro.core.placement import standard_rules
from repro.checkpoint.store import CheckpointManager, latest_step
from repro.data.pipeline import SyntheticLMDataset, Prefetcher
from repro.launch import steps as steps_mod
from repro.launch.backend import (add_backend_args, execute_traced,
                                  validate_backend_args)
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.models import frontends
from repro.optim.schedules import cosine_schedule
from repro.parallel.mesh import make_mesh_for, single_device_mesh
from repro.parallel.sharding import ShardingCtx


# --------------------------------------------------------------------------
# traced-driver demo tasks (--show-graph).  Module-level and parameterized
# by LITERALS so the traced graph pickles into spawn-started cluster
# workers (see launch/backend.py): each worker rebuilds the model/optimizer
# from the recipe (arch, seed, ...) on first use — weights never cross the
# wire, exactly like shipping the program to a remote node.
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=2)
def _demo_runtime(arch, reduced, remat, mode, lr, warmup, steps, seed):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat=remat)
    ctx = ShardingCtx(single_device_mesh(),
                      standard_rules(mode, pod_axis=None))
    opt = steps_mod.make_optimizer(cfg, lr=cosine_schedule(lr, warmup, steps))
    step = jax.jit(steps_mod.make_train_step(cfg, opt, ctx))
    M = ED if cfg.is_encoder_decoder else TF
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, step, params, opt.init(params)


def _demo_load_batch(arch, reduced, seq, batch, step, seed):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    ds = SyntheticLMDataset(cfg.vocab_size, seq, batch, seed=seed)
    b = {k: np.asarray(v) for k, v in ds.batch_at(step).items()}
    if cfg.family == "vlm":
        b["patch_embeds"] = np.asarray(frontends.synth_patches(cfg, batch))
    if cfg.is_encoder_decoder:
        b["frames"] = np.asarray(frontends.synth_frames(cfg, batch))
    return b


def _demo_train_step(arch, reduced, remat, mode, lr, warmup, steps, seed, b):
    _, step, params, opt_state = _demo_runtime(
        arch, reduced, remat, mode, lr, warmup, steps, seed)
    b = {k: jax.numpy.asarray(v) for k, v in b.items()}
    _, _, metrics = step(params, opt_state, b)
    return float(metrics["total_loss"])


def _demo_save(loss):
    return loss


demo_load_batch = io_task(_demo_load_batch, cost=0.01, name="load_batch",
                          meta={"idempotent": True})
demo_train_step = task(_demo_train_step, cost=1.0, name="spmd_train_step")
demo_save = io_task(_demo_save, cost=0.05, name="save_ckpt")


def build_runtime(args) -> Dict[str, Any]:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat=args.remat)

    n_dev = len(jax.devices())
    mesh = (make_mesh_for(n_dev, model_parallel=args.tp)
            if n_dev > 1 else single_device_mesh())
    rules = standard_rules(args.mode, pod_axis=None)
    ctx = ShardingCtx(mesh, rules)

    M = ED if cfg.is_encoder_decoder else TF
    opt = steps_mod.make_optimizer(cfg, lr=cosine_schedule(
        args.lr, args.warmup, args.steps))
    step_fn = steps_mod.make_train_step(cfg, opt, ctx)

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    return dict(cfg=cfg, mesh=mesh, ctx=ctx, opt=opt, params=params,
                opt_state=opt_state, step=jitted, module=M)


def main(argv: Optional[list] = None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1, help="model-parallel ways")
    ap.add_argument("--mode", default="fsdp_tp")
    ap.add_argument("--remat", default="selective")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-x", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--show-graph", action="store_true",
                    help="trace one driver iteration into a task DAG, "
                         "print it, and execute it on --backend")
    add_backend_args(ap)
    args = ap.parse_args(argv)
    # flag sanity before any model building: --transport/--channel must
    # name something the chosen --backend can actually do
    validate_backend_args(args)

    rt = build_runtime(args)
    cfg = rt["cfg"]
    params, opt_state = rt["params"], rt["opt_state"]
    jitted = rt["step"]

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every, keep=3)
        if args.resume and latest_step(args.ckpt_dir) is not None:
            tree = {"params": params, "opt": opt_state}
            restored, extra = mgr.restore_latest(tree)
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(extra["step"]) + 1
            print(f"resumed from step {extra['step']} "
                  f"(data cursor {start_step})", flush=True)

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch,
                            seed=args.seed)
    pf = Prefetcher(ds, start_step=start_step, depth=2)

    # ---- the paper's interface: trace ONE driver iteration into a DAG ----
    # and really execute it on the selected runtime backend (thread =
    # in-process work stealing; process = spawned cluster workers).  The
    # demo runtime is a fresh single-device, non-donating jit, so executing
    # it cannot invalidate the training loop's donated buffers.
    if args.show_graph:
        def demo_driver():
            b = demo_load_batch(args.arch, args.reduced, args.seq,
                                args.batch, start_step, args.seed)
            loss = demo_train_step(args.arch, args.reduced, args.remat,
                                   args.mode, args.lr, args.warmup,
                                   args.steps, args.seed, b)
            return checkpoint_barrier(demo_save(loss))

        g, _ = trace(demo_driver)
        print(g.summary())
        print(g.to_dot())
        res = execute_traced(g, args)
        print(f"traced-driver step loss: {res[g.outputs[0]]:.4f}",
              flush=True)

    losses = []
    ewma: Optional[float] = None
    stragglers = 0
    t_total = time.time()
    final_step = start_step
    for s in range(start_step, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in pf.next().items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = frontends.synth_patches(cfg, args.batch)
        if cfg.is_encoder_decoder:
            batch["frames"] = frontends.synth_frames(cfg, args.batch)
        t0 = time.time()
        params, opt_state, metrics = jitted(params, opt_state, batch)
        loss = float(metrics["total_loss"])
        dt = time.time() - t0
        if ewma is not None and dt > args.straggler_x * ewma:
            stragglers += 1
            print(f"[straggler] step {s}: {dt:.3f}s vs EWMA {ewma:.3f}s",
                  flush=True)
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        losses.append(loss)
        final_step = s
        if s % args.log_every == 0:
            print(f"step {s:5d} loss {loss:8.4f} "
                  f"aux {float(metrics.get('aux', 0.0)):7.4f} "
                  f"{dt*1e3:7.1f} ms", flush=True)
        if mgr is not None and mgr.maybe_save(
                s, {"params": params, "opt": opt_state}, {"step": s}):
            pass
    if mgr is not None:
        mgr.finish()
    pf.close()
    wall = time.time() - t_total
    print(f"done: steps {start_step}..{final_step} in {wall:.1f}s | "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} | "
          f"stragglers {stragglers}", flush=True)
    return {"losses": losses, "params": params, "wall": wall,
            "start_step": start_step}


if __name__ == "__main__":
    main()
