"""Serving launcher: continuous-batched prefill + decode loop.

The serving analogue of ``train.py``: the same ``launch.steps`` prefill /
decode step functions the dry-run compiles, driven by a simple
request-queue scheduler:

  * requests arrive with a prompt and a token budget;
  * prefill runs one request at a time into a batch slot's KV cache
    (slot-sharded cache, batch dim = ``--slots``);
  * decode advances ALL active slots in lock-step (continuous batching —
    a finished slot is immediately refilled from the queue);
  * the loop itself is the paper's driver: prefill/decode are pure tasks,
    queue pops are IO — ``--show-graph`` prints the traced DAG.

CPU example (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \\
      --requests 6 --slots 2 --max-new 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, ARCHS
from repro.core import task, trace
from repro.launch.backend import (add_backend_args, execute_traced,
                                  validate_backend_args)
from repro.models import transformer as TF
from repro.parallel.mesh import make_mesh_for, single_device_mesh
from repro.core.placement import standard_rules
from repro.parallel.sharding import ShardingCtx


# --------------------------------------------------------------------------
# traced-driver demo tasks (--show-graph): module-level + literal args so
# the graph pickles into spawn-started cluster workers; each worker lazily
# rebuilds params + prefill/decode jits from the recipe (see
# launch/backend.py and the same pattern in train.py).
# --------------------------------------------------------------------------
import functools


@functools.lru_cache(maxsize=2)
def _serve_runtime(arch, reduced, max_len, seed):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    ctx = ShardingCtx(single_device_mesh(),
                      standard_rules("dp_tp", pod_axis=None))
    params = TF.init_params(cfg, jax.random.PRNGKey(seed))
    pre = jax.jit(TF.make_prefill_step(cfg, ctx, max_len=max_len))
    dec = jax.jit(TF.make_decode_step(cfg, ctx))
    return params, pre, dec


def _demo_prefill(arch, reduced, max_len, seed, prompt):
    params, pre, _ = _serve_runtime(arch, reduced, max_len, seed)
    last, cache = pre(params, jnp.asarray(np.asarray(prompt)[None, :]))
    return int(jnp.argmax(last[0])), jax.device_get(cache)


def _demo_decode(arch, reduced, max_len, seed, tok, cache):
    params, _, dec = _serve_runtime(arch, reduced, max_len, seed)
    cache = jax.tree.map(jnp.asarray, cache)
    logits, cache = dec(params, cache, jnp.asarray([[tok]], jnp.int32))
    return int(jnp.argmax(logits[0])), jax.device_get(cache)


def _demo_respond(*toks):
    return list(toks)


demo_prefill = task(_demo_prefill, cost=1.0, name="prefill", n_outputs=2)
demo_decode = task(_demo_decode, cost=0.2, name="decode", n_outputs=2)
demo_respond = task(_demo_respond, cost=0.01, name="respond")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


def synth_requests(n: int, vocab: int, lo: int = 4, hi: int = 12,
                   max_new: int = 8, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        ln = int(rng.integers(lo, hi + 1))
        out.append(Request(i, rng.integers(1, vocab, ln).astype(np.int32),
                           max_new))
    return out


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--show-graph", action="store_true",
                    help="trace one request (prefill + decode chain) into "
                         "a task DAG, print it, and execute on --backend")
    ap.add_argument("--gateway", default=None, metavar="HOST:PORT",
                    help="with --show-graph: submit the traced request "
                         "DAG to a resident repro-gateway as one tenant "
                         "of its shared pool, instead of executing on "
                         "--backend (the gateway must run with "
                         "--start-method spawn for JAX payloads)")
    ap.add_argument("--gateway-token", default=None,
                    help="gateway dial secret")
    ap.add_argument("--tenant", default="serve",
                    help="gateway tenant identity (quota/fair-share/"
                         "accounting bucket)")
    add_backend_args(ap)
    args = ap.parse_args(argv)
    # flag sanity before any model building: --transport/--channel must
    # name something the chosen --backend can actually do
    validate_backend_args(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("serve.py targets decoder-only archs; whisper's "
                         "enc-dec serving is exercised in the dry-run cells")

    n_dev = len(jax.devices())
    mesh = (make_mesh_for(n_dev, model_parallel=args.tp)
            if n_dev > 1 else single_device_mesh())
    ctx = ShardingCtx(mesh, standard_rules("dp_tp", pod_axis=None))

    params = TF.init_params(cfg, jax.random.PRNGKey(args.seed))
    prefill = jax.jit(TF.make_prefill_step(cfg, ctx, max_len=args.max_len))
    decode = jax.jit(TF.make_decode_step(cfg, ctx))

    # ---- traced one-request driver executed on the chosen backend ----
    # The serving analogue of train.py's --show-graph: prefill is the DAG
    # root, decode ticks are pure tasks chained through the (pickled) KV
    # cache, respond collects the token chain — the paper's driver view of
    # one request, executable on either runtime backend.
    if args.show_graph:
        demo_prompt = tuple(
            synth_requests(1, cfg.vocab_size, max_new=3,
                           seed=args.seed)[0].prompt.tolist())

        prefill_t, decode_t, respond_t = (demo_prefill, demo_decode,
                                          demo_respond)
        if args.gateway:
            # run via ``python -m``, this module IS __main__, and its
            # functions would pickle as ``__main__.*`` — unresolvable in
            # the gateway process (whose __main__ is the gateway CLI).
            # Trace against the canonically imported module instead; when
            # serve is already imported normally this is the same object.
            import importlib
            canon = importlib.import_module("repro.launch.serve")
            prefill_t, decode_t, respond_t = (
                canon.demo_prefill, canon.demo_decode, canon.demo_respond)

        def req_driver():
            tok, cache = prefill_t(args.arch, args.reduced, args.max_len,
                                   args.seed, demo_prompt)
            toks = [tok]
            for _ in range(2):
                tok, cache = decode_t(args.arch, args.reduced,
                                      args.max_len, args.seed, tok, cache)
                toks.append(tok)
            return respond_t(*toks)

        g, _ = trace(req_driver)
        print(g.summary())
        if args.gateway:
            # tenant mode: the request DAG runs on a SHARED resident pool
            # next to other tenants' jobs, bit-identical to local
            from repro.gateway import connect as gateway_connect
            with gateway_connect(args.gateway, token=args.gateway_token,
                                 tenant=args.tenant) as gc:
                fut = gc.submit(g, label="serve-request")
                res = fut.result()
            print(f"[gateway {args.gateway}] executed {len(g.nodes)} "
                  f"tasks as tenant {args.tenant} in "
                  f"{fut.wall_time:.3f}s (stats {fut.stats})", flush=True)
        else:
            res = execute_traced(g, args)
        print(f"traced request tokens: {res[g.outputs[0]]}", flush=True)

    reqs = synth_requests(args.requests, cfg.vocab_size,
                          max_new=args.max_new, seed=args.seed)
    queue = list(reqs)
    for r in queue:
        r.t_submit = time.time()

    # slot state
    slot_req: List[Optional[Request]] = [None] * args.slots
    caches: List[Optional[Dict]] = [None] * args.slots
    t0 = time.time()
    n_decode_steps = 0
    finished: List[Request] = []

    while queue or any(s is not None for s in slot_req):
        # admit: fill every free slot (prefill)
        for s in range(args.slots):
            if slot_req[s] is None and queue:
                req = queue.pop(0)
                last, caches[s] = prefill(params, req.prompt[None, :])
                req.t_first = time.time()
                req.out.append(int(jnp.argmax(last[0])))
                slot_req[s] = req
        # decode tick over active slots
        for s in range(args.slots):
            req = slot_req[s]
            if req is None:
                continue
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, caches[s] = decode(params, caches[s], tok)
            req.out.append(int(jnp.argmax(logits[0])))
            n_decode_steps += 1
            if len(req.out) >= req.max_new or \
                    len(req.prompt) + len(req.out) >= args.max_len:
                req.t_done = time.time()
                finished.append(req)
                slot_req[s] = None
                caches[s] = None

    wall = time.time() - t0
    ttft = [r.t_first - r.t_submit for r in finished]
    lat = [r.t_done - r.t_submit for r in finished]
    print(f"served {len(finished)} requests in {wall:.2f}s | "
          f"decode steps {n_decode_steps} "
          f"({n_decode_steps / wall:.1f} tok/s) | "
          f"TTFT p50 {np.median(ttft) * 1e3:.0f} ms | "
          f"latency p50 {np.median(lat) * 1e3:.0f} ms", flush=True)
    for r in finished[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    return {"finished": finished, "wall": wall,
            "decode_steps": n_decode_steps}


if __name__ == "__main__":
    main()
