"""``repro-gateway`` — run the cluster as a resident multi-tenant service.

Brings up one :class:`~repro.gateway.GatewayService` (a shared worker
pool + a client listener) and serves until interrupted.  Clients submit
task graphs from other processes/hosts with::

    with repro.connect("gw-host:7777", token=tok, tenant="serve") as c:
        results = c.submit(graph, inputs).result()

or the one-liner ``repro.run_graph(graph, connect="gw-host:7777")``.

Start a gateway (the client address prints first, flushed, so a
supervisor can capture it before handing it to clients)::

    python -m repro.launch.gateway --n-workers 8 --token s3cret \\
        --client-address 0.0.0.0:7777 \\
        --quota serve=64 --quota batch=32:1000000000 --weight serve=2

Quotas are ``TENANT=MAX_CLUSTERS[:MAX_BYTES]`` (either part empty for
unlimited); ``--weight TENANT=W`` sets fair-share dispatch weights.
With ``--checkpoint-dir`` the pool journals a run log, and a restarted
gateway with ``--resume latest`` re-creates tenant sessions (quotas,
weights) from it — in-flight jobs fail on their clients, which resubmit
(graphs are pure, so the resubmission is bit-identical).

All pool knobs (transport, channel, fusion, fault policy, ...) are the
standard :class:`repro.ClusterConfig` flag group — the operator owns
them; tenants can only set ``repro.config.TENANT_FIELDS`` per job.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.config import ClusterConfig


def _parse_quota(spec: str):
    """``TENANT=CLUSTERS[:BYTES]`` -> (tenant, TenantQuota)."""
    from repro.gateway import TenantQuota
    tenant, sep, rest = spec.partition("=")
    if not sep or not tenant:
        raise argparse.ArgumentTypeError(
            f"quota must be TENANT=MAX_CLUSTERS[:MAX_BYTES], got {spec!r}")
    clusters, _, byts = rest.partition(":")
    try:
        return tenant, TenantQuota(
            max_inflight_clusters=int(clusters) if clusters else None,
            max_store_bytes=int(byts) if byts else None)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"quota limits must be integers, got {spec!r}") from None


def _parse_weight(spec: str):
    tenant, sep, w = spec.partition("=")
    if not sep or not tenant:
        raise argparse.ArgumentTypeError(
            f"weight must be TENANT=FLOAT, got {spec!r}")
    try:
        return tenant, float(w)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"weight must be TENANT=FLOAT, got {spec!r}") from None


def _stats_line(stats: dict) -> str:
    parts = []
    for tenant in sorted(k for k in stats if k != "pool"):
        s = stats[tenant]
        slo = s["slo"]["submit_to_gather_s"]
        p50 = f"{slo['p50'] * 1e3:.0f}ms" if slo["p50"] is not None else "-"
        p99 = f"{slo['p99'] * 1e3:.0f}ms" if slo["p99"] is not None else "-"
        parts.append(
            f"{tenant}[sess {s['sessions']} inflight {s['inflight_jobs']}"
            f" done {s['completed']} fail {s['failed']}"
            f" rej {s['rejected']} p50 {p50} p99 {p99}]")
    return " ".join(parts) or "(no tenants yet)"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-gateway",
        description="resident multi-tenant cluster gateway: one shared "
                    "worker pool, graph submissions over TCP")
    ap.add_argument("--client-address", default="127.0.0.1:0",
                    metavar="HOST:PORT",
                    help="address to bind for client sessions (port 0 = "
                         "ephemeral; printed on startup).  Distinct from "
                         "--connect, the pool's worker listener")
    ap.add_argument("--quota", action="append", default=[],
                    type=_parse_quota, metavar="TENANT=CLUSTERS[:BYTES]",
                    help="per-tenant admission ceiling (repeatable)")
    ap.add_argument("--default-quota", default=None,
                    metavar="CLUSTERS[:BYTES]",
                    help="admission ceiling for tenants without an "
                         "explicit --quota")
    ap.add_argument("--weight", action="append", default=[],
                    type=_parse_weight, metavar="TENANT=W",
                    help="fair-share dispatch weight (repeatable; "
                         "default 1.0)")
    ap.add_argument("--stats-every", type=float, default=0.0, metavar="S",
                    help="print a per-tenant stats line every S seconds "
                         "(0 = off)")
    ClusterConfig.add_flags(ap)
    args = ap.parse_args(argv)

    resume = args.resume
    if resume == "latest":
        from repro.checkpoint.runlog import latest_run
        resume = latest_run(args.checkpoint_dir or "")
        if resume is None:
            print("repro-gateway: no run logs under "
                  f"{args.checkpoint_dir}", file=sys.stderr, flush=True)
            return 2

    cfg = ClusterConfig.from_flags(args, resume=resume)
    default_quota = None
    if args.default_quota:
        default_quota = _parse_quota(f"*={args.default_quota}")[1]

    from repro.gateway import GatewayService
    gw = GatewayService(cfg, client_address=args.client_address,
                        quotas=dict(args.quota),
                        default_quota=default_quota)
    gw.start()
    for tenant, w in args.weight:
        gw.executor.set_tenant_weight(tenant, w)
    # first line out, flushed: clients need this address
    print(f"repro-gateway: serving clients on {gw.address} "
          f"(pool: {cfg.n_workers} workers, worker listener "
          f"{gw.executor.address or '-'}) "
          f"pid {__import__('os').getpid()}", flush=True)

    import threading
    stop_stats = threading.Event()
    if args.stats_every > 0:
        def report() -> None:
            while not stop_stats.wait(args.stats_every):
                print(f"repro-gateway: {_stats_line(gw.stats())}",
                      flush=True)
        threading.Thread(target=report, daemon=True,
                         name="gateway-stats").start()
    try:
        gw.serve_forever()
    except KeyboardInterrupt:
        print("repro-gateway: interrupted, draining", flush=True)
    except BaseException as e:
        print(f"repro-gateway: pool died: {e!r}", file=sys.stderr,
              flush=True)
        stop_stats.set()
        gw.stop()
        return 3
    stop_stats.set()
    gw.stop()
    print("repro-gateway: stopped", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
