"""Version compatibility shims for the installed JAX.

The codebase targets current JAX semantics; on older installs two things
drift and are papered over here:

* ``jax.sharding.AxisType`` may not exist — handled locally in
  :mod:`repro.parallel.mesh`;
* the threefry RNG is not partitionable by default, so putting a sharding
  constraint on the output of ``jax.random.*`` *changes the generated
  values* — breaking the invariant every executor in this repo relies on
  (sharded execution must be bit-identical to the sequential oracle).

:func:`ensure_partitionable_rng` flips ``jax_threefry_partitionable`` on
(newer JAX defaults to it) and is called when any sharding-aware module is
imported, i.e. before either the oracle or the mesh program runs in a given
process, keeping the two streams identical.
"""
from __future__ import annotations

import jax


def ensure_partitionable_rng() -> None:
    try:
        if not jax.config.jax_threefry_partitionable:
            jax.config.update("jax_threefry_partitionable", True)
    except AttributeError:   # flag removed: modern JAX, always partitionable
        pass


def static_axis_size(name) -> int:
    """Static size of a named mesh axis, inside shard_map/pmap bodies.

    ``jax.lax.axis_size`` only exists on newer JAX; older releases expose the
    same number through the trace context's axis environment.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.core.axis_frame(name)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on current JAX but a
    one-element list of dicts on older releases — normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
