"""One frozen configuration object for the cluster runtime.

:class:`ClusterConfig` consolidates the ~30 keyword arguments that accreted
on :class:`repro.cluster.ClusterExecutor` / :func:`repro.core.make_executor`
/ :func:`repro.core.run_graph` over the project's history (transport,
channel, fusion, collectives, checkpointing, fault policy, ...) into a
single validated, hashable value:

* **per-field validation** — every constraint the executor used to check at
  construction time (membership sets, positivity, cross-field requirements
  like ``resume`` needing ``checkpoint_dir``) is enforced in
  ``__post_init__`` with the field's own name in the error;
* **flags** — :meth:`ClusterConfig.add_flags` generates an argparse group
  from field metadata (single source of truth for flag names, help text,
  choices and backend gating used by ``launch/train.py`` / ``serve.py`` /
  ``driver.py``), :meth:`from_flags` rebuilds a config from a parsed
  namespace, and :meth:`to_flags` serializes the non-default fields back
  into CLI tokens (``from_flags(parse(to_flags()))`` round-trips);
* **back-compat shim** — :func:`resolve_config` maps the legacy keyword
  arguments onto config fields, emitting a :class:`DeprecationWarning`
  once per keyword name.  Old call sites keep working for one release:
  ``ClusterExecutor(4, fuse="auto")`` ≡
  ``ClusterExecutor(config=ClusterConfig(n_workers=4, fuse="auto"))``.

The gateway (``repro/gateway``) exposes :data:`TENANT_FIELDS` — the subset
of knobs a tenant may set per submitted job; everything else is fixed by
the operator when the shared pool starts.
"""
from __future__ import annotations

import argparse
import dataclasses
import warnings
from dataclasses import dataclass, field, fields
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["ClusterConfig", "resolve_config", "TENANT_FIELDS"]

_START_METHODS = ("fork", "spawn", "forkserver")
_TRANSPORTS = ("auto", "shm", "sock", "tcp", "driver")
_CHANNELS = ("pipe", "spawn", "tcp")
_WORKER_SPECS = ("local", "remote")


def _flag(help: str, *, choices: Optional[Tuple[str, ...]] = None,
          parse: Any = None, backend: Optional[str] = None,
          metavar: Optional[str] = None) -> Dict[str, Any]:
    """Field metadata for a CLI-exposed knob.

    ``backend="process"`` marks a flag the thread backend cannot honour —
    ``validate_flags`` rejects a non-default value with the flag's own
    vocabulary (see ``launch/backend.py``).
    """
    return {"help": help, "choices": choices, "parse": parse,
            "backend": backend, "metavar": metavar}


def _opt_str(s: str) -> Optional[str]:
    return None if s in ("", "none", "auto") else s


def _opt_float(s: str) -> Optional[float]:
    return None if s in ("", "none") else float(s)


def _opt_int(s: str) -> Optional[int]:
    return None if s in ("", "none", "auto") else int(s)


@dataclass(frozen=True)
class ClusterConfig:
    """Every runtime knob of the cluster backend, as one frozen value.

    Fields mirror the historical ``ClusterExecutor`` keyword arguments
    one-to-one (same names, same defaults), so the legacy-kwarg shim is a
    pure rename-free mapping.  ``None`` means "backend default" for the
    optional fields (``shm_threshold`` falls back to the serde default,
    ``channel`` is inferred from ``start_method``/``connect``/pool shape).
    """

    # ---- pool shape / scheduling -----------------------------------
    n_workers: int = field(default=2, metadata=_flag(
        "worker-process pool size (a 'workers' spec list overrides it)"))
    policy: str = field(default="critical_path", metadata=_flag(
        "list-scheduling priority policy for the driver's placement plan"))
    worker_speed: Optional[Tuple[float, ...]] = None
    pipeline_depth: int = field(default=2, metadata=_flag(
        "super-tasks kept in flight per worker (driver-side pipelining)"))
    outputs_only: bool = field(default=False, metadata=_flag(
        "return only marked outputs and GC intermediates eagerly "
        "(memory-bounded production mode)"))
    progress_timeout: float = field(default=60.0, metadata=_flag(
        "seconds without any cluster completion before the run aborts"))
    start_method: str = field(default="fork", metadata=_flag(
        "multiprocessing start method for local workers",
        choices=_START_METHODS))
    seed: int = field(default=0, metadata=_flag(
        "tie-break seed for the scheduler"))
    # ---- data plane -------------------------------------------------
    transport: str = field(default="auto", metadata=_flag(
        "process-backend data plane: zero-copy shared memory, direct "
        "unix-socket or TCP pulls, or the driver-relayed pipe path "
        "(A/B baseline)", choices=_TRANSPORTS, backend="process"))
    shm_threshold: Optional[int] = None
    bandwidth: float = float(256 << 20)
    # ---- control plane ----------------------------------------------
    channel: Optional[str] = field(default=None, metadata=_flag(
        "process-backend control plane: in-host pipes (forked/spawned "
        "workers) or the multi-host TCP listener (workers dial in; see "
        "repro-worker)", choices=("auto",) + _CHANNELS, parse=_opt_str,
        backend="process"))
    connect: Optional[str] = field(default=None, metadata=_flag(
        "host:port the driver binds for dialing workers (TCP channel); "
        "port 0 picks an ephemeral port", parse=_opt_str,
        metavar="HOST:PORT", backend="process"))
    workers: Optional[Tuple[str, ...]] = None
    token: Optional[str] = field(default=None, metadata=_flag(
        "shared secret for the TCP handshake (workers and clients must "
        "present it)", parse=_opt_str, backend="process"))
    accept_timeout: float = 60.0
    heartbeat_interval: float = field(default=1.0, metadata=_flag(
        "seconds between driver->worker liveness probes (TCP channel)",
        backend="process"))
    heartbeat_timeout: float = field(default=15.0, metadata=_flag(
        "seconds of heartbeat silence before a worker is suspected dead",
        backend="process"))
    heartbeat_jitter: float = 0.25
    # ---- graph compilation / execution policy -----------------------
    speculate_after: Optional[float] = field(default=None, metadata=_flag(
        "speculatively re-execute a task running longer than X times its "
        "expected duration on an idle worker (first completion wins; off "
        "by default — see docs/speculation.md)", parse=_opt_float,
        metavar="X", backend="process"))
    fuse: Any = field(default="off", metadata=_flag(
        "compile the task graph into super-tasks before dispatch (fuse "
        "chains, small fan-ins, sibling groups) so fine-grained graphs "
        "stop paying one driver round-trip per node; N caps members per "
        "super-task (see docs/fusion.md)", metavar="{auto,off,N}",
        backend="process"))
    collectives: Any = field(default="auto", metadata=_flag(
        "lower broadcast/scatter/gather/all_reduce nodes into staged tree "
        "hops over the peer data plane instead of N×M point-to-point "
        "edges; off executes each collective's dense fallback on one "
        "worker, N overrides the tree arity (see docs/collectives.md)",
        metavar="{auto,off,N}", backend="process"))
    adaptive: str = field(default="off", metadata=_flag(
        "profile-guided adaptive replanning: auto feeds measured task "
        "durations back into the planner mid-run (calibrated scheduling "
        "costs, re-fusion of lopsided not-yet-dispatched clusters, "
        "derived keep-parallelism and speculate-after); off pins every "
        "planning decision to plan time (see docs/adaptive.md)",
        choices=("off", "auto"), backend="process"))
    keep_parallelism: Optional[int] = field(default=None, metadata=_flag(
        "sibling-packing parallelism floor for fusion and re-fusion; "
        "default derives it from the live worker count under "
        "--adaptive auto and uses the static fusion default otherwise",
        parse=_opt_int, metavar="N", backend="process"))
    refuse_skew: float = field(default=4.0, metadata=_flag(
        "duration-skew hysteresis threshold (max/median of observed "
        "seconds-per-cost-unit) above which the adaptive runtime "
        "re-fuses the not-yet-dispatched frontier", metavar="X",
        backend="process"))
    # ---- checkpointing / resume -------------------------------------
    checkpoint_dir: Optional[str] = field(default=None, metadata=_flag(
        "directory for the driver's append-only run log (enables "
        "--resume after a driver crash)", parse=_opt_str,
        backend="process"))
    checkpoint_interval: float = field(default=0.25, metadata=_flag(
        "seconds between run-log fsync batches", backend="process"))
    resume: Optional[str] = field(default=None, metadata=_flag(
        "run id (or 'latest') to resume from checkpoint_dir",
        parse=_opt_str, metavar="RUN_ID", backend="process"))
    rejoin_timeout: float = 10.0
    rejoin_window: Optional[float] = None
    # ---- failure policy / chaos hooks -------------------------------
    fail_worker: Optional[Tuple[int, int]] = None
    join_after: Optional[Tuple[int, int]] = None
    fail_driver: Optional[int] = None
    fault_plan: Optional[Any] = None
    suspect_grace: float = field(default=5.0, metadata=_flag(
        "seconds a heartbeat-silence death verdict is held as suspicion "
        "before lineage recovery runs", backend="process"))
    quarantine_after: int = 3
    probe_interval: float = 2.0
    fetch_retry: Optional[Any] = None

    # ------------------------------------------------------------ checks
    def __post_init__(self) -> None:
        def norm(name: str, value: Any) -> None:
            object.__setattr__(self, name, value)

        if self.worker_speed is not None:
            norm("worker_speed", tuple(float(s) for s in self.worker_speed))
        if self.workers is not None:
            norm("workers", tuple(self.workers))
            bad = [w for w in self.workers if w not in _WORKER_SPECS]
            if bad:
                raise ValueError(
                    f"workers: unknown worker spec(s) {bad!r} "
                    f"(expected one of {_WORKER_SPECS})")
            norm("n_workers", len(self.workers))
        if self.fail_worker is not None:
            norm("fail_worker", tuple(self.fail_worker))
        if self.join_after is not None:
            norm("join_after", tuple(self.join_after))
        if self.n_workers < 1:
            raise ValueError("n_workers >= 1")
        if self.start_method not in _START_METHODS:
            raise ValueError(
                f"unknown start_method {self.start_method!r}")
        if self.transport not in _TRANSPORTS:
            raise ValueError(f"unknown transport {self.transport!r} "
                             f"(expected one of {_TRANSPORTS})")
        if self.channel is not None and self.channel not in _CHANNELS:
            raise ValueError(f"unknown channel {self.channel!r} "
                             f"(expected one of {_CHANNELS})")
        if self.shm_threshold is not None and self.shm_threshold < 1:
            raise ValueError("shm_threshold must be >= 1 byte")
        if self.bandwidth <= 0:
            raise ValueError("bandwidth must be positive bytes/second")
        if self.speculate_after is not None and self.speculate_after <= 0:
            raise ValueError("speculate_after must be a positive "
                             "×expected-duration multiple (or None to "
                             "disable speculation)")
        if self.fail_driver is not None and self.fail_driver < 1:
            raise ValueError("fail_driver must be a positive completion "
                             "count (or None to disable crash emulation)")
        if self.adaptive not in ("off", "auto"):
            raise ValueError(f"unknown adaptive mode {self.adaptive!r} "
                             "(expected 'off' or 'auto')")
        if self.keep_parallelism is not None and self.keep_parallelism < 1:
            raise ValueError("keep_parallelism must be >= 1 sibling "
                             "groups (or None to derive it)")
        if self.refuse_skew <= 1.0:
            raise ValueError("refuse_skew must be > 1 (a max/median "
                             "duration-skew ratio)")
        if self.resume is not None and self.checkpoint_dir is None:
            raise ValueError("resume requires checkpoint_dir")
        if self.checkpoint_interval < 0:
            raise ValueError("checkpoint_interval must be >= 0 seconds")
        for name in ("progress_timeout", "accept_timeout",
                     "heartbeat_interval", "heartbeat_timeout"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be positive seconds")
        # fuse/collectives specs: validate at the field, not deep in the
        # executor (lazy import: config must stay importable without jax)
        from repro.core.fusion import parse_fuse_spec
        from repro.core.collectives import parse_collectives_spec
        norm("fuse", parse_fuse_spec(self.fuse))
        norm("collectives", parse_collectives_spec(self.collectives))

    # ------------------------------------------------------------- flags
    @classmethod
    def flag_fields(cls) -> List[Any]:
        """Dataclass fields that carry CLI metadata, in declaration order."""
        return [f for f in fields(cls) if "help" in f.metadata]

    @classmethod
    def add_flags(cls, ap: argparse.ArgumentParser,
                  names: Optional[Sequence[str]] = None,
                  title: str = "cluster runtime",
                  defaults: Optional[Dict[str, Any]] = None) -> None:
        """Add one argparse group generated from field metadata.

        ``names`` restricts the group to a subset of flaggable fields (the
        launchers expose only the knobs their workloads exercise); flag
        destinations are the field names, so :meth:`from_flags` can read
        any namespace this produced.  ``defaults`` overrides per-flag
        defaults without forking the help text (the launchers default
        ``--fuse`` to ``auto`` while the library default stays ``off``).
        """
        grp = ap.add_argument_group(title)
        want = set(names) if names is not None else None
        for f in cls.flag_fields():
            if want is not None and f.name not in want:
                continue
            meta = f.metadata
            flag = "--" + f.name.replace("_", "-")
            default = f.default
            if defaults is not None and f.name in defaults:
                default = defaults[f.name]
            if f.type in ("bool", bool) or isinstance(default, bool):
                grp.add_argument(flag, action="store_true",
                                 default=default, help=meta["help"])
                continue
            parse = meta["parse"]
            if parse is None:
                parse = type(default) if default is not None else str
            kw: Dict[str, Any] = {"default": default, "type": parse,
                                  "help": meta["help"]}
            if meta["choices"]:
                kw["choices"] = list(meta["choices"])
                # an optional-str field parses "auto" to None, which must
                # stay an admissible choice post-parse
                if parse is _opt_str:
                    kw["choices"] = [None] + [c for c in kw["choices"]
                                              if c != "auto"]
                    kw["metavar"] = "{%s}" % ",".join(meta["choices"])
            if meta["metavar"]:
                kw["metavar"] = meta["metavar"]
            grp.add_argument(flag, **kw)

    @classmethod
    def from_flags(cls, args: argparse.Namespace,
                   names: Optional[Sequence[str]] = None,
                   **overrides: Any) -> "ClusterConfig":
        """Build a config from a parsed namespace (only the fields whose
        destinations are present), plus explicit ``overrides``.

        A launcher that exposed a subset via ``add_flags(names=...)``
        must read back the SAME subset: its own unrelated flags may
        share a destination with a config field (``train.py --resume``
        is a model-checkpoint toggle, ``--seed`` a data-order seed) and
        would otherwise leak into the cluster config."""
        kw: Dict[str, Any] = {}
        want = set(names) if names is not None else None
        for f in cls.flag_fields():
            if want is not None and f.name not in want:
                continue
            if hasattr(args, f.name):
                kw[f.name] = getattr(args, f.name)
        kw.update(overrides)
        return cls(**kw)

    def to_flags(self) -> List[str]:
        """Serialize the non-default flaggable fields back to CLI tokens.

        ``from_flags(parser.parse_args(cfg.to_flags()))`` reproduces
        ``cfg`` for every field that has a flag; non-flag fields (fault
        plans, retry policies, injection hooks) are process-local values
        with no CLI form and are intentionally dropped.
        """
        out: List[str] = []
        for f in self.flag_fields():
            value = getattr(self, f.name)
            if value == f.default:
                continue
            flag = "--" + f.name.replace("_", "-")
            if isinstance(value, bool):
                if value:
                    out.append(flag)
            elif value is None:
                out.extend([flag, "none"])
            else:
                out.extend([flag, str(value)])
        return out

    # ------------------------------------------------------------ helpers
    def replace(self, **changes: Any) -> "ClusterConfig":
        return dataclasses.replace(self, **changes)

    def executor_kwargs(self) -> Dict[str, Any]:
        """The config as the executor's legacy keyword dict (shim-free
        internal path; also what the gateway journals per session)."""
        return {f.name: getattr(self, f.name) for f in fields(self)}


#: knobs a gateway tenant may set per submitted job; everything else
#: (pool shape, transports, fusion/collectives specs, checkpointing,
#: fault policy) belongs to the operator who started the shared pool —
#: jobs share one resident plan universe, so even the fuse spec is
#: pool-level.  A submit carrying any other key is rejected before the
#: graph is unpickled (repro/gateway/service.py).  See docs/gateway.md.
TENANT_FIELDS = frozenset({"outputs_only", "label"})


_FIELD_NAMES = tuple(f.name for f in fields(ClusterConfig))
_warned_kwargs: set = set()


def _warn_legacy(name: str, owner: str) -> None:
    if name in _warned_kwargs:
        return
    _warned_kwargs.add(name)
    warnings.warn(
        f"passing {name!r} as a keyword to {owner} is deprecated; pass "
        f"config=repro.ClusterConfig({name}=...) instead (legacy keywords "
        f"keep working for one release)",
        DeprecationWarning, stacklevel=4)


def resolve_config(config: Optional[ClusterConfig],
                   legacy: Dict[str, Any], *,
                   owner: str = "ClusterExecutor") -> ClusterConfig:
    """Merge legacy keyword arguments into ``config`` (shim).

    Every historical keyword maps one-to-one onto a :class:`ClusterConfig`
    field; unknown names raise ``TypeError`` exactly like a misspelled
    keyword always did.  Legacy keywords override ``config`` fields and
    warn once per name per process.
    """
    unknown = sorted(set(legacy) - set(_FIELD_NAMES))
    if unknown:
        raise TypeError(
            f"{owner}() got unexpected keyword argument(s) {unknown}; "
            f"valid ClusterConfig fields: {sorted(_FIELD_NAMES)}")
    if config is None:
        config = ClusterConfig() if legacy else _DEFAULT_CONFIG
    if legacy:
        for name in legacy:
            _warn_legacy(name, owner)
        config = dataclasses.replace(config, **legacy)
    return config


_DEFAULT_CONFIG = ClusterConfig()
