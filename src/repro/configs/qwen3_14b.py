"""qwen3-14b [dense] — GQA 40H/8kv + per-head RMS qk_norm.
40L d_model=5120 d_ff=17408 vocab=151936. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
