"""whisper-tiny [audio] — encoder-decoder; conv frontend is a STUB
(``input_specs`` feeds precomputed frame embeddings at enc_seq=1500).
4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    is_encoder_decoder=True,
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    enc_seq=1500,
    frontend="audio",
    mlp_act="gelu",
    norm_type="layernorm",
    use_rope=False,
    qkv_bias=True,
    tie_embeddings=True,
)
