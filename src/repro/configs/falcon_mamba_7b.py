"""falcon-mamba-7b [ssm] — attention-free Mamba1.
64L d_model=4096 ssm_state=16 vocab=65024 (d_inner = 2×4096 = 8192).
[arXiv:2410.05355; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free); keeps config uniform
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,               # mamba blocks have no separate FFN
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
)
