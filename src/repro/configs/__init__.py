"""Architecture registry: one module per assigned arch (+ the paper's own
matmul workload).  ``get_config(name)`` returns the exact published config;
``get_config(name).reduced()`` is the CPU smoke variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "zamba2-7b",
    "qwen3-14b",
    "yi-9b",
    "qwen2-7b",
    "granite-20b",
    "falcon-mamba-7b",
    "dbrx-132b",
    "llama4-maverick-400b-a17b",
    "llava-next-34b",
    "whisper-tiny",
]

_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "qwen3-14b": "qwen3_14b",
    "yi-9b": "yi_9b",
    "qwen2-7b": "qwen2_7b",
    "granite-20b": "granite_20b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "llava-next-34b": "llava_next_34b",
    "whisper-tiny": "whisper_tiny",
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
