"""dbrx-132b [moe] — 16 experts top-4 (fine-grained), GQA 48H/8kv.
40L d_model=6144 d_ff(expert)=10752 vocab=100352. [hf:databricks/dbrx-base; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_token=4,
    capacity_factor=1.25,
    moe_group=4096,
    rope_theta=500_000.0,
)
