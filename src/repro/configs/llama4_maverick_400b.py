"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + one shared expert.
48L d_model=5120 40H/8kv d_ff(expert)=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Training this arch uses Adafactor (see launch/train.py): Adam's 2×f32 state
on 400B params (3.2 TB) cannot fit a single v5e-256 pod alongside weights.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    moe_every=2,            # interleaved: every other layer is MoE
    n_shared_experts=1,
    capacity_factor=1.25,
    moe_group=4096,
    param_dtype="bfloat16",
    rope_theta=500_000.0,
)
