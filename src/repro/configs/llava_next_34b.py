"""llava-next-34b [vlm] — backbone only (anyres tiling frontend is a STUB:
``input_specs`` feeds precomputed patch embeddings).
60L d_model=7168 56H/8kv d_ff=20480 vocab=64000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    n_patches=576,          # one base-resolution tile; anyres adds more
    rope_theta=5_000_000.0,
)
