"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
TPU — the same call sites work in both worlds.  Model code selects the
kernel path with ``use_kernels(cfg)``; the jnp reference path remains the
default so the 512-device dry-run lowers without a TPU backend.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from . import ref
from .matmul_pallas import matmul as _matmul_pallas
from .flash_attention import flash_attention as _flash_pallas
from .ssm_scan import ssm_scan as _ssm_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(x, y, *, impl: str = "pallas", interpret: Optional[bool] = None):
    if impl == "ref":
        return ref.matmul(x, y)
    return _matmul_pallas(x, y, interpret=_default_interpret()
                          if interpret is None else interpret)


def flash_attention(q, k, v, *, causal: bool = True, impl: str = "pallas",
                    bq: int = 256, bk: int = 512,
                    interpret: Optional[bool] = None):
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal)
    return _flash_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                         interpret=_default_interpret()
                         if interpret is None else interpret)


def ssm_scan(x, dt, B, C, A, *, impl: str = "pallas", chunk: int = 64,
             bd: int = 512, interpret: Optional[bool] = None):
    if impl == "ref":
        return ref.ssm_scan(x, dt, B, C, A)
    return _ssm_pallas(x, dt, B, C, A, chunk=chunk, bd=bd,
                       interpret=_default_interpret()
                       if interpret is None else interpret)
