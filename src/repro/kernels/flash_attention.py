"""Flash attention (blocked online softmax) for TPU, with GQA.

Hardware adaptation notes (vs the CUDA FlashAttention algorithm):
* the (bq, d) query tile and (bk, d) K/V tiles live in VMEM; the running
  (m, l, acc) statistics live in VMEM scratch that persists across the kv
  grid dimension — TPU grids execute sequentially over the last dimension,
  which replaces the CUDA thread-block loop;
* block sizes default to (256 q × 512 kv): bq·d + 2·bk·d + bq·bk f32
  ≈ 1.1 MB at d=128 — far under the 16 MB VMEM, leaving room for the
  double-buffered HBM→VMEM prefetch of the next K/V tiles;
* matmul dims stay multiples of 128 for the MXU; softmax statistics are
  float32 regardless of input dtype;
* causal masking skips FULLY-masked kv blocks via ``pl.when`` (no compute,
  no VREG traffic) and masks the diagonal block element-wise.

GQA: ``n_heads`` query heads share ``n_kv_heads`` K/V heads via the kv
index_map (h → h·KH/H), so no K/V repetition is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, scale: float, bq: int, bk: int,
                  kv_steps: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal (fully masked)
        @pl.when(ik * bk <= iq * bq + bq - 1)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ik == kv_steps - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows → 0
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 256, bk: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KH, Sk, D); H % KH == 0. Returns (B, H, Sq, D).

    Sq % bq == 0 and Sk % bk == 0 required (pad upstream; model seq lens are
    powers of two).
    """
    B, H, Sq, D = q.shape
    _, KH, Sk, _ = k.shape
    assert H % KH == 0
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    group = H // KH
    scale = D ** -0.5
    kv_steps = Sk // bk

    return pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, scale=scale,
                          bq=bq, bk=bk, kv_steps=kv_steps),
        grid=(B, H, Sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, _g=group: (b, h // _g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, _g=group: (b, h // _g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running sum l
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
