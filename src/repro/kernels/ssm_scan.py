"""Chunked selective-scan (Mamba) kernel.

TPU adaptation of the CUDA selective-scan: the GPU kernel parallelizes over
channels with warp-level scans; on TPU we instead
* put the (bd, N) state in VMEM scratch, persisting across the sequential
  chunk grid dimension (grid order replaces the CUDA block loop);
* tile channels (bd = 512 lanes) over a parallel grid dimension;
* run the in-chunk recurrence as an unrolled VPU loop over ``chunk`` steps
  (elementwise FMAs on (bd, N) tiles — no MXU needed, this kernel is
  bandwidth-bound and the roofline term that matters is HBM bytes).

VMEM: x/dt (chunk, bd) + B/C (chunk, N) + state (bd, N) f32
≈ 0.6 MB at chunk=64, bd=512, N=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *,
                 chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)               # (bd, N)
    h = h_ref[...]                                    # (bd, N) f32
    ys = []
    for t in range(chunk):                            # unrolled VPU loop
        dt = dt_ref[0, t].astype(jnp.float32)         # (bd,)
        xt = x_ref[0, t].astype(jnp.float32)          # (bd,)
        Bt = b_ref[0, t].astype(jnp.float32)          # (N,)
        Ct = c_ref[0, t].astype(jnp.float32)          # (N,)
        da = jnp.exp(dt[:, None] * A)                 # (bd, N)
        h = da * h + (dt * xt)[:, None] * Bt[None, :]
        ys.append(jnp.sum(h * Ct[None, :], axis=1))   # (bd,)
    h_ref[...] = h
    y_ref[0] = jnp.stack(ys, axis=0).astype(y_ref.dtype)   # (chunk, bd)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array, *, chunk: int = 64, bd: int = 512,
             interpret: bool = True) -> jax.Array:
    """Selective scan: x, dt (Bsz, S, D); B, C (Bsz, S, N); A (D, N).
    Returns y (Bsz, S, D).  S % chunk == 0, D % bd == 0."""
    Bsz, S, D = x.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    bd = min(bd, D)
    assert S % chunk == 0 and D % bd == 0
    nc = S // chunk

    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        # channel tiles parallel; chunks sequential (state carried in VMEM)
        grid=(Bsz, D // bd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),   # x
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),   # dt
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),    # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, c: (d, 0)),             # A
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A)
