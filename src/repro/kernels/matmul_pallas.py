"""Blocked MXU matmul — the compute payload of the paper's Fig. 2 benchmark.

TPU mapping of the paper's "large random matrix multiplication" tasks:
(bm, bk) × (bk, bn) VMEM tiles streamed over a (M/bm, N/bn, K/bk) grid with a
float32 VMEM accumulator.  Block sizes default to 256/512 — multiples of the
128-lane MXU dimension, sized so 3 tiles + accumulator ≈ 1.4 MB ≪ 16 MB VMEM
(double buffering headroom for the HBM→VMEM pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fit_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want, preferring multiples of
    128 (the MXU lane width) when one divides."""
    want = min(want, dim)
    for b in range(want - want % 128, 0, -128):
        if dim % b == 0:
            return b
    for b in range(want, 0, -1):
        if dim % b == 0:
            return b
    return dim


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512, interpret: bool = True) -> jax.Array:
    """x: (M, K) @ y: (K, N) -> (M, N).  Block sizes are fitted down to
    divisors of the dims (128-multiples preferred for the MXU)."""
    M, K = x.shape
    K2, N = y.shape
    assert K == K2
    bm, bn, bk = _fit_block(M, bm), _fit_block(N, bn), _fit_block(K, bk)
    k_steps = K // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
