"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32)
                   ).astype(x.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KH, Sk, D)."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    if H != KH:
        k = jnp.repeat(k, H // KH, axis=1)
        v = jnp.repeat(v, H // KH, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array) -> jax.Array:
    """Naive step-by-step selective scan (float32)."""
    Bsz, S, D = x.shape
    N = A.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[:, :, None] * Af[None])           # (Bsz, D, N)
        h = da * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, D, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
                          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(x.dtype)
