"""repro.core — the paper's auto-parallelizer.

Public API:
  task, io_task, trace, placeholder, checkpoint_barrier   (build a DAG)
  broadcast, scatter, gather, all_reduce                  (collective nodes:
      group-communication shapes traced like pure tasks and compiled to
      staged trees — repro.core.collectives, docs/collectives.md)
  TaskGraph                                               (the IR)
  fuse, FusedPlan, parse_fuse_spec                        (graph compilation:
      cluster the DAG into super-tasks before dispatch — repro.core.fusion)
  lower_collectives, parse_collectives_spec               (collective lowering)
  list_schedule, replan                                   (static scheduling)
  ClusterSim, simulate, WorkerEvent                       (cluster simulator)
  Executor, execute_sequential, ThreadedExecutor,
  run_graph, make_executor                                (real execution;
      backend="thread" stays in-process, backend="process" selects the
      multi-process repro.cluster.ClusterExecutor runtime)
  MeshExecutor                                            (SPMD lowering)
  recovery_plan, recover                                  (lineage FT)
  standard_rules, logical_to_spec, tree_shardings         (auto-sharding)
"""
from .graph import TaskGraph, TaskNode, TaskKind, GraphError
from .tracing import (task, io_task, trace, placeholder, checkpoint_barrier,
                      broadcast, scatter, gather, all_reduce,
                      Trace, TaskRef, fuse_cheap_chains, substitute_refs)
from .collectives import (lower_collectives, parse_collectives_spec,
                          tree_fold, collective_stages,
                          add_all_reduce, add_gather, add_broadcast,
                          add_scatter)
from .purity import infer_purity, declare, declared_purity
from .effects import EffectToken, initial_token
from .fusion import (FusedPlan, WorkerFusionView, fuse, identity_plan,
                     parse_fuse_spec)
from .scheduler import (Schedule, Placement, list_schedule, replan,
                        theoretical_speedup, collective_comm_cost)
from .simulator import ClusterSim, SimResult, WorkerEvent, simulate
from .executor import (execute_sequential, ThreadedExecutor, run_graph,
                       make_executor, output_values, Executor, TaskFailed)
from .lineage import recovery_plan, recover, replay, lineage_depth, NonIdempotentReplay
from .placement import (standard_rules, sequence_parallel_rules,
                        logical_to_spec, sharding_for, tree_specs,
                        tree_shardings, ValueInfo, refine_placements,
                        resharding_bytes, total_resharding_bytes, spec_shards)
from .mesh_executor import MeshExecutor

__all__ = [k for k in dir() if not k.startswith("_")]
