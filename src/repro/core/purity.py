"""Purity inference — the JAX analogue of reading a Haskell type signature.

In the paper, ``f :: A -> B`` is pure and ``f :: IO B`` is effectful, and the
auto-parallelizer decides *from the signature alone* whether a call can float.
JAX gives us the same decidability: a function that traces to a jaxpr with an
empty effect set is pure by construction; anything that cannot be traced (or
that the user declares with ``@io_task``) is treated as ``IO``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

# Explicit declarations take precedence (the "type signature" the user wrote).
_DECLARED: dict[int, bool] = {}   # id(fn) -> is_pure


def declare(fn: Callable, pure: bool) -> None:
    _DECLARED[id(fn)] = pure


def declared_purity(fn: Callable) -> Optional[bool]:
    return _DECLARED.get(id(fn))


def infer_purity(fn: Callable, *abstract_args: Any, **abstract_kwargs: Any) -> bool:
    """Return True iff ``fn`` is pure.

    Order of evidence (mirrors "check the type signature"):
      1. an explicit ``declare``/``@io_task``/``@task`` annotation;
      2. trace to a jaxpr and inspect ``jaxpr.effects`` — JAX's effect system
         records io_callback/debug effects exactly like ``IO`` in a type;
      3. if tracing itself raises (side-effecting Python, unhashable state...),
         conservatively report impure.
    """
    d = declared_purity(fn)
    if d is not None:
        return d
    try:
        jaxpr = jax.make_jaxpr(fn)(*abstract_args, **abstract_kwargs)
    except Exception:
        return False
    return len(jaxpr.effects) == 0
