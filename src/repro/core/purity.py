"""Purity inference — the JAX analogue of reading a Haskell type signature.

In the paper, ``f :: A -> B`` is pure and ``f :: IO B`` is effectful, and the
auto-parallelizer decides *from the signature alone* whether a call can float.
JAX gives us the same decidability: a function that traces to a jaxpr with an
empty effect set is pure by construction; anything that cannot be traced (or
that the user declares with ``@io_task``) is treated as ``IO``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional
import weakref

import jax

# Explicit declarations take precedence (the "type signature" the user wrote).
# Weak-keyed: an ``id()``-keyed dict would let a dead function's entry leak
# onto whatever new function the allocator places at the same address.
_DECLARED: "weakref.WeakKeyDictionary[Callable, bool]" = \
    weakref.WeakKeyDictionary()


def declare(fn: Callable, pure: bool) -> None:
    try:
        _DECLARED[fn] = pure
        return
    except TypeError:   # non-weakref-able callable: annotate directly
        pass
    try:
        fn.__declared_pure__ = pure
    except (AttributeError, TypeError):
        # neither weakref-able nor attribute-assignable (numpy ufuncs, C
        # builtins): leave undeclared — infer_purity falls back to jaxpr
        # inspection, and the @task wrapper passes purity explicitly anyway
        pass


def declared_purity(fn: Callable) -> Optional[bool]:
    try:
        d = _DECLARED.get(fn)
    except TypeError:
        d = None
    if d is None:
        d = getattr(fn, "__declared_pure__", None)
    return d


def infer_purity(fn: Callable, *abstract_args: Any, **abstract_kwargs: Any) -> bool:
    """Return True iff ``fn`` is pure.

    Order of evidence (mirrors "check the type signature"):
      1. an explicit ``declare``/``@io_task``/``@task`` annotation;
      2. trace to a jaxpr and inspect ``jaxpr.effects`` — JAX's effect system
         records io_callback/debug effects exactly like ``IO`` in a type;
      3. if tracing itself raises (side-effecting Python, unhashable state...),
         conservatively report impure.
    """
    d = declared_purity(fn)
    if d is not None:
        return d
    try:
        jaxpr = jax.make_jaxpr(fn)(*abstract_args, **abstract_kwargs)
    except Exception:
        return False
    return len(jaxpr.effects) == 0
