"""Collective operations as first-class graph nodes.

A wide shuffle or reduction written the paper's way — every consumer
fans in from every producer — compiles to N×M point-to-point edges the
scheduler prices one by one, and BENCH_transfer showed those fan-ins
dominating shuffle cells.  Following "Group Communication Patterns for
High Performance Computing in Scala" (PAPERS.md), this module makes the
*pattern* a node: ``broadcast`` / ``scatter`` / ``gather`` /
``all_reduce`` are traced like any pure task (``TaskKind.COLLECTIVE``),
and :func:`lower_collectives` compiles each one into a **tree of staged
hops** before the fusion pass and the scheduler ever see the graph.

Two invariants make the whole thing safe:

1. **The unlowered node is executable.**  Every collective node carries
   a real ``fn`` computing its dense semantics (``all_reduce`` → a
   deterministic tree fold, ``gather`` → the input tuple, ``broadcast``
   → identity, ``scatter`` → contiguous chunks), so
   ``execute_sequential``, the thread backend, and ``collectives="off"``
   need no changes — the node *is* its own point-to-point fallback.
2. **Bracketing is semantics, fixed at trace time.**  Floating-point
   reduction is not associative, so the *shape* of the combine tree is
   part of the value.  :func:`tree_fold` (the dense fn) and the lowered
   stage nodes share one grouping rule — contiguous ``arity``-sized
   chunks per level, left-fold within a chunk — so the distributed tree
   computes **bit-for-bit** the same value as the oracle, healthy or
   under SIGKILL-triggered lineage replay.  Tuning the arity re-traces
   (or re-lowers) the graph; it never silently changes results between
   backends because both sides read the same ``arity``.

Lowering is a deterministic graph→graph rewrite in the style of
:func:`repro.core.tracing.fuse_cheap_chains`: a NEW graph with re-assigned
ids and an ``old2new`` map (every original tid keeps a semantically
identical node, so ``run()``'s ``{tid: value}`` contract and lineage
tests keep speaking original ids).  Stage nodes are ``COLLECTIVE`` too —
:data:`repro.core.fusion.FUSABLE_KINDS` excludes the kind, so every hop
is its own cluster: tree levels parallelize across workers, and a dead
mid-tree aggregator replays as exactly one cluster
(:func:`repro.core.lineage.recovery_plan_clusters` walks only its
subtree).  See ``docs/collectives.md`` for shapes, the host-leader
topology argument, and when point-to-point still wins.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .graph import GraphError, TaskGraph, TaskKind
from .tracing import RemappedRef, _Project

#: default combine-tree arity: 4 keeps the tree shallow (log4 depth) while
#: each stage's fan-in stays small enough that one slow input does not
#: serialize many (hillclimb/ClusterSim searches per-workload values —
#: see simulator.search_collective_arity)
DEFAULT_ARITY = 4

CollectivesSpec = Union[None, bool, int, str]


def parse_collectives_spec(spec: CollectivesSpec):
    """Normalize a collectives spec to ``"off"`` | ``"auto"`` | int.

    Mirrors :func:`repro.core.fusion.parse_fuse_spec` and the launcher
    vocabulary (``--collectives {auto,off,N}``): ``auto`` lowers with each
    node's traced arity, ``off`` executes the dense fallback node
    point-to-point, an integer ``N >= 2`` overrides the tree arity for
    every collective in the graph.
    """
    if spec is None or spec is False:
        return "off"
    if spec is True:
        return "auto"
    if isinstance(spec, int) and not isinstance(spec, bool):
        if spec < 2:
            raise ValueError(
                f"collectives arity {spec} makes no tree (need >= 2)")
        return spec
    if isinstance(spec, str):
        s = spec.strip().lower()
        if s in ("off", "none"):
            return "off"
        if s == "auto":
            return "auto"
        try:
            n = int(s)
        except ValueError:
            raise ValueError(
                f"unknown collectives spec {spec!r} (expected 'auto', "
                f"'off', or a tree-arity integer >= 2)") from None
        return parse_collectives_spec(n)
    raise ValueError(f"unknown collectives spec {spec!r}")


# --------------------------------------------------------------------------
# combine ops (module-level and picklable: traced graphs ship to spawn-
# started and remote TCP workers — see tracing._Project for the idiom)
# --------------------------------------------------------------------------

def _op_sum(a, b):
    return a + b


def _op_max(a, b):
    import numpy as _np
    return _np.maximum(a, b) if hasattr(a, "shape") else max(a, b)


def _op_min(a, b):
    import numpy as _np
    return _np.minimum(a, b) if hasattr(a, "shape") else min(a, b)


def _op_concat(a, b):
    import numpy as _np
    if hasattr(a, "shape"):
        return _np.concatenate([a, b])
    return a + b


REDUCE_OPS: Dict[str, Callable] = {
    "sum": _op_sum, "max": _op_max, "min": _op_min, "concat": _op_concat,
}


def resolve_op(op: Union[str, Callable]) -> Tuple[str, Callable]:
    """``op`` is a registry name or a picklable binary callable."""
    if callable(op):
        return getattr(op, "__name__", "custom"), op
    if op in REDUCE_OPS:
        return op, REDUCE_OPS[op]
    raise ValueError(f"unknown all_reduce op {op!r} "
                     f"(expected one of {sorted(REDUCE_OPS)} or a callable)")


# --------------------------------------------------------------------------
# the shared tree shape + dense node bodies
# --------------------------------------------------------------------------

def tree_depth(n: int, arity: int) -> int:
    """Combine-tree depth for ``n`` leaves (0 when one stage suffices)."""
    arity = max(2, arity)
    depth = 0
    while n > arity:
        n = math.ceil(n / arity)
        depth += 1
    return depth


def tree_fold(values: Sequence[Any], combine: Callable, arity: int) -> Any:
    """THE reduction bracketing: contiguous ``arity`` chunks per level,
    left-fold inside a chunk, repeat until one value.  The lowered stage
    nodes compute exactly one chunk each, so dense and distributed
    evaluation agree bit-for-bit even for non-associative float ops."""
    vals = list(values)
    if not vals:
        raise ValueError("tree_fold of no values")
    arity = max(2, arity)
    while len(vals) > 1:
        vals = [functools.reduce(combine, vals[i:i + arity])
                for i in range(0, len(vals), arity)]
    return vals[0]


class _ReduceStage:
    """One combine-tree hop: left-fold its (<= arity) inputs.  Doubles as
    the dense ``all_reduce`` body when ``arity`` covers all inputs."""

    __slots__ = ("combine",)

    def __init__(self, combine: Callable):
        self.combine = combine

    def __call__(self, *xs):
        return functools.reduce(self.combine, xs)


class _AllReduceFn:
    """Dense ``all_reduce`` body: the full tree fold (same bracketing the
    lowered stages compute piecewise)."""

    __slots__ = ("combine", "arity")

    def __init__(self, combine: Callable, arity: int):
        self.combine = combine
        self.arity = arity

    def __call__(self, *xs):
        return tree_fold(xs, self.combine, self.arity)


def _gather_leaf(*xs):
    """Leaf gather hop (and the dense ``gather`` body): tuple of inputs."""
    return xs


def _gather_concat(*parts):
    """Inner gather hop: flatten child tuples one level (order preserved,
    so the concatenation of contiguous leaf groups == the dense tuple)."""
    out: List[Any] = []
    for p in parts:
        out.extend(p)
    return tuple(out)


def _identity(x):
    """Broadcast body: every copy IS the value (replication happens in the
    lowered copy tree, not in the function)."""
    return x


def _chunk_bounds(length: int, n: int) -> List[Tuple[int, int]]:
    """``np.array_split`` boundaries: first ``length % n`` chunks get one
    extra element.  Shared by the dense scatter body and the lowered
    per-chunk nodes so both slice identically."""
    base, extra = divmod(length, n)
    bounds = []
    start = 0
    for i in range(n):
        end = start + base + (1 if i < extra else 0)
        bounds.append((start, end))
        start = end
    return bounds


class _ScatterFn:
    """Dense ``scatter`` body: tuple of ``n`` contiguous chunks of the
    leading axis (arrays slice as views; sequences slice as lists)."""

    __slots__ = ("n",)

    def __init__(self, n: int):
        self.n = n

    def __call__(self, x):
        bounds = _chunk_bounds(len(x), self.n)
        return tuple(x[a:b] for a, b in bounds)


class _ScatterChunk:
    """Lowered scatter hop: chunk ``i`` straight off the source value —
    bit-identical to ``_ScatterFn(n)(x)[i]`` without materializing the
    full tuple on the consumer's worker."""

    __slots__ = ("i", "n")

    def __init__(self, i: int, n: int):
        self.i = i
        self.n = n

    def __call__(self, x):
        a, b = _chunk_bounds(len(x), self.n)[self.i]
        return x[a:b]


# --------------------------------------------------------------------------
# graph-level builders (shared by the tracing API and hand-built graphs)
# --------------------------------------------------------------------------

def _coll_meta(op: str, n: int, arity: int, **extra) -> Dict[str, Any]:
    info = {"op": op, "n": n, "arity": max(2, arity)}
    info.update(extra)
    return {"collective": info}


def add_all_reduce(graph: TaskGraph, dep_tids: Sequence[int],
                   op: Union[str, Callable] = "sum", *,
                   arity: int = DEFAULT_ARITY, name: Optional[str] = None,
                   cost: float = 1.0, out_bytes: int = 0) -> int:
    """Append an ``all_reduce`` node combining ``dep_tids`` (in order)."""
    if not dep_tids:
        raise GraphError("all_reduce needs at least one input")
    op_name, combine = resolve_op(op)
    return graph.add_node(
        name or f"all_reduce[{op_name}]",
        _AllReduceFn(combine, arity),
        tuple(RemappedRef(d) for d in dep_tids), {}, TaskKind.COLLECTIVE,
        deps=tuple(dict.fromkeys(dep_tids)), cost=cost, out_bytes=out_bytes,
        meta=_coll_meta("all_reduce", len(dep_tids), arity, combine=op_name))


def add_gather(graph: TaskGraph, dep_tids: Sequence[int], *,
               arity: int = DEFAULT_ARITY, name: Optional[str] = None,
               cost: float = 1.0, out_bytes: int = 0) -> int:
    """Append a ``gather`` node producing ``tuple(values of dep_tids)``."""
    if not dep_tids:
        raise GraphError("gather needs at least one input")
    return graph.add_node(
        name or "gather", _gather_leaf,
        tuple(RemappedRef(d) for d in dep_tids), {}, TaskKind.COLLECTIVE,
        deps=tuple(dict.fromkeys(dep_tids)), cost=cost, out_bytes=out_bytes,
        meta=_coll_meta("gather", len(dep_tids), arity))


def add_broadcast(graph: TaskGraph, dep_tid: int, *,
                  arity: int = DEFAULT_ARITY, name: Optional[str] = None,
                  cost: float = 0.0, out_bytes: int = 0) -> int:
    """Append a ``broadcast`` node (identity value; the replication tree
    over its consumers is built at lowering time, when they are known)."""
    return graph.add_node(
        name or "broadcast", _identity, (RemappedRef(dep_tid),), {},
        TaskKind.COLLECTIVE, deps=(dep_tid,), cost=cost,
        out_bytes=out_bytes or graph.nodes[dep_tid].out_bytes,
        meta=_coll_meta("broadcast", 1, arity))


def add_scatter(graph: TaskGraph, dep_tid: int, n: int, *,
                arity: int = DEFAULT_ARITY, name: Optional[str] = None,
                cost: float = 0.0, out_bytes: int = 0) -> int:
    """Append a ``scatter`` node splitting ``dep_tid`` into ``n``
    contiguous leading-axis chunks (unpack via projections)."""
    if n < 1:
        raise GraphError("scatter needs n >= 1 chunks")
    return graph.add_node(
        name or f"scatter{n}", _ScatterFn(n), (RemappedRef(dep_tid),), {},
        TaskKind.COLLECTIVE, deps=(dep_tid,), cost=cost,
        out_bytes=out_bytes, meta=_coll_meta("scatter", n, arity))


# --------------------------------------------------------------------------
# the lowering pass
# --------------------------------------------------------------------------

def has_collectives(graph: TaskGraph) -> bool:
    return any(n.kind is TaskKind.COLLECTIVE and "collective" in n.meta
               for n in graph.nodes.values())


def _stage_cost(root_cost: float, width: int, n: int) -> float:
    """Shape-aware stage pricing: a hop combining ``width`` of ``n``
    inputs carries that fraction of the root's traced cost, so the
    scheduler's EFT and fusion's cost gates see per-hop work, never the
    root's full N-wide fan-in."""
    return max(1e-6, root_cost * width / max(1, n))


def lower_collectives(
    graph: TaskGraph, spec: CollectivesSpec = "auto", *,
    reshape_reductions: bool = False,
) -> Tuple[TaskGraph, Optional[Dict[int, int]]]:
    """Compile collective nodes into staged tree hops.

    Returns ``(lowered_graph, old2new)`` — or ``(graph, None)`` (identity,
    the SAME object) when the spec is off or the graph has no collectives,
    which is what keeps every collective-free run byte-identical to the
    pre-collectives runtime.

    Deterministic: equal ``(graph, spec)`` always produce an equal lowered
    graph, so resumed runs re-derive the same node ids and the run log's
    graph fingerprint stays meaningful.

    An integer spec overrides the tree arity — but only for the
    value-preserving shapes (``broadcast`` replication, ``gather``
    concatenation, which produce identical bits at any arity).  An
    ``all_reduce``'s bracketing IS its value (float combines are not
    associative), so its arity is frozen at trace time and a live
    executor never reshapes it: that is what keeps ``--collectives N``
    runs bit-identical to the sequential oracle.  ``ClusterSim`` passes
    ``reshape_reductions=True`` — a simulator prices shapes and never
    looks at values, so the arity search
    (:func:`repro.core.simulator.search_collective_arity`) can model the
    reduce tree at each candidate; feed the winner back as the traced
    ``arity=`` to change real bracketing deliberately.

    Per op (``arity`` = the node's traced arity, or the spec's integer
    override where value-preserving):

    * ``all_reduce`` — contiguous ``arity``-chunks fold per level
      (:func:`tree_fold`'s exact bracketing); each chunk is one
      ``COLLECTIVE`` stage node, the original tid becomes the final fold.
    * ``gather`` — leaf stages tuple their chunk, inner stages concatenate
      child tuples; the original tid concatenates the last level.
    * ``broadcast`` — the original tid stays an identity root; a copy tree
      fans out below it and each consumer is rewired to its assigned copy
      (≤ ``arity`` consumers per copy), so no single worker serves all M
      readers.
    * ``scatter`` — each ``π_i`` projection consumer is rewritten to a
      direct :class:`_ScatterChunk` node on the source, skipping the full
      tuple; the root keeps the dense body for non-projection readers.
      (A scatter is already one value per consumer — point-to-point is
      the optimal shape; see docs/collectives.md.)
    """
    mode = parse_collectives_spec(spec)
    graph.validate()
    if mode == "off" or not has_collectives(graph):
        return graph, None

    succ = graph.successors()
    new = TaskGraph()
    old2new: Dict[int, int] = {}
    # per-consumer dep rewrites (broadcast copy assignment): old consumer
    # tid -> {old producer tid: new tid}
    overrides: Dict[int, Dict[int, int]] = {}
    # scatter projections rewritten to direct chunk reads:
    # old projection tid -> (old scatter tid, chunk index, n)
    chunk_rewrites: Dict[int, Tuple[int, int, int]] = {}

    def remap_table(tid: int) -> Dict[int, int]:
        ov = overrides.get(tid)
        return {**old2new, **ov} if ov else old2new

    def remap_refs(obj: Any, table: Dict[int, int]) -> Any:
        from .tracing import _remap_arg_refs
        return _remap_arg_refs(obj, table)

    def emit_plain(node) -> int:
        table = remap_table(node.tid)
        return new.add_node(
            node.name, node.fn,
            remap_refs(node.args, table), remap_refs(node.kwargs, table),
            node.kind,
            deps=tuple(dict.fromkeys(table[d] for d in node.deps)),
            token_deps=tuple(dict.fromkeys(table[d]
                                           for d in node.token_deps)),
            cost=node.cost, out_bytes=node.out_bytes, meta=node.meta)

    def stage_meta(op: str, root_old: int, level: int, index: int) -> dict:
        return {"collective_stage": {"op": op, "root": root_old,
                                     "level": level, "index": index}}

    def emit_tree(node, info) -> int:
        """all_reduce / gather: chunk-per-level stage tree, root last."""
        op = info["op"]
        if (isinstance(mode, int)
                and (op != "all_reduce" or reshape_reductions)):
            arity = mode
        else:
            arity = info["arity"]   # reduce bracketing == the traced value
        arity = max(2, arity)
        table = remap_table(node.tid)
        # arg order (not the deduped ``deps``) defines leaf order — a ref
        # passed twice participates twice, exactly as the dense fn sees it
        leaves = [table[r.tid] for r in node.args]
        n = len(leaves)
        combine = node.fn.combine if op == "all_reduce" else None
        vals = leaves
        level = 0
        while len(vals) > arity:
            nxt: List[int] = []
            for gi in range(0, len(vals), arity):
                group = vals[gi:gi + arity]
                if len(group) == 1 and not (op == "gather" and level == 0):
                    nxt.append(group[0])    # fold of one == the value
                    continue
                if op == "all_reduce":
                    fn: Callable = _ReduceStage(combine)
                    sbytes = node.out_bytes
                else:
                    fn = _gather_leaf if level == 0 else _gather_concat
                    sbytes = node.out_bytes * len(group) // max(1, n)
                stid = new.add_node(
                    f"{node.name}@L{level}.{gi // arity}", fn,
                    tuple(RemappedRef(v) for v in group), {},
                    TaskKind.COLLECTIVE,
                    deps=tuple(dict.fromkeys(group)),
                    cost=_stage_cost(node.cost, len(group), n),
                    out_bytes=sbytes,
                    meta=stage_meta(op, node.tid, level, gi // arity))
                nxt.append(stid)
            vals = nxt
            level += 1
        if op == "all_reduce":
            root_fn: Callable = _ReduceStage(combine)
        else:
            root_fn = _gather_leaf if level == 0 else _gather_concat
        return new.add_node(
            node.name, root_fn, tuple(RemappedRef(v) for v in vals), {},
            TaskKind.COLLECTIVE, deps=tuple(dict.fromkeys(vals)),
            cost=_stage_cost(node.cost, len(vals), n),
            out_bytes=node.out_bytes, meta=node.meta)

    def emit_broadcast(node, info) -> int:
        arity = mode if isinstance(mode, int) else info["arity"]
        arity = max(2, arity)
        table = remap_table(node.tid)
        root = new.add_node(
            node.name, _identity, remap_refs(node.args, table), {},
            TaskKind.COLLECTIVE,
            deps=tuple(dict.fromkeys(table[d] for d in node.deps)),
            cost=node.cost, out_bytes=node.out_bytes, meta=node.meta)
        consumers = sorted(succ[node.tid])
        if len(consumers) <= arity:
            return root      # the root alone can serve them
        # copy-tree sizes, top-down: the bottom level serves <= arity
        # consumers per copy, each level above serves <= arity copies
        sizes = [math.ceil(len(consumers) / arity)]
        while sizes[0] > arity:
            sizes.insert(0, math.ceil(sizes[0] / arity))
        parents = [root]
        for lvl, size in enumerate(sizes):
            cur: List[int] = []
            for i in range(size):
                p = parents[i // arity]
                cid = new.add_node(
                    f"{node.name}@B{lvl}.{i}", _identity,
                    (RemappedRef(p),), {}, TaskKind.COLLECTIVE,
                    deps=(p,), cost=_stage_cost(node.cost or 1.0, 1,
                                                len(consumers)),
                    out_bytes=node.out_bytes,
                    meta=stage_meta("broadcast", node.tid, lvl, i))
                cur.append(cid)
            parents = cur
        for ci, c in enumerate(consumers):
            overrides.setdefault(c, {})[node.tid] = parents[ci // arity]
        return root

    def emit_scatter(node, info) -> int:
        table = remap_table(node.tid)
        root = new.add_node(
            node.name, node.fn, remap_refs(node.args, table), {},
            TaskKind.COLLECTIVE,
            deps=tuple(dict.fromkeys(table[d] for d in node.deps)),
            cost=node.cost, out_bytes=node.out_bytes, meta=node.meta)
        n = info["n"]
        for c in succ[node.tid]:
            cn = graph.nodes[c]
            if (cn.kind is TaskKind.PROJECTION
                    and isinstance(cn.fn, _Project)
                    and cn.deps == (node.tid,) and 0 <= cn.fn.idx < n):
                chunk_rewrites[c] = (node.tid, cn.fn.idx, n)
        return root

    for tid in sorted(graph.nodes):     # ascending tid IS topo order
        node = graph.nodes[tid]
        if tid in chunk_rewrites:
            src_old, idx, n = chunk_rewrites[tid]
            # read the chunk straight off the scatter *source*, not the
            # dense tuple — the only bytes that move are the chunk's
            src_new = old2new[graph.nodes[src_old].deps[0]]
            old2new[tid] = new.add_node(
                f"{node.name}[{idx}/{n}]", _ScatterChunk(idx, n),
                (RemappedRef(src_new),), {}, TaskKind.COLLECTIVE,
                deps=(src_new,), cost=node.cost,
                out_bytes=graph.nodes[src_old].out_bytes // max(1, n),
                meta=stage_meta("scatter", src_old, 0, idx))
            continue
        info = node.meta.get("collective") \
            if node.kind is TaskKind.COLLECTIVE else None
        if info is None:
            old2new[tid] = emit_plain(node)
        elif info["op"] in ("all_reduce", "gather"):
            old2new[tid] = emit_tree(node, info)
        elif info["op"] == "broadcast":
            old2new[tid] = emit_broadcast(node, info)
        elif info["op"] == "scatter":
            old2new[tid] = emit_scatter(node, info)
        else:
            raise GraphError(f"unknown collective op {info['op']!r} "
                             f"on task {node.name}#{tid}")

    for o in graph.outputs:
        new.mark_output(old2new[o])
    new.validate()
    new.meta_old2new = old2new  # type: ignore[attr-defined]
    return new, old2new


def collective_stages(graph: TaskGraph, root_old: int) -> List[int]:
    """The lowered stage tids belonging to collective root ``root_old``
    (by original tid) — the bounded set a mid-tree aggregator loss may
    force :func:`repro.core.lineage.recovery_plan_clusters` to replay."""
    return [t for t, n in graph.nodes.items()
            if n.meta.get("collective_stage", {}).get("root") == root_old]
