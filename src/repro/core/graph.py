"""Task-graph IR — the data-dependency DAG the paper's parser produces.

A :class:`TaskGraph` is the JAX-side analogue of the dependency graph the
paper extracts from a Haskell ``main``: nodes are coarse-grained function
calls, edges are value dependencies, and effectful nodes additionally carry
*token* dependencies (the paper's "RealWorld is an input and output of each
IO function").
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple


class TaskKind(enum.Enum):
    PURE = "pure"          # freely parallelizable (Haskell: ``a -> b``)
    EFFECTFUL = "io"       # ordered via token edges (Haskell: ``IO b``)
    PROJECTION = "proj"    # zero-cost tuple-element projection
    BARRIER = "barrier"    # checkpoint/materialization barrier (lineage cut)
    COLLECTIVE = "coll"    # group-communication node (broadcast / scatter /
    #                        gather / all_reduce): semantically a pure
    #                        function of its inputs, but carrying a
    #                        communication *shape* in ``meta["collective"]``
    #                        that repro.core.collectives compiles into
    #                        tree-structured staged hops before dispatch


@dataclasses.dataclass
class TaskNode:
    """One node of the dependency DAG.

    ``args``/``kwargs`` may contain :class:`repro.core.tracing.TaskRef`
    placeholders (dependencies) or plain literals.  ``deps`` is the resolved
    list of producer task ids (value deps first, then token deps).
    """

    tid: int
    name: str
    fn: Optional[Callable]
    args: Tuple[Any, ...]
    kwargs: Dict[str, Any]
    kind: TaskKind
    deps: Tuple[int, ...]            # value dependencies (producer tids)
    token_deps: Tuple[int, ...]      # effect-ordering dependencies
    cost: float = 1.0                # abstract cost estimate (seconds-ish)
    out_bytes: int = 0               # estimated output size (placement/steal)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def all_deps(self) -> Tuple[int, ...]:
        return tuple(dict.fromkeys(self.deps + self.token_deps))


class GraphError(ValueError):
    pass


class TaskGraph:
    """Append-only DAG of :class:`TaskNode`."""

    def __init__(self) -> None:
        self.nodes: Dict[int, TaskNode] = {}
        self._next_id = 0
        self.outputs: List[int] = []   # tids whose values the driver returns

    # ------------------------------------------------------------- building
    def add_node(
        self,
        name: str,
        fn: Optional[Callable],
        args: Tuple[Any, ...],
        kwargs: Dict[str, Any],
        kind: TaskKind,
        deps: Sequence[int],
        token_deps: Sequence[int] = (),
        cost: float = 1.0,
        out_bytes: int = 0,
        meta: Optional[Dict[str, Any]] = None,
    ) -> int:
        tid = self._next_id
        self._next_id += 1
        for d in tuple(deps) + tuple(token_deps):
            if d not in self.nodes:
                raise GraphError(f"dependency {d} of task {tid} does not exist")
        self.nodes[tid] = TaskNode(
            tid=tid, name=name, fn=fn, args=args, kwargs=kwargs, kind=kind,
            deps=tuple(deps), token_deps=tuple(token_deps), cost=cost,
            out_bytes=out_bytes, meta=dict(meta or {}),
        )
        return tid

    def mark_output(self, tid: int) -> None:
        if tid not in self.nodes:
            raise GraphError(f"output task {tid} does not exist")
        self.outputs.append(tid)

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self.nodes)

    def __iter__(self):
        return iter(self.nodes.values())

    def successors(self) -> Dict[int, List[int]]:
        succ: Dict[int, List[int]] = {tid: [] for tid in self.nodes}
        for node in self.nodes.values():
            for d in node.all_deps:
                succ[d].append(node.tid)
        return succ

    def in_degree(self) -> Dict[int, int]:
        return {tid: len(n.all_deps) for tid, n in self.nodes.items()}

    def topo_order(self) -> List[int]:
        """Kahn topological order; raises on cycles (defensive — tracing
        cannot create cycles, but graphs can be built by hand)."""
        indeg = self.in_degree()
        succ = self.successors()
        ready = deque(sorted(t for t, d in indeg.items() if d == 0))
        order: List[int] = []
        while ready:
            t = ready.popleft()
            order.append(t)
            for s in succ[t]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(order) != len(self.nodes):
            raise GraphError("task graph contains a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()
        for node in self.nodes.values():
            for d in node.all_deps:
                if d >= node.tid:
                    raise GraphError(
                        f"task {node.tid} depends on later/equal task {d}")

    def ancestors(self, tids: Iterable[int]) -> Set[int]:
        seen: Set[int] = set()
        stack = list(tids)
        while stack:
            t = stack.pop()
            if t in seen:
                continue
            seen.add(t)
            stack.extend(self.nodes[t].all_deps)
        return seen

    # -------------------------------------------------------- cost analysis
    def critical_path_rank(self) -> Dict[int, float]:
        """Upward rank: cost of the node + longest downstream cost chain.

        This is the (communication-free) HEFT ``rank_u`` used as scheduling
        priority — the paper's greedy scheduler extended with critical-path
        tie-breaking.
        """
        rank: Dict[int, float] = {}
        succ = self.successors()
        for tid in reversed(self.topo_order()):
            node = self.nodes[tid]
            down = max((rank[s] for s in succ[tid]), default=0.0)
            rank[tid] = node.cost + down
        return rank

    def critical_path_length(self) -> float:
        rank = self.critical_path_rank()
        return max(rank.values(), default=0.0)

    def total_work(self) -> float:
        return sum(n.cost for n in self.nodes.values())

    def max_parallelism(self) -> float:
        """Work / span — the classic upper bound on useful workers."""
        span = self.critical_path_length()
        return self.total_work() / span if span > 0 else 1.0

    # ------------------------------------------------------------ rendering
    def to_dot(self) -> str:
        lines = ["digraph tasks {", "  rankdir=TB;"]
        shapes = {"pure": "ellipse", "io": "box", "proj": "point",
                  "barrier": "octagon", "coll": "doubleoctagon"}
        for node in self.nodes.values():
            shape = shapes.get(node.kind.value, "ellipse")
            label = f"{node.name}#{node.tid}"
            if node.kind is TaskKind.COLLECTIVE:
                # a collective root carries its shape; a lowered stage node
                # carries which root it is a hop of (see core/collectives.py)
                info = node.meta.get("collective")
                stage = node.meta.get("collective_stage")
                if info:
                    label += (f"\\n{info.get('op', '?')}"
                              f"(n={info.get('n', '?')}, "
                              f"arity={info.get('arity', '?')})")
                elif stage:
                    label += (f"\\n{stage.get('op', '?')} stage "
                              f"L{stage.get('level', '?')} "
                              f"of #{stage.get('root', '?')}")
            lines.append(
                f'  t{node.tid} [label="{label}" shape={shape}];')
        for node in self.nodes.values():
            for d in node.deps:
                lines.append(f"  t{d} -> t{node.tid};")
            for d in node.token_deps:
                lines.append(f'  t{d} -> t{node.tid} [style=dashed,label="RW"];')
        lines.append("}")
        return "\n".join(lines)

    def summary(self) -> str:
        kinds: Dict[str, int] = {}
        colls: Dict[str, int] = {}
        for n in self.nodes.values():
            kinds[n.kind.value] = kinds.get(n.kind.value, 0) + 1
            if n.kind is TaskKind.COLLECTIVE and "collective" in n.meta:
                op = n.meta["collective"].get("op", "?")
                colls[op] = colls.get(op, 0) + 1
        coll = f", collectives={colls}" if colls else ""
        return (f"TaskGraph(n={len(self.nodes)}, kinds={kinds}{coll}, "
                f"work={self.total_work():.3g}, span={self.critical_path_length():.3g}, "
                f"max_parallelism={self.max_parallelism():.2f})")
