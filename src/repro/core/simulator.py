"""Deterministic discrete-event cluster simulator.

The paper evaluates its greedy/work-stealing scheduler on Cloud Haskell
workers.  This container has one CPU, so — exactly like the paper "simulated"
workers with Cloud Haskell processes on one box — we simulate a cluster with
a discrete-event model: heterogeneous worker speeds, work-stealing deques,
steal latency, worker failures (→ lineage recovery), stragglers
(→ speculative re-execution), elastic joins, and **fused execution**
(``fuse=`` runs the sim over the same super-task graph the real driver
dispatches, with ``dispatch_overhead`` charging the per-dispatch
control-plane cost fusion amortizes away).

Everything is deterministic given the seed, which makes the scheduler's
behaviour property-testable (see ``tests/test_scheduler.py``).
"""
from __future__ import annotations

import dataclasses
import heapq
import random as _random
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

from .adaptive import (CostModel, RefuseGovernor, RunTrace, fn_key,
                       refusion_due)
from .collectives import (CollectivesSpec, lower_collectives,
                          parse_collectives_spec)
from .fusion import FusedPlan, FuseSpec, fuse as fuse_graph
from .graph import TaskGraph, TaskKind

DURABLE = -1   # pseudo-worker id: result survives any failure (checkpointed)


def pick_speculation(running: Dict[int, Tuple[float, float]],
                     speculate_after: float) -> Optional[int]:
    """The speculation policy, shared by this simulator and the real
    :class:`repro.cluster.ClusterExecutor` so the two provably agree on
    *which* task a free worker duplicates (see
    ``tests/test_speculation.py``).

    ``running`` maps a singly-in-flight task id to ``(elapsed, expected)``
    durations — elapsed wall time so far vs the expected duration from the
    cost model (sim: nominal ``node.cost``; runtime: the static
    ``list_schedule`` duration calibrated by a runtime EWMA).  Returns the
    most-overdue task whose ``elapsed > speculate_after × expected`` (ties
    to the lower tid), or ``None`` when nothing is overdue enough.
    """
    best: Optional[Tuple[float, int]] = None
    for tid, (elapsed, expected) in running.items():
        overdue = elapsed / max(expected, 1e-12)
        if overdue <= speculate_after:
            continue
        if best is None or (overdue, -tid) > (best[0], -best[1]):
            best = (overdue, tid)
    return None if best is None else best[1]


@dataclasses.dataclass
class WorkerEvent:
    """Cluster dynamics injected into a run."""
    time: float
    kind: str           # "fail" | "join" | "slow" | "partition"
    worker: int
    factor: float = 1.0  # "slow": multiply speed by this;
    #                      "partition": seconds the worker is unreachable


@dataclasses.dataclass
class SimResult:
    makespan: float
    n_steals: int = 0
    n_recomputed: int = 0
    n_speculative: int = 0
    n_failures: int = 0
    # partition / suspect-grace accounting ("partition" events):
    n_suspected: int = 0     # partitions that opened on a live worker
    n_healed: int = 0        # partitions outwaited inside suspect_grace
    n_false_deaths: int = 0  # live workers declared dead by an expired grace
    speculated: Set[int] = dataclasses.field(default_factory=set)
    busy_time: Dict[int, float] = dataclasses.field(default_factory=dict)
    task_worker: Dict[int, int] = dataclasses.field(default_factory=dict)
    timeline: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    # adaptive trigger model (``adaptive="auto"``): how many times, and
    # when, the re-fusion governor would have fired on this run
    refusions: int = 0
    refusion_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def utilization(self) -> float:
        if not self.busy_time or self.makespan <= 0:
            return 1.0
        return sum(self.busy_time.values()) / (self.makespan * len(self.busy_time))


class ClusterSim:
    def __init__(
        self,
        graph: TaskGraph,
        n_workers: int,
        *,
        worker_speed: Optional[List[float]] = None,
        steal_latency: float = 0.0,
        allow_steal: bool = True,
        comm_per_byte: float = 0.0,
        events: Optional[List[WorkerEvent]] = None,
        speculate_after: Optional[float] = None,  # ×expected-duration threshold
        policy: str = "critical_path",
        seed: int = 0,
        fuse: FuseSpec = "off",
        collectives: CollectivesSpec = "auto",
        dispatch_overhead: float = 0.0,
        driver_kill: Optional[float] = None,
        driver_dead_workers: Optional[List[int]] = None,
        driver_resume_latency: float = 1.0,
        suspect_grace: float = 5.0,
        adaptive: str = "off",
        refuse_skew: float = 4.0,
        trace: Optional[RunTrace] = None,
        fuse_kw: Optional[Dict[str, float]] = None,
    ) -> None:
        graph.validate()
        # collective lowering first, exactly as ClusterExecutor does: the
        # sim prices the SAME staged tree hops the real driver dispatches,
        # which is what makes the offline arity search
        # (search_collective_arity) transfer to the runtime.  Unlike the
        # executor, the sim reshapes reduce trees under an integer spec —
        # it prices shapes and never touches values, so candidate arities
        # can be modeled without the bit-equality constraint
        graph, _ = lower_collectives(graph, parse_collectives_spec(
            collectives), reshape_reductions=True)
        # fused execution model: the sim runs over the SAME cluster-level
        # graph the real driver dispatches (repro.core.fusion), and
        # ``dispatch_overhead`` charges the per-dispatch control-plane
        # round-trip (BENCH_multihost: ~0.78 ms/task on TCP) each task
        # start pays — so policy studies of fusion granularity transfer:
        # fewer clusters ⇒ fewer overheads, identical total work.
        # ``fuse_kw`` forwards fusion knobs (keep_parallelism, fanin_cost,
        # group_cost) so the offline policy search can price candidate
        # REGROUPINGS of the same graph — the simulator half of the
        # adaptive re-fusion loop (docs/adaptive.md)
        self.plan: FusedPlan = fuse_graph(graph, fuse, **(fuse_kw or {}))
        # member-level graph, kept around so a recorded RunTrace (keyed by
        # member tid) can price any candidate clustering of the same tasks
        self.member_graph = graph
        graph = self.plan.cgraph
        self.dispatch_overhead = dispatch_overhead
        self.graph = graph
        self.trace = trace
        # adaptive="auto" models the RE-FUSION TRIGGER: the sim feeds the
        # same CostModel/RefuseGovernor the live driver uses and counts
        # where the governor fires (SimResult.refusions /
        # .refusion_times).  It does not re-splice the plan mid-sim —
        # candidate regroupings are priced by re-running with ``fuse_kw``
        # / ``trace``, which is exactly what search_policy does.
        if adaptive not in ("off", "auto"):
            raise ValueError(f"adaptive must be 'off' or 'auto': {adaptive}")
        self.adaptive = adaptive
        self._model: Optional[CostModel] = None
        self._governor: Optional[RefuseGovernor] = None
        if adaptive == "auto":
            self._model = CostModel(dispatch_s=dispatch_overhead)
            self._governor = RefuseGovernor(skew_threshold=refuse_skew)
        self.n_workers = n_workers
        self.speed = {w: (worker_speed[w] if worker_speed else 1.0)
                      for w in range(n_workers)}
        self.steal_latency = steal_latency
        self.allow_steal = allow_steal
        self.comm_per_byte = comm_per_byte
        self.events = sorted(events or [], key=lambda e: e.time)
        self.speculate_after = speculate_after
        self.rng = _random.Random(seed)
        self.rank = graph.critical_path_rank()
        if policy not in ("critical_path", "fifo", "random"):
            raise ValueError(policy)
        self.policy = policy
        self._jitter = {tid: self.rng.random() for tid in graph.nodes}
        # driver-outage model (mirrors ClusterExecutor checkpoint/resume):
        # at ``driver_kill`` the driver stops dispatching; workers in
        # ``driver_dead_workers`` die with it; everyone else keeps running
        # what they hold and buffers completions.  ``driver_resume_latency``
        # later the restarted driver re-adopts survivors, reconciles the
        # buffered work, and recovers every confirmed loss in ONE pass.
        self.driver_kill = driver_kill
        self.driver_dead_workers = list(driver_dead_workers or [])
        self.driver_resume_latency = driver_resume_latency
        # partition model (mirrors the executor's suspect-vs-dead policy,
        # docs/faults.md): a "partition" event makes a worker unreachable
        # for ``factor`` seconds — no new dispatches, its completions
        # buffer until the heal.  A partition longer than ``suspect_grace``
        # is indistinguishable from death at the driver, so the worker is
        # declared dead at grace expiry (its sole-copy values replay via
        # lineage — the *phantom* recovery cost) and rejoins empty at heal
        # time.  Sweeping this knob offline is the grace policy search.
        self.suspect_grace = max(0.0, suspect_grace)

    # priority of a ready task (lower = sooner)
    def _prio(self, tid: int) -> Tuple:
        if self.policy == "critical_path":
            return (-self.rank[tid], tid)
        if self.policy == "fifo":
            return (tid,)
        return (self._jitter[tid], tid)

    # ---------------------------------------------------------------- run
    def run(self) -> SimResult:
        g = self.graph
        succ = g.successors()
        res = SimResult(makespan=0.0)

        alive: Set[int] = set(range(self.n_workers))
        deques: Dict[int, deque] = {w: deque() for w in alive}
        # results_at[tid] = set of workers holding the value (or DURABLE)
        results_at: Dict[int, Set[int]] = {}
        done: Set[int] = set()
        # running[w] = (tid, start, end, epoch); epoch invalidates stale events
        running: Dict[int, Tuple[int, float, float, int]] = {}
        partitioned: Dict[int, float] = {}   # w -> heal time (unreachable)
        busy: Dict[int, float] = {w: 0.0 for w in alive}
        inflight: Dict[int, Set[int]] = {}   # tid -> workers currently running it
        epoch = 0

        evq: List[Tuple[float, int, str, tuple]] = []
        seq = 0

        def push(t: float, kind: str, data: tuple) -> None:
            nonlocal seq
            heapq.heappush(evq, (t, seq, kind, data))
            seq += 1

        for e in self.events:
            push(e.time, e.kind, (e.worker, e.factor))
        driver_down = False
        if self.driver_kill is not None:
            push(self.driver_kill, "driver_kill", ())
            push(self.driver_kill + self.driver_resume_latency,
                 "driver_resume", ())

        def ready_p(tid: int) -> bool:
            # NB: inflight values are sets that may be empty after a
            # discard — membership must be by truthiness, not key presence,
            # or recomputed tasks are blocked forever.
            return (tid not in done and not inflight.get(tid)
                    and all(d in done for d in g.nodes[tid].all_deps))

        pending: Set[int] = set(g.nodes)
        central: List[Tuple] = []   # overflow queue for tasks with no owner

        def enqueue_ready_from(tid_done: int, worker: int) -> None:
            """Paper's greedy rule: schedule successors the moment their
            inputs are ready; locality: place on the finishing worker's deque."""
            for s in succ[tid_done]:
                if s in pending and ready_p(s):
                    deques[worker].appendleft(s) if worker in deques else \
                        heapq.heappush(central, (*self._prio(s), s))

        def start_task(w: int, tid: int, now: float, speculative: bool = False):
            nonlocal epoch
            node = g.nodes[tid]
            if self.trace is not None:
                # trace replay: recorded per-member seconds (declared cost
                # × recorded unit rate for never-observed members), so the
                # same trace prices ANY candidate clustering of the tasks
                work = self.trace.cluster_seconds(
                    self.plan.members.get(tid, (tid,)),
                    self.member_graph.nodes)
            else:
                work = node.cost
            dur = work / self.speed[w] + self.dispatch_overhead
            # input fetch cost: bytes from deps whose results live elsewhere
            if self.comm_per_byte > 0.0:
                for d in node.deps:
                    holders = results_at.get(d, set())
                    if w not in holders and DURABLE not in holders:
                        dur += g.nodes[d].out_bytes * self.comm_per_byte
            epoch += 1
            running[w] = (tid, now, now + dur, epoch)
            inflight.setdefault(tid, set()).add(w)
            if speculative:
                res.n_speculative += 1
            push(now + dur, "finish", (w, tid, epoch))

        def try_acquire(w: int, now: float) -> bool:
            if driver_down:
                return False    # no driver, no dispatch: survivors finish
                # what they hold and idle until re-adoption
            if w in running or w not in alive or w in partitioned:
                return False
            # 1. own deque (LIFO — classic work-stealing owner end)
            if deques[w]:
                tid = deques[w].popleft()
                if ready_p(tid):
                    start_task(w, tid, now)
                    return True
                return try_acquire(w, now)   # stale entry; keep looking
            # 2. central overflow
            while central:
                entry = heapq.heappop(central)
                tid = entry[-1]
                if ready_p(tid):
                    start_task(w, tid, now)
                    return True
            # 3. steal from the most-loaded victim (FIFO end)
            victim = None if not self.allow_steal else \
                max((v for v in alive
                     if v != w and v not in partitioned and deques[v]),
                    key=lambda v: len(deques[v]), default=None)
            if victim is not None:
                tid = deques[victim].pop()
                if ready_p(tid):
                    res.n_steals += 1
                    start_task(w, tid, now + self.steal_latency)
                    return True
                return try_acquire(w, now)
            # 4. speculation: duplicate the longest-overdue running task
            # (the pick itself is the shared pick_speculation policy, so
            # the real ClusterExecutor makes the identical choice)
            if self.speculate_after is not None:
                overdue_view = {
                    tid: (now - st, g.nodes[tid].cost)  # nominal speed 1.0
                    for v, (tid, st, en, _) in running.items()
                    if len(inflight.get(tid, ())) == 1}
                cand = pick_speculation(overdue_view, self.speculate_after)
                if cand is not None:
                    start_task(w, cand, now, speculative=True)
                    res.speculated.add(cand)
                    return True
            return False

    # -- failure → lineage recovery (pure tasks recomputed from survivors) --
        def handle_failure(w: int, now: float) -> None:
            res.n_failures += 1
            alive.discard(w)
            lost_running = running.pop(w, None)
            if lost_running is not None:
                tid = lost_running[0]
                inflight.get(tid, set()).discard(w)
                # the in-flight task dies with the worker; unless a
                # speculative twin still runs it elsewhere, put it back
                if tid not in done and not inflight.get(tid):
                    heapq.heappush(central, (*self._prio(tid), tid))
            # orphan this worker's queued tasks into the central queue
            while deques[w]:
                tid = deques[w].pop()
                heapq.heappush(central, (*self._prio(tid), tid))
            del deques[w]
            # results held only by w are lost unless durable
            lost: Set[int] = set()
            for tid, holders in results_at.items():
                holders.discard(w)
                if not holders:
                    lost.add(tid)
            if not lost:
                return
            # lineage: a lost result must be recomputed iff some not-done
            # task (or a driver output) still needs it
            needed: Set[int] = set(g.outputs)
            for t in pending:
                needed.update(g.nodes[t].all_deps)
            to_redo = {t for t in lost if t in needed or t in g.outputs}
            # recompute transitively: ancestors of to_redo that are also lost
            frontier = set(to_redo)
            while frontier:
                t = frontier.pop()
                for d in g.nodes[t].all_deps:
                    if d in lost and d not in to_redo:
                        to_redo.add(d)
                        frontier.add(d)
            for t in to_redo:
                results_at.pop(t, None)
                done.discard(t)
                pending.add(t)
                res.n_recomputed += 1
            for t in sorted(to_redo):
                if ready_p(t):
                    heapq.heappush(central, (*self._prio(t), t))

        # seed: all zero-dep tasks round-robin across workers
        sources = [tid for tid in g.topo_order()
                   if not g.nodes[tid].all_deps]
        sources.sort(key=self._prio)
        for i, tid in enumerate(sources):
            deques[i % self.n_workers].append(tid)

        now = 0.0
        for w in list(alive):
            try_acquire(w, now)

        while evq:
            now, _, kind, data = heapq.heappop(evq)
            if kind == "finish":
                w, tid, ep = data
                cur = running.get(w)
                if cur is None or cur[3] != ep:
                    continue   # stale (worker failed / task re-assigned)
                if w in partitioned:
                    # the worker finished, but the driver can't see it:
                    # the completion buffers until the partition heals
                    # (or is discarded by a grace-expiry death)
                    push(partitioned[w], "finish", (w, tid, ep))
                    continue
                del running[w]
                inflight.get(tid, set()).discard(w)
                busy[w] = busy.get(w, 0.0) + (now - cur[1])
                if tid in done:
                    pass       # a speculative twin already finished
                else:
                    done.add(tid)
                    pending.discard(tid)
                    results_at.setdefault(tid, set()).add(w)
                    res.task_worker[tid] = w
                    node = g.nodes[tid]
                    if node.kind is TaskKind.BARRIER:
                        # checkpoint: node + its direct inputs become durable
                        results_at.setdefault(tid, set()).add(DURABLE)
                        for d in node.deps:
                            results_at.setdefault(d, set()).add(DURABLE)
                    enqueue_ready_from(tid, w)
                    res.makespan = max(res.makespan, now)
                    if self._model is not None:
                        # same observation + trigger predicate the live
                        # driver applies in on_done/maybe_refuse
                        mg = self.member_graph
                        ms = self.plan.members.get(tid, (tid,))
                        self._model.observe(
                            max(node.cost, 1e-9), now - cur[1],
                            fn_units=[(fn_key(mg.nodes[m]),
                                       mg.nodes[m].cost)
                                      for m in ms if m in mg.nodes])
                        n_frontier = sum(1 for t in pending
                                         if not inflight.get(t))
                        if refusion_due(self._model, self._governor,
                                        n_frontier):
                            self._governor.note_fired(self._model)
                            res.refusions += 1
                            res.refusion_times.append(now)
                            res.timeline.append((now, "refusion trigger"))
                try_acquire(w, now)
                # a finish may unblock work for idle peers
                for v in list(alive):
                    if v not in running:
                        try_acquire(v, now)
            elif kind == "fail":
                w, _ = data
                if w in alive:
                    handle_failure(w, now)
                    res.timeline.append((now, f"fail w{w}"))
                    for v in list(alive):
                        if v not in running:
                            try_acquire(v, now)
            elif kind == "join":
                w, _ = data
                if w not in alive:
                    alive.add(w)
                    deques[w] = deque()
                    busy.setdefault(w, 0.0)
                    self.speed.setdefault(w, 1.0)
                    res.timeline.append((now, f"join w{w}"))
                    try_acquire(w, now)
            elif kind == "slow":
                w, factor = data
                if w in self.speed:
                    self.speed[w] *= factor
                    res.timeline.append((now, f"slow w{w} ×{factor}"))
            elif kind == "partition":
                w, dur = data
                if w in alive and w not in partitioned:
                    heal_t = now + dur
                    partitioned[w] = heal_t
                    res.n_suspected += 1
                    res.timeline.append((now, f"partition w{w} {dur:g}s"))
                    if dur > self.suspect_grace:
                        # the driver will give up first: a false death at
                        # grace expiry, then an empty-handed rejoin at heal
                        push(now + self.suspect_grace,
                             "partition_expire", (w, heal_t))
                    else:
                        push(heal_t, "partition_heal", (w,))
            elif kind == "partition_heal":
                (w,) = data
                if w in partitioned:
                    partitioned.pop(w)
                    res.n_healed += 1
                    res.timeline.append((now, f"heal w{w}"))
                    # buffered finishes for w fire at this same timestamp
                    # (pushed behind this event); idle peers may also have
                    # work for it now
                    try_acquire(w, now)
            elif kind == "partition_expire":
                w, heal_t = data
                if w in partitioned:
                    # suspect_grace ran out mid-partition: the driver
                    # declares a LIVE worker dead — sole-copy values replay
                    # through lineage (the phantom recovery cost a longer
                    # grace would have avoided), and the worker rejoins
                    # empty when the partition actually heals
                    partitioned.pop(w)
                    res.n_false_deaths += 1
                    if w in alive:
                        handle_failure(w, now)
                        res.timeline.append((now, f"false death w{w}"))
                    push(heal_t, "join", (w, 1.0))
                    for v in list(alive):
                        if v not in running:
                            try_acquire(v, now)
            elif kind == "driver_kill":
                driver_down = True
                res.timeline.append((now, "driver killed"))
                # workers that die WITH the driver are confirmed losses at
                # resume — one handle_failure each folds into the single
                # reconciliation pass (their requeued work sits in the
                # central queue until dispatch unblocks)
                for w in self.driver_dead_workers:
                    if w in alive:
                        handle_failure(w, now)
                        res.timeline.append((now, f"fail w{w} (outage)"))
            elif kind == "driver_resume":
                driver_down = False
                res.timeline.append((now, "driver resumed"))
                for v in list(alive):
                    if v not in running:
                        try_acquire(v, now)

        if pending:
            n_ready = sum(1 for t in pending if ready_p(t))
            frontier = [t for t in sorted(pending)
                        if all(d in done or d not in pending
                               for d in g.nodes[t].all_deps)][:5]
            detail = {t: {"inflight": sorted(inflight.get(t, ())),
                          "missing_deps": [d for d in g.nodes[t].all_deps
                                           if d not in done]}
                      for t in frontier}
            raise RuntimeError(
                f"simulation deadlocked with {len(pending)} tasks pending "
                f"({n_ready} ready; alive={sorted(alive)}; "
                f"running={ {w: r[0] for w, r in running.items()} }; "
                f"deques={ {w: len(d) for w, d in deques.items() if d} }; "
                f"central={len(central)}; frontier={detail})")
        res.busy_time = busy
        return res


def simulate(graph: TaskGraph, n_workers: int, **kw) -> SimResult:
    return ClusterSim(graph, n_workers, **kw).run()


#: knobs search_policy knows how to sweep, and the sim parameter each maps
#: to.  Fusion-shape knobs go through ``fuse_kw`` so each candidate prices
#: a different REGROUPING of the same graph.
SEARCHABLE_POLICIES = ("suspect_grace", "collective_arity",
                       "speculate_after", "keep_parallelism",
                       "fanin_cost", "group_cost")


def search_policy(
    name: str,
    graph: TaskGraph,
    n_workers: int,
    candidates: List,
    *,
    events: Optional[List[WorkerEvent]] = None,
    trace: Optional[RunTrace] = None,
    **kw,
):
    """One front door for every offline policy search.

    Sweeps ``candidates`` for the named knob over the same scenario and
    returns ``(best, results)``.  ``trace`` (a recorded
    :class:`repro.core.adaptive.RunTrace`, e.g. a live run's
    ``ClusterExecutor.last_trace``) replays *measured* per-member
    durations instead of declared costs — that is what closes the loop
    from runtime measurement back to offline search: candidates are
    priced against what the cluster actually did, and the winner feeds
    straight back into ``ClusterConfig``.

    Knobs and tie-breaks (all minimize makespan first):

    ``suspect_grace``      fewer recomputes, then the smaller grace
                           (requires partition ``events``)
    ``collective_arity``   the larger arity (shallower tree)
    ``speculate_after``    fewer speculative twins, then the smaller
                           threshold
    ``keep_parallelism`` / ``fanin_cost`` / ``group_cost``
                           the smaller candidate; swept through
                           ``fuse_kw`` (``fuse`` defaults to ``"auto"``
                           for these so the knob has something to shape)
    """
    if name not in SEARCHABLE_POLICIES:
        raise ValueError(f"unknown policy knob {name!r}; searchable: "
                         f"{SEARCHABLE_POLICIES}")
    if not candidates:
        noun = {"suspect_grace": "grace",
                "collective_arity": "arity"}.get(name, name)
        raise ValueError(f"need at least one candidate {noun}")
    if name == "suspect_grace" and events is None:
        raise ValueError("suspect_grace search needs partition events")
    if trace is not None:
        kw["trace"] = trace
    if name in ("keep_parallelism", "fanin_cost", "group_cost"):
        kw.setdefault("fuse", "auto")
    results: Dict = {}
    for cand in candidates:
        ckw = dict(kw)
        if events is not None:
            ckw["events"] = list(events)
        if name == "suspect_grace":
            ckw["suspect_grace"] = cand
        elif name == "collective_arity":
            if parse_collectives_spec(cand) == "off":
                raise ValueError(f"candidate arity {cand} is not a tree")
            ckw["collectives"] = cand
        elif name == "speculate_after":
            ckw["speculate_after"] = cand
        else:
            fkw = dict(ckw.pop("fuse_kw", None) or {})
            fkw[name] = int(cand) if name == "keep_parallelism" else cand
            ckw["fuse_kw"] = fkw
        results[cand] = simulate(graph, n_workers, **ckw)
    if name == "suspect_grace":
        def key(c):
            return (results[c].makespan, results[c].n_recomputed, c)
    elif name == "collective_arity":
        def key(c):
            return (results[c].makespan, -c)
    elif name == "speculate_after":
        def key(c):
            return (results[c].makespan, results[c].n_speculative, c)
    else:
        def key(c):
            return (results[c].makespan, c)
    best = min(results, key=key)
    return best, results


def search_suspect_grace(
    graph: TaskGraph,
    n_workers: int,
    candidates: List[float],
    *,
    events: List[WorkerEvent],
    **kw,
) -> Tuple[float, Dict[float, SimResult]]:
    """Offline policy search for the executor's ``suspect_grace`` knob.

    Replays the same partition scenario (``events`` with ``"partition"``
    entries; ``factor`` = outage seconds) under each candidate grace and
    returns ``(best, results)``.  Too short a grace converts transient
    partitions into false deaths and phantom recomputation
    (:func:`repro.core.lineage.phantom_recovery_cost` is the per-event
    analytic form); too long a grace leaves the pool waiting on a worker
    that really is dead.  ``best`` minimizes makespan, ties broken toward
    fewer recomputes, then the *smaller* grace (detect true deaths
    sooner).  Feed the winner straight to
    ``ClusterExecutor(suspect_grace=...)``.  Thin wrapper over
    :func:`search_policy` (same candidates, scenario, and tie-breaks).
    """
    return search_policy("suspect_grace", graph, n_workers, candidates,
                         events=events, **kw)


def search_collective_arity(
    graph: TaskGraph,
    n_workers: int,
    candidates: List[int],
    **kw,
) -> Tuple[int, Dict[int, SimResult]]:
    """Offline policy search for the collective tree arity
    (``ClusterExecutor(collectives=<arity>)`` / ``--collectives N``).

    Re-lowers the SAME traced graph under each candidate arity (the
    ``collectives`` integer spec overrides every node's traced arity) and
    simulates it: a small arity makes the tree deep (more staged hops,
    more dispatch overheads on the critical path), a large arity makes
    each stage wide (one stage serializes many combines and a single
    slow input stalls more of the tree).  The sweet spot depends on
    ``n_workers``, ``dispatch_overhead``, and ``comm_per_byte`` — i.e.
    on the machine, which is why this is a searched knob and not a
    constant (the ``hillclimb``/``search_suspect_grace`` pattern;
    ROADMAP item 4).  ``best`` minimizes makespan, ties toward the
    larger arity (shallower tree ⇒ fewer dispatches at equal makespan).
    Thin wrapper over :func:`search_policy`.
    """
    return search_policy("collective_arity", graph, n_workers, candidates,
                         **kw)
