"""RealWorld-token threading.

The paper: "Notice that RealWorld is considered an input and output by each
IO function."  We realize the same state-token model with an explicit value:
every effectful task consumes the current :class:`EffectToken` and produces a
fresh one, which linearizes effects in the DAG while pure work floats freely.

The token is a real (scalar) array so the SPMD mesh executor can thread it
through a jitted program without special-casing.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EffectToken:
    """Opaque ordering token. ``epoch`` is only for debugging/printing."""

    epoch: int = 0

    def next(self) -> "EffectToken":
        return EffectToken(self.epoch + 1)

    def as_array(self):
        # Used when a token flows through a jitted SPMD program.
        return jnp.zeros((), dtype=jnp.float32) + self.epoch


def initial_token() -> EffectToken:
    return EffectToken(0)
