"""Greedy ready-set scheduling — the paper's scheduler, made concrete.

The paper: "a scheduler ... greedily schedules tasks to worker nodes as their
inputs are ready".  We implement that greedy rule and extend it with the two
standard refinements a production system needs:

* **priority** within the ready set — critical-path (HEFT ``rank_u``) first,
  FIFO and random as ablation baselines;
* **worker choice** — earliest-finish-time over heterogeneous-speed workers,
  with an optional per-edge communication delay (locality-aware).

The static schedule produced here is used (a) directly by the mesh executor
to order SPMD task launches, (b) as the baseline the work-stealing runtime
(:mod:`repro.core.simulator`, :mod:`repro.core.executor`) is compared
against, and (c) for elastic re-planning when the worker set changes.

Since the fusion pass (:mod:`repro.core.fusion`) the cluster runtime plans
over the *fused* cluster-level graph, not the raw task graph: node ids are
super-task ids, ``cost``/``out_bytes`` are aggregates, and the
``data_sizes`` comm-cost term therefore prices only **cross-cluster**
edges — intra-cluster values never move, so they never enter the plan.
Nothing here special-cases that: a ``FusedPlan.cgraph`` is an ordinary
:class:`TaskGraph`, which is the point.

Collectives get the same treatment, one pass earlier: a traced
``all_reduce``/``gather``/``broadcast`` node would price as N×M
point-to-point edges here, but
:func:`repro.core.collectives.lower_collectives` rewrites it into an
arity-bounded stage tree *before* planning, so the graph this module
sees already has log-depth structure — every node's fan-in is at most
the tree arity, the comm term prices one hop per value per level, and
EFT spreads sibling stages across workers for free.
:func:`collective_comm_cost` is the closed-form of that price, used by
the offline arity search (``simulator.search_collective_arity``) and
``docs/collectives.md``'s costing model.
"""
from __future__ import annotations

import dataclasses
import heapq
import random as _random
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .graph import TaskGraph


@dataclasses.dataclass(frozen=True)
class Placement:
    tid: int
    worker: int
    start: float
    end: float


@dataclasses.dataclass
class Schedule:
    placements: Dict[int, Placement]
    n_workers: int

    @property
    def makespan(self) -> float:
        return max((p.end for p in self.placements.values()), default=0.0)

    def order_for_worker(self, worker: int) -> List[int]:
        ps = [p for p in self.placements.values() if p.worker == worker]
        return [p.tid for p in sorted(ps, key=lambda p: p.start)]

    def utilization(self) -> float:
        busy = sum(p.end - p.start for p in self.placements.values())
        total = self.makespan * self.n_workers
        return busy / total if total > 0 else 1.0

    def expected_durations(self) -> Dict[int, float]:
        """Static cost-model hint: the planned execution time of each task
        (``end - start`` of its placement, i.e. ``cost / worker_speed`` —
        queue/transfer waits are not included).  The cluster runtime's
        speculation policy calibrates these cost-unit durations into
        seconds with a runtime EWMA to decide when a running task is
        overdue (see ``docs/speculation.md``)."""
        return {tid: p.end - p.start for tid, p in self.placements.items()}

    def validate_against(self, graph: TaskGraph) -> None:
        """Every dep finishes before its consumer starts; no worker overlap."""
        for node in graph.nodes.values():
            p = self.placements[node.tid]
            for d in node.all_deps:
                if self.placements[d].end > p.start + 1e-9:
                    raise AssertionError(
                        f"task {node.tid} starts before dep {d} ends")
        by_worker: Dict[int, List[Placement]] = {}
        for p in self.placements.values():
            by_worker.setdefault(p.worker, []).append(p)
        for ps in by_worker.values():
            ps.sort(key=lambda p: p.start)
            for a, b in zip(ps, ps[1:]):
                if a.end > b.start + 1e-9:
                    raise AssertionError("overlapping tasks on one worker")


def list_schedule(
    graph: TaskGraph,
    n_workers: int,
    *,
    policy: str = "critical_path",       # | "fifo" | "random"
    worker_speed: Optional[Sequence[float]] = None,
    comm_cost: Optional[Callable[[int, int], float]] = None,
    seed: int = 0,
    start_time: float = 0.0,
    done: Optional[Dict[int, float]] = None,
    data_sizes: Optional[Dict[int, int]] = None,
    bandwidth: float = float(256 << 20),
    placed: Optional[Dict[int, int]] = None,
    worker_host: Optional[Sequence[Any]] = None,
    near_factor: float = 0.25,
    cost_scale: float = 1.0,
) -> Schedule:
    """Greedy list scheduling.

    ``cost_scale`` converts abstract ``node.cost`` units into the seconds
    the comm-cost terms are priced in (``size / bandwidth``).  The
    default ``1.0`` keeps the historical convention that one cost unit is
    one second; the adaptive runtime passes its measured
    ``CostModel.unit_s`` (seconds per unit) so compute and transfer
    finally land on one axis and the EFT trade-off between "run near the
    data" and "run on the free worker" uses real magnitudes.  Placements
    and :meth:`Schedule.expected_durations` come back in the scaled
    (seconds) axis.

    ``done`` maps already-completed task ids to their completion times —
    used for elastic re-planning mid-flight (those tasks are not rescheduled
    but their finish times gate successors).

    Transfer-cost-aware placement: ``data_sizes`` (task id -> payload
    bytes, as recorded by the cluster runtime at completion) synthesizes a
    per-edge ``comm_cost`` of ``size / bandwidth`` when none is given, and
    ``placed`` (task id -> worker index for already-completed tasks) makes
    that cost apply to edges out of *completed* work too — so a mid-run
    replan keeps consumers next to the worker already holding their input
    bytes instead of treating finished values as free everywhere.

    ``worker_host`` (one machine id per worker index) adds per-host
    locality grouping to the synthesized cost: an edge between two workers
    on the same host moves over shared memory / a unix socket and costs
    ``near_factor`` of the cross-host (TCP) price, so the plan prefers
    keeping a value's consumers on the machine that holds it while still
    treating two same-host workers as distinct.  It scales only the
    synthesized ``data_sizes`` cost; an explicit ``comm_cost`` callable is
    used verbatim.
    """
    if n_workers <= 0:
        raise ValueError("need at least one worker")
    speeds = list(worker_speed) if worker_speed else [1.0] * n_workers
    if len(speeds) != n_workers:
        raise ValueError("worker_speed length mismatch")
    hosts = list(worker_host) if worker_host is not None else None
    if hosts is not None and len(hosts) != n_workers:
        raise ValueError("worker_host length mismatch")
    done = dict(done or {})
    placed = dict(placed or {})
    edge_cost: Optional[Callable[[int, int, int, int], float]] = None
    if comm_cost is not None:
        cc = comm_cost
        edge_cost = lambda d, t, pw, w: cc(d, t)            # noqa: E731
    elif data_sizes:
        sizes = data_sizes

        def edge_cost(d: int, t: int, pw: int, w: int) -> float:
            c = sizes.get(d, 0) / bandwidth
            if hosts is not None and hosts[pw] == hosts[w]:
                c *= near_factor            # same-host move: shm-near
            return c
    rng = _random.Random(seed)

    rank = graph.critical_path_rank()
    if policy == "critical_path":
        prio = lambda tid: (-rank[tid], tid)
    elif policy == "fifo":
        prio = lambda tid: (tid,)
    elif policy == "random":
        jitter = {tid: rng.random() for tid in graph.nodes}
        prio = lambda tid: (jitter[tid], tid)
    else:
        raise ValueError(f"unknown policy {policy!r}")

    indeg = graph.in_degree()
    succ = graph.successors()
    finish: Dict[int, float] = dict(done)
    for tid in done:
        for s in succ.get(tid, []):
            indeg[s] -= 1
    ready: List[Tuple] = []
    for tid, d in indeg.items():
        if tid in done:
            continue
        if d == 0:
            heapq.heappush(ready, (*prio(tid), tid))

    worker_free = [start_time] * n_workers
    placements: Dict[int, Placement] = {}

    while ready:
        entry = heapq.heappop(ready)
        tid = entry[-1]
        node = graph.nodes[tid]
        deps_done = max((finish[d] for d in node.all_deps), default=start_time)
        # earliest-finish-time worker choice
        best = None
        for w in range(n_workers):
            est = max(worker_free[w], deps_done)
            if edge_cost is not None:
                for d in node.deps:
                    if d in placements:
                        pw = placements[d].worker
                    else:           # completed task: known owner, else local
                        pw = placed.get(d, w)
                    if pw != w:
                        est = max(est, finish[d] + edge_cost(d, tid, pw, w))
            dur = node.cost * cost_scale / speeds[w]
            eft = est + dur
            if best is None or eft < best[0]:
                best = (eft, est, w)
        eft, est, w = best  # type: ignore[misc]
        placements[tid] = Placement(tid, w, est, eft)
        worker_free[w] = eft
        finish[tid] = eft
        for s in succ[tid]:
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(ready, (*prio(s), s))

    if len(placements) + len(done) != len(graph.nodes):
        raise AssertionError("scheduler did not place every task")
    return Schedule(placements, n_workers)


def replan(
    graph: TaskGraph,
    completed: Dict[int, float],
    n_workers: int,
    now: float,
    **kw,
) -> Schedule:
    """Elastic re-plan: schedule only the not-yet-completed tasks on the new
    worker set (workers may have joined or left)."""
    return list_schedule(graph, n_workers, done=completed, start_time=now, **kw)


def fair_interleave(
    items: Sequence[Any],
    tenant_of: Callable[[Any], Any],
    key: Callable[[Any], Any],
    weights: Optional[Dict[Any, float]] = None,
) -> List[Any]:
    """Weighted round-robin interleave of a ready set across tenants.

    The resident (multi-tenant) executor dispatches from one union ready
    set; a plain global priority sort would let a tenant with a wide,
    high-rank graph starve everyone else's short interactive jobs.  This
    deterministically reorders ``items`` so each scheduling pass offers
    every tenant a slot before any tenant gets a second one (``weights``
    scale slots-per-round; fractional weights accumulate as deficits, so
    a weight of 0.5 yields a slot every other round).

    Within a tenant, ``key`` orders its own items (the executor passes its
    usual critical-path priority), so fairness is *between* tenants only —
    each tenant's work still runs in rank order.  Pure and deterministic:
    equal inputs give equal output, keeping replays and differential tests
    stable.
    """
    groups: Dict[Any, List[Any]] = {}
    for it in items:
        groups.setdefault(tenant_of(it), []).append(it)
    for g in groups.values():
        g.sort(key=key)
    tenants = sorted(groups, key=repr)
    idx = {t: 0 for t in tenants}
    credit = {t: 0.0 for t in tenants}
    out: List[Any] = []
    while len(out) < len(items):
        progressed = False
        for t in tenants:
            w = float((weights or {}).get(t, 1.0))
            credit[t] += max(0.0, w)
            g = groups[t]
            while credit[t] >= 1.0 and idx[t] < len(g):
                credit[t] -= 1.0
                out.append(g[idx[t]])
                idx[t] += 1
                progressed = True
        if not progressed:
            # only zero-weight (or credit-starved) tenants left: drain them
            # round-robin so every ready item is still eventually offered
            for t in tenants:
                if idx[t] < len(groups[t]):
                    out.append(groups[t][idx[t]])
                    idx[t] += 1
    return out


def theoretical_speedup(graph: TaskGraph, n_workers: int) -> float:
    """Brent's bound: T_p >= max(T_1 / p, T_inf); speedup <= T_1 / that."""
    t1 = graph.total_work()
    tinf = graph.critical_path_length()
    tp = max(t1 / n_workers, tinf)
    return t1 / tp if tp > 0 else 1.0


def collective_comm_cost(n: int, consumers: int, value_bytes: int,
                         bandwidth: float, *, arity: int = 4,
                         n_hosts: int = 1,
                         cross_host_penalty: float = 2.0) -> float:
    """Closed-form structured-shape price of a lowered reduction/gather
    feeding ``consumers`` readers — the model behind the collective
    lowering's win over N×M point-to-point edges.

    Point-to-point moves ``n × consumers`` values; the tree moves one
    value per input up a ``ceil(log_arity n)``-depth combine tree (at
    most ``n - 1`` hop transfers in total, levels overlapping across
    workers) and one result per consumer down — ``~(n + consumers)``
    transfers instead of ``n × consumers``.  With ``n_hosts > 1`` each
    host's members reduce locally first (intra-host hops on the shm
    fast path) and exactly one partial per host crosses the boundary —
    priced at ``cross_host_penalty``×, mirroring
    ``ClusterExecutor.move_cost`` doubling cross-host bytes.  Compare
    against ``n * consumers * value_bytes / bandwidth`` to decide when
    point-to-point still wins (tiny n, or one consumer —
    docs/collectives.md)."""
    if bandwidth <= 0:
        return 0.0
    per_value = value_bytes / bandwidth
    arity = max(2, arity)
    up_hops = max(0, n - 1)             # combine-tree edges, all levels
    if n_hosts > 1:
        intra = max(0, n - n_hosts)     # local partial reductions
        cross = n_hosts - 1             # one partial per host crosses
        up = intra * per_value + cross * per_value * cross_host_penalty
    else:
        up = up_hops * per_value
    down = consumers * per_value        # result fan-out (broadcast tree)
    return up + down
