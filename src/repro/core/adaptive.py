"""Profile-guided adaptive replanning: the measurement→decision loop.

Every planning knob in PRs 1–9 was a constant fixed at plan time:
``node.cost`` is in abstract units, ``fusion.fuse``'s fan-in/group gates
are abstract units, ``keep_parallelism`` is a constant, and
``speculate_after`` is one number.  Meanwhile the executor measures
ground truth on every completion — per-cluster wall seconds, per-dispatch
driver overhead, per-value sizes.  This module closes the loop (ROADMAP
item 2): it holds the *policy* state and the *pure decision functions*
shared by the real :class:`repro.cluster.ClusterExecutor` and the offline
:class:`repro.core.simulator.ClusterSim`, so that offline policy search
and the live runtime provably agree (the ``pick_speculation`` pattern
from PR 4, generalized).

Three design rules keep the loop safe:

1. **Decisions are scale-invariant.**  Every decision (re-fusion trigger,
   calibrated fusion gates, derived ``speculate_after``) depends only on
   *ratios* of measured seconds — uniformly scaling all observed
   durations (a faster machine, a slower day) changes no decision.
   ``tests/test_adaptive.py`` pins this as a property.
2. **Decisions never touch values.**  Calibration rescales costs, picks
   placements, and regroups *not-yet-dispatched* clusters; member tasks
   still execute the same pure functions in the same topological order,
   so results stay bit-for-bit equal to ``execute_sequential``.
3. **Decisions are journaled.**  A mid-run re-fusion is appended to the
   run log and replayed verbatim on ``--resume`` — a restarted driver
   reconstructs the exact post-refusion plan before reconciling ``done``
   claims (see ``docs/adaptive.md``).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "CostModel", "RefuseGovernor", "RunTrace", "fn_key", "refusion_due",
    "MIN_OBS", "MAX_REFUSIONS", "MIN_FRONTIER", "GATE_OVERHEADS",
    "SPEC_AFTER_MIN", "SPEC_AFTER_MAX",
]

# -- policy constants (documented in docs/adaptive.md) -------------------
MIN_OBS = 6          # completions required before any adaptive decision
MAX_REFUSIONS = 3    # hard cap on mid-run re-fusions per incarnation
MIN_FRONTIER = 4     # smallest not-yet-dispatched frontier worth re-fusing
GATE_OVERHEADS = 8.0  # calibrated gate: fuse while cluster compute
#                       seconds stay within this many dispatch overheads
SPEC_AFTER_MIN = 1.5  # derived speculate_after clamp (×expected duration)
SPEC_AFTER_MAX = 8.0


def fn_key(node) -> Optional[str]:
    """Profile key for a task node: the *code identity* of its function.

    Observed duration ratios generalize across tasks by template, not by
    task id — every call of the same function body tends to mis-cost the
    same way.  ``__qualname__`` is stable across processes and resumes
    (unlike ``id(fn)``) and shared by all tasks traced from one def.
    """
    fn = getattr(node, "fn", None)
    if fn is None:
        return None
    return getattr(fn, "__qualname__", None) or getattr(
        fn, "__name__", None)


@dataclasses.dataclass
class CostModel:
    """Calibrates abstract ``node.cost`` units against measured seconds.

    ``observe()`` is fed one completed cluster at a time: the planned
    cost in units and the measured wall seconds.  It maintains

    * ``unit_s`` — EWMA seconds per cost unit (the same 0.9/0.1 blend the
      pre-adaptive executor used for speculation expectations), i.e. the
      global exchange rate between planner units and wall clock;
    * ``fn_ratio`` — per-function-template seconds-per-unit, the
      profile-guided part: a template that runs 60× its declared cost
      keeps that ratio wherever it appears next;
    * a per-observation ratio log, from which the re-fusion trigger
      computes duration *skew* and the speculation auto-tuner computes
      duration *variance* — both as dimensionless ratios.
    """

    alpha: float = 0.1       # EWMA weight for the global unit (PR-4 blend)
    fn_alpha: float = 0.5    # per-template ratios adapt fast (few samples)
    unit_s: Optional[float] = None
    dispatch_s: float = 0.0  # measured mean per-dispatch driver seconds
    n_obs: int = 0
    fn_ratio: Dict[str, float] = dataclasses.field(default_factory=dict)
    ratio_log: List[float] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------- observations
    def observe(self, planned_units: float, wall_s: float,
                fn_units: Tuple[Tuple[Optional[str], float], ...] = (),
                ) -> float:
        """Record one completed cluster.  ``fn_units`` lists the cluster's
        members as ``(fn_key, declared_units)`` pairs; the cluster's wall
        is attributed to each template proportional to its declared share
        (exact for homogeneous clusters and singletons — probes)."""
        ratio = wall_s / max(planned_units, 1e-9)
        self.unit_s = (ratio if self.unit_s is None
                       else (1 - self.alpha) * self.unit_s
                       + self.alpha * ratio)
        self.n_obs += 1
        self.ratio_log.append(ratio)
        for key, units in fn_units:
            if key is None or units <= 0:
                continue
            old = self.fn_ratio.get(key)
            self.fn_ratio[key] = (ratio if old is None
                                  else (1 - self.fn_alpha) * old
                                  + self.fn_alpha * ratio)
        return ratio

    def observe_dispatch(self, total_overhead_s: float,
                         n_dispatched: int) -> None:
        """Refresh the measured per-dispatch cost from the executor's
        running ``dispatch_overhead_s`` / ``dispatched`` counters."""
        if n_dispatched > 0:
            self.dispatch_s = total_overhead_s / n_dispatched

    # ---------------------------------------------------------- exchange
    def seconds(self, units: float) -> float:
        """Planner units → predicted wall seconds (identity uncalibrated)."""
        return units * (self.unit_s if self.unit_s else 1.0)

    def corrected_units(self, node) -> float:
        """Profile-corrected cost of ``node`` in *units*: declared cost
        rescaled by its template's observed ratio relative to the global
        unit.  A template never observed keeps its declared cost.  The
        correction is a ratio of two measured seconds-per-unit figures,
        so a uniform rescale of all observations cancels out."""
        cost = max(getattr(node, "cost", 1.0), 1e-9)
        if not self.unit_s:
            return cost
        r = self.fn_ratio.get(fn_key(node))
        if r is None:
            return cost
        return cost * (r / self.unit_s)

    def fuse_gates(self, base_fanin: float, base_group: float,
                   ) -> Tuple[float, float]:
        """Calibrated fusion cost gates, in (corrected) units.

        The point of fusing is amortizing the per-dispatch control-plane
        round-trip, so the natural gate is "keep fusing while a cluster's
        compute stays within :data:`GATE_OVERHEADS` dispatch overheads".
        Expressed in units that is ``GATE_OVERHEADS × dispatch_s /
        unit_s`` — invariant under uniform time rescaling.  Falls back to
        the static abstract-unit gates until both rates are measured."""
        if not self.unit_s or self.dispatch_s <= 0.0:
            return base_fanin, base_group
        gate = GATE_OVERHEADS * self.dispatch_s / self.unit_s
        return gate, gate

    # ---------------------------------------------------------- variance
    def skew(self, start: int = 0) -> float:
        """Duration skew of observations ``start:``, as max/median of the
        per-cluster seconds-per-unit ratios.  ≈1 when declared costs are
        proportional to the truth; large when some clusters are running
        far over their plan relative to the rest."""
        window = self.ratio_log[start:]
        if len(window) < 2:
            return 1.0
        srt = sorted(window)
        med = srt[len(srt) // 2]
        return srt[-1] / max(med, 1e-12)

    def cv(self) -> float:
        """Coefficient of variation (std/mean) of the observed ratios —
        dimensionless duration variance."""
        n = len(self.ratio_log)
        if n < 2:
            return 0.0
        mean = sum(self.ratio_log) / n
        if mean <= 0:
            return 0.0
        var = sum((r - mean) ** 2 for r in self.ratio_log) / (n - 1)
        return math.sqrt(var) / mean

    def derived_speculate_after(self) -> Optional[float]:
        """Auto-tuned speculation threshold (×expected duration): tight
        when durations are predictable (a straggler stands out quickly),
        loose when natural variance is high (so ordinary spread does not
        burn workers on twins).  ``None`` until enough observations."""
        if self.n_obs < MIN_OBS:
            return None
        return min(SPEC_AFTER_MAX,
                   max(SPEC_AFTER_MIN, SPEC_AFTER_MIN + 2.0 * self.cv()))


@dataclasses.dataclass
class RefuseGovernor:
    """Hysteresis around the re-fusion trigger.

    Fires when the duration skew of observations *since the last fire*
    exceeds ``skew_threshold``.  After a fire (or a no-op fire that left
    the partition unchanged) the window resets, so the governor must see
    :data:`MIN_OBS` fresh completions that are *themselves* skewed before
    acting again — one lopsided historical cluster cannot trigger
    re-fusion forever.  ``MAX_REFUSIONS`` is the hard cap per driver
    incarnation."""

    skew_threshold: float = 4.0
    min_obs: int = MIN_OBS
    max_refusions: int = MAX_REFUSIONS
    fired: int = 0
    window_start: int = 0    # ratio_log index where the current window opens
    last_skew: float = 1.0

    def should_fire(self, model: CostModel) -> bool:
        if self.fired >= self.max_refusions:
            return False
        if model.n_obs - self.window_start < self.min_obs:
            return False
        self.last_skew = model.skew(self.window_start)
        return self.last_skew > self.skew_threshold

    def note_fired(self, model: CostModel) -> None:
        self.fired += 1
        self.window_start = model.n_obs

    def note_no_change(self, model: CostModel) -> None:
        """The trigger fired but re-fusion reproduced the same partition:
        reset the window without spending a fire, so the governor stays
        quiet until genuinely new evidence arrives."""
        self.window_start = model.n_obs


def refusion_due(model: CostModel, governor: RefuseGovernor,
                 n_frontier: int, *, min_frontier: int = MIN_FRONTIER,
                 ) -> bool:
    """The shared re-fusion trigger: enough not-yet-dispatched clusters
    to be worth regrouping, and the governor's skew window open.  Both
    the live executor and :class:`repro.core.simulator.ClusterSim` call
    exactly this predicate (``tests/test_adaptive.py`` pins agreement)."""
    if n_frontier < min_frontier:
        return False
    return governor.should_fire(model)


# -------------------------------------------------------------- run traces

@dataclasses.dataclass
class RunTrace:
    """A recorded execution profile, replayable through the simulator.

    ``tasks`` maps member tid → attributed wall seconds (a cluster's
    measured wall split over its members by declared-cost share), which
    makes the trace *plan-independent*: a candidate policy that fuses the
    graph differently still prices each cluster as the sum of its
    members' recorded seconds.  This is what wires the offline search
    (``hillclimb.py search`` / :func:`repro.core.simulator.search_policy`)
    to live measurements."""

    tasks: Dict[int, float] = dataclasses.field(default_factory=dict)
    n_workers: int = 0
    unit_s: float = 0.0
    dispatch_s: float = 0.0

    def record(self, members, nodes: Dict[int, Any], wall_s: float) -> None:
        """Attribute one completed cluster's wall over its members."""
        total = sum(max(nodes[m].cost, 1e-9) for m in members)
        for m in members:
            self.tasks[m] = wall_s * max(nodes[m].cost, 1e-9) / total

    def cluster_seconds(self, members, nodes: Dict[int, Any]) -> float:
        """Predicted seconds for a (possibly re-fused) cluster: recorded
        member seconds where known, declared cost × recorded unit rate
        otherwise."""
        unit = self.unit_s or 1.0
        return sum(self.tasks.get(m, nodes[m].cost * unit) for m in members)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"tasks": {str(t): s for t, s in self.tasks.items()},
                       "n_workers": self.n_workers, "unit_s": self.unit_s,
                       "dispatch_s": self.dispatch_s}, f)

    @staticmethod
    def load(path: str) -> "RunTrace":
        with open(path) as f:
            raw = json.load(f)
        return RunTrace(
            tasks={int(t): float(s) for t, s in raw["tasks"].items()},
            n_workers=int(raw.get("n_workers", 0)),
            unit_s=float(raw.get("unit_s", 0.0)),
            dispatch_s=float(raw.get("dispatch_s", 0.0)))
