"""Plan-time graph compilation: fuse the task DAG into *super-tasks*.

The paper's purity guarantee means the runtime may rewrite the task graph
freely — results are a function of the graph alone, not of how it is cut
into dispatch units.  BENCH_multihost measured ~0.78 ms of control-plane
overhead per task on TCP, so a fine-grained graph (many small pure
functions — the paper's natural programming style) is *driver-bound*: the
cluster spends its time round-tripping ``run``/``done`` messages, not
computing.  Following Mapple's framing (mapping/granularity decisions
belong in a compilation pass over the graph, not in the per-task dispatch
loop), this module compiles the DAG **before** dispatch:

* :func:`fuse` clusters the graph into super-tasks and returns a
  :class:`FusedPlan` — the member-level graph, a *cluster-level*
  :class:`~repro.core.graph.TaskGraph` (``cgraph``) the scheduler and the
  driver state machine run over, and the member/boundary index maps the
  runtime needs (which values cross cluster edges, which stay private).
* A super-task is dispatched as **one** control message; the worker runs
  its members locally in topo order and only *cluster outputs* (values
  some other cluster, or the driver, will read) are kept/published.
* ``--fuse off`` produces the **identity plan**: ``cgraph`` *is* the
  original graph and cluster ids equal task ids, so fused and unfused
  execution share a single driver code path.

What fuses (all rules are deterministic, so every process that computes a
plan from the same graph and spec agrees):

1. **Single-consumer contraction** (chains and converging trees): a
   cluster whose members' only external successors live in one cluster
   ``Y`` is merged into ``Y``.  Contracting an out-degree-1 cluster into
   its sole successor can never create a cycle and — for a strict linear
   link (``Y``'s only external producer is ``X``) — can never lose
   parallelism either, so strict chains fuse regardless of cost; a
   *fan-in* merge (``Y`` has other producers) is gated by ``fanin_cost``
   because the absorbed producer could otherwise have overlapped with
   ``Y``'s other inputs.
2. **Sibling grouping** (wide maps): clusters at the same topo depth with
   identical dependency signatures (equal depth ⇒ no path between them ⇒
   merging is cycle-safe) are packed into groups, bounded by
   ``group_cost``/``max_members`` and floored at ``keep_parallelism``
   groups so a wide map still feeds every worker.

``BARRIER`` and ``EFFECTFUL`` nodes never fuse (a barrier is a lineage
cut, and replaying half-fused IO at recovery would duplicate effects);
``PURE`` and ``PROJECTION`` nodes do.  ``COLLECTIVE`` nodes — the staged
tree hops :func:`repro.core.collectives.lower_collectives` emits — are
**cluster boundaries** too: each hop must stay its own dispatch unit so
sibling stages of one tree level run on different workers in parallel,
and a SIGKILL'd mid-tree aggregator replays as exactly one cluster
(its subtree), never as part of an absorbed producer chain.  Their
fan-in costing is shape-aware by construction: lowering prices each
stage at ``root_cost × width / n`` (width <= the tree arity), so the
cost gates here and the scheduler's EFT term see per-hop work, never
the original N-wide fan-in (docs/collectives.md).

This is the runtime sibling of :func:`repro.core.tracing.fuse_cheap_chains`
(a trace-time rewrite that composes Python callables and *erases* member
identity).  The runtime pass must keep members addressable — lineage
recovery, differential tests, and ``run(graph)``'s ``{tid: value}``
contract all speak member task ids — so it fuses at the *plan* level and
leaves the graph untouched.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple, Union

from .graph import GraphError, TaskGraph, TaskKind, TaskNode

#: kinds that may share a cluster with other members.  COLLECTIVE is
#: deliberately absent: a lowered collective stage is a cluster boundary
#: (parallel tree levels + subtree-bounded recovery — module docstring)
FUSABLE_KINDS = (TaskKind.PURE, TaskKind.PROJECTION)

DEFAULT_MAX_MEMBERS = 32        # member cap per super-task
DEFAULT_FANIN_COST = 8.0        # cost cap for non-chain (fan-in) merges
DEFAULT_GROUP_COST = 8.0        # cost cap per sibling group
DEFAULT_KEEP_PARALLELISM = 8    # sibling groups never packed below this

FuseSpec = Union[None, bool, int, str]


def parse_fuse_spec(spec: FuseSpec):
    """Normalize a user-facing fuse spec to ``"off"`` | ``"auto"`` | int.

    Accepts the launcher vocabulary (``--fuse {auto,off,N}``), booleans,
    and ``None`` (off).  ``N`` caps cluster size at ``N`` members with the
    auto rules; ``N <= 1`` is the identity (a one-member cluster per task).
    """
    if spec is None or spec is False:
        return "off"
    if spec is True:
        return "auto"
    if isinstance(spec, int) and not isinstance(spec, bool):
        return "off" if spec <= 1 else spec
    if isinstance(spec, str):
        s = spec.strip().lower()
        if s in ("off", "none", "0", "1"):
            return "off"
        if s == "auto":
            return "auto"
        try:
            n = int(s)
        except ValueError:
            raise ValueError(
                f"unknown fuse spec {spec!r} (expected 'auto', 'off', or a "
                f"max-members integer)") from None
        return "off" if n <= 1 else n
    raise ValueError(f"unknown fuse spec {spec!r}")


@dataclasses.dataclass
class WorkerFusionView:
    """The per-run slice of a plan a worker needs to execute super-tasks:
    which member tids each cluster id runs (topo order) and which of them
    to keep in the local store (cluster outputs plus driver-required
    values).  Plain dicts of int tuples — a few bytes per task — so it
    ships in spawn args and TCP welcome frames alike."""

    members: Dict[int, Tuple[int, ...]]
    keep: Dict[int, Tuple[int, ...]]


@dataclasses.dataclass
class FusedPlan:
    """The compiled execution plan for one graph.

    ``cgraph`` is a real :class:`TaskGraph` over cluster ids (topo-ordered,
    ``fn=None``, cost/out_bytes aggregated), so the scheduler, the
    simulator, and the driver's critical-path machinery run on it
    unchanged — and its comm-cost terms see only **cross-cluster** edges.
    For the identity plan ``cgraph is graph`` and every map is trivial,
    which is what keeps ``--fuse off`` byte-identical to the pre-fusion
    runtime.
    """

    graph: TaskGraph                          # member-level graph
    cgraph: TaskGraph                         # cluster-level graph
    members: Dict[int, Tuple[int, ...]]       # cid -> member tids (topo)
    cluster_of: Dict[int, int]                # member tid -> cid
    outputs: Dict[int, Tuple[int, ...]]       # cid -> externally read values
    ext_deps: Dict[int, Tuple[int, ...]]      # cid -> external input values
    consumers: Dict[int, Tuple[int, ...]]     # value -> consuming cids (ext)
    spec: Any = "off"

    @property
    def identity(self) -> bool:
        return self.cgraph is self.graph

    @property
    def n_clusters(self) -> int:
        return len(self.cgraph.nodes)

    @property
    def n_fused(self) -> int:
        """Tasks that no longer cost a dispatch round-trip."""
        return len(self.graph.nodes) - len(self.cgraph.nodes)

    def worker_view(self, required: Iterable[int]) -> WorkerFusionView:
        """Build the worker-facing slice.  ``required`` is the set of
        member values the driver must materialize at the end of the run
        (all tasks, or just ``graph.outputs`` under ``outputs_only``).
        The identity plan keeps everything — exactly the pre-fusion worker
        behavior — while a real plan keeps only boundary values."""
        if self.identity:
            return WorkerFusionView(dict(self.members), dict(self.members))
        req = set(required)
        keep = {
            cid: tuple(m for m in ms
                       if m in req or m in self._outset[cid])
            for cid, ms in self.members.items()
        }
        return WorkerFusionView(dict(self.members), keep)

    def __post_init__(self) -> None:
        self._outset: Dict[int, Set[int]] = {
            cid: set(vs) for cid, vs in self.outputs.items()}

    def summary(self) -> str:
        sizes = [len(m) for m in self.members.values()]
        return (f"FusedPlan(tasks={len(self.graph.nodes)}, "
                f"clusters={self.n_clusters}, fused={self.n_fused}, "
                f"max_cluster={max(sizes, default=0)})")


def identity_plan(graph: TaskGraph) -> FusedPlan:
    """One cluster per task, cluster id == task id, ``cgraph is graph``."""
    members = {t: (t,) for t in graph.nodes}
    succ = graph.successors()
    return FusedPlan(
        graph=graph,
        cgraph=graph,
        members=members,
        cluster_of={t: t for t in graph.nodes},
        outputs=dict(members),
        ext_deps={t: n.all_deps for t, n in graph.nodes.items()},
        consumers={t: tuple(succ[t]) for t in graph.nodes},
        spec="off",
    )


def offset_plan(plan: FusedPlan, base: int, off_graph: TaskGraph) -> FusedPlan:
    """Rebase a job-local :class:`FusedPlan` by ``+base`` onto ``off_graph``
    (the job's graph already shifted by :func:`repro.core.tracing.offset_graph`).

    Jobs submitted to a resident executor are fused in their own pristine
    0-based space — the fusion rules are deterministic over *that* graph —
    and then transplanted into the executor's union namespace, where both
    cluster ids and member tids live in the job's ``[base, base + n)``
    range.  An identity job plan stays identity (``cgraph is off_graph``),
    so unfused jobs keep the single driver code path.
    """
    if plan.identity:
        cgraph = off_graph
    else:
        cgraph = TaskGraph()
        for cid in sorted(plan.cgraph.nodes):
            n = plan.cgraph.nodes[cid]
            meta = dict(n.meta)
            if "members" in meta:
                meta["members"] = tuple(m + base for m in meta["members"])
            cgraph.nodes[cid + base] = dataclasses.replace(
                n,
                tid=cid + base,
                deps=tuple(d + base for d in n.deps),
                token_deps=tuple(d + base for d in n.token_deps),
                meta=meta,
            )
        cgraph.outputs = [o + base for o in plan.cgraph.outputs]
        cgraph._next_id = base + (max(plan.cgraph.nodes) + 1
                                  if plan.cgraph.nodes else 0)
    return FusedPlan(
        graph=off_graph,
        cgraph=cgraph,
        members={c + base: tuple(m + base for m in ms)
                 for c, ms in plan.members.items()},
        cluster_of={m + base: c + base for m, c in plan.cluster_of.items()},
        outputs={c + base: tuple(v + base for v in vs)
                 for c, vs in plan.outputs.items()},
        ext_deps={c + base: tuple(v + base for v in vs)
                  for c, vs in plan.ext_deps.items()},
        consumers={v + base: tuple(c + base for c in cs)
                   for v, cs in plan.consumers.items()},
        spec=plan.spec,
    )


class _UnionFind:
    def __init__(self, ids: Iterable[int]) -> None:
        self.parent = {i: i for i in ids}

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:          # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> int:
        """Merge ``a``'s set into ``b``'s root; returns the surviving root."""
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[ra] = rb
        return rb


def fuse(
    graph: TaskGraph,
    spec: FuseSpec = "auto",
    *,
    max_members: Optional[int] = None,
    fanin_cost: float = DEFAULT_FANIN_COST,
    group_cost: float = DEFAULT_GROUP_COST,
    keep_parallelism: int = DEFAULT_KEEP_PARALLELISM,
) -> FusedPlan:
    """Compile ``graph`` into a :class:`FusedPlan` (see module docstring).

    Deterministic: equal ``(graph, spec, knobs)`` always produce an equal
    plan, so the driver and every worker can each compute it locally and
    agree on cluster ids without shipping the plan itself.
    """
    mode = parse_fuse_spec(spec)
    graph.validate()
    if mode == "off" or len(graph.nodes) <= 1:
        return identity_plan(graph)
    cap = max_members if max_members is not None else (
        mode if isinstance(mode, int) else DEFAULT_MAX_MEMBERS)
    cap = max(1, cap)

    succ = graph.successors()
    order = graph.topo_order()
    uf = _UnionFind(graph.nodes)
    # per-root aggregates (only valid at the current root of each set)
    cost = {t: graph.nodes[t].cost for t in graph.nodes}
    size = {t: 1 for t in graph.nodes}
    roster: Dict[int, List[int]] = {t: [t] for t in graph.nodes}
    fusable = {t: graph.nodes[t].kind in FUSABLE_KINDS for t in graph.nodes}

    def merge(a: int, b: int) -> None:
        """Union root ``a`` into root ``b``, folding aggregates."""
        if a == b:
            return
        root = uf.union(a, b)
        gone = a if root == b else b
        cost[root] = cost[a] + cost[b]
        size[root] = size[a] + size[b]
        roster[root].extend(roster.pop(gone))
        fusable[root] = fusable[a] and fusable[b]

    def dep_roots(root: int) -> Set[int]:
        out = set()
        for m in roster[root]:
            for d in graph.nodes[m].all_deps:
                r = uf.find(d)
                if r != root:
                    out.add(r)
        return out

    # --- phase A: single-consumer contraction (reverse topo: sinks first,
    # so a chain collapses transitively in one pass) -----------------------
    for tid in reversed(order):
        x = uf.find(tid)
        if not fusable[x]:
            continue
        ext = {uf.find(s) for s in succ[tid]} - {x}
        if len(ext) != 1:
            continue        # a sink, or fans out to several clusters
        (y,) = ext
        if not fusable[y] or size[x] + size[y] > cap:
            continue
        # a strict linear link (Y's only producer is X) is serial either
        # way — fuse at any cost; a fan-in merge steals overlap, so gate it
        if dep_roots(y) != {x} and cost[x] + cost[y] > fanin_cost:
            continue
        merge(x, y)

    # --- phase B: sibling grouping (same depth + same dep signature ⇒ no
    # path between them ⇒ merging is cycle-safe) ---------------------------
    roots = sorted(roster, key=lambda r: min(roster[r]))
    depth: Dict[int, int] = {}              # cluster depth in the cluster DAG
    for tid in order:
        r = uf.find(tid)
        for dep in graph.nodes[tid].all_deps:
            rd = uf.find(dep)
            if rd != r:
                depth[r] = max(depth.get(r, 0), depth.get(rd, 0) + 1)
        depth.setdefault(r, 0)
    buckets: Dict[Tuple, List[int]] = {}
    for r in roots:
        if not fusable[r]:
            continue
        sig = (depth[r], tuple(sorted(min(roster[d]) for d in dep_roots(r))))
        buckets.setdefault(sig, []).append(r)
    # the parallelism floor is per topo DEPTH, not per signature bucket: a
    # wide map whose members fan in from rotating producer pairs splits
    # into many small buckets, and each alone would refuse to pack — but
    # what feeds the workers is the total cluster count at that depth
    depth_total: Dict[int, int] = {}
    for (d, _), grp in buckets.items():
        depth_total[d] = depth_total.get(d, 0) + len(grp)
    for sig in sorted(buckets):
        group = buckets[sig]
        per_group = depth_total[sig[0]] // max(1, keep_parallelism)
        if per_group < 2:
            continue                        # packing would eat parallelism
        acc: List[int] = []
        for r in group:
            r = uf.find(r)
            if acc and (len(acc) >= per_group
                        or size[uf.find(acc[0])] + size[r] > cap
                        or cost[uf.find(acc[0])] + cost[r] > group_cost):
                acc = []
            if acc:
                merge(r, uf.find(acc[0]))
            acc.append(r)

    return _build_plan(graph, uf, spec=mode)


def _build_plan(graph: TaskGraph, uf: _UnionFind, spec: Any) -> FusedPlan:
    """Topo-number the clusters and materialize the cluster-level graph."""
    groups: Dict[int, List[int]] = {}
    for tid in sorted(graph.nodes):
        # ascending task id IS topo order within a cluster (a dep's id is
        # always smaller than its consumer's), so members execute in id
        # order on the worker
        groups.setdefault(uf.find(tid), []).append(tid)

    # cluster DAG topo order, min-member heap tie-break: for all-singleton
    # plans this reproduces task-id order, so cid == tid when nothing fused
    root_deps: Dict[int, Set[int]] = {}
    root_succ: Dict[int, Set[int]] = {}
    for r, ms in groups.items():
        deps = set()
        for m in ms:
            for d in graph.nodes[m].all_deps:
                rd = uf.find(d)
                if rd != r:
                    deps.add(rd)
        root_deps[r] = deps
        for d in deps:
            root_succ.setdefault(d, set()).add(r)
    indeg = {r: len(ds) for r, ds in root_deps.items()}
    ready = [(min(groups[r]), r) for r, d in indeg.items() if d == 0]
    heapq.heapify(ready)
    root_order: List[int] = []
    while ready:
        _, r = heapq.heappop(ready)
        root_order.append(r)
        for s in root_succ.get(r, ()):
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(ready, (min(groups[s]), s))
    if len(root_order) != len(groups):      # pragma: no cover — defensive
        raise GraphError("fusion produced a cyclic cluster graph")

    cid_of_root = {r: i for i, r in enumerate(root_order)}
    cluster_of = {m: cid_of_root[r] for r, ms in groups.items() for m in ms}
    members = {cid_of_root[r]: tuple(ms) for r, ms in groups.items()}
    out_set = set(graph.outputs)

    cgraph = TaskGraph()
    outputs: Dict[int, Tuple[int, ...]] = {}
    ext_deps: Dict[int, Tuple[int, ...]] = {}
    consumers: Dict[int, List[int]] = {}
    succ = graph.successors()
    for r in root_order:
        cid = cid_of_root[r]
        ms = groups[r]
        nodes = [graph.nodes[m] for m in ms]
        deps: Set[int] = set()
        token_deps: Set[int] = set()
        evals: Set[int] = set()
        for n in nodes:
            for d in n.deps:
                if cluster_of[d] != cid:
                    deps.add(cluster_of[d])
                    evals.add(d)
            for d in n.token_deps:
                if cluster_of[d] != cid:
                    token_deps.add(cluster_of[d])
                    evals.add(d)
        token_deps -= deps
        outs = tuple(m for m in ms
                     if m in out_set
                     or any(cluster_of[s] != cid for s in succ[m]))
        outputs[cid] = outs
        ext_deps[cid] = tuple(sorted(evals))
        for v in sorted(evals):
            consumers.setdefault(v, []).append(cid)
        name = (nodes[0].name if len(nodes) == 1
                else f"{nodes[0].name}+{len(nodes) - 1}")
        kind = nodes[0].kind if len(nodes) == 1 else TaskKind.PURE
        got = cgraph.add_node(
            name, None, (), {}, kind,
            deps=tuple(sorted(deps)),
            token_deps=tuple(sorted(token_deps)),
            cost=sum(n.cost for n in nodes),
            out_bytes=sum(graph.nodes[m].out_bytes for m in outs),
            meta={"members": tuple(ms)},
        )
        assert got == cid
    seen_out = set()
    for o in graph.outputs:
        c = cluster_of[o]
        if c not in seen_out:
            seen_out.add(c)
            cgraph.mark_output(c)
    cgraph.validate()
    return FusedPlan(
        graph=graph, cgraph=cgraph, members=members, cluster_of=cluster_of,
        outputs=outputs, ext_deps=ext_deps,
        consumers={v: tuple(cs) for v, cs in consumers.items()},
        spec=spec,
    )


# ---------------------------------------------------------------------------
# Mid-run re-fusion (profile-guided adaptive replanning — docs/adaptive.md)
# ---------------------------------------------------------------------------

def refuse_frontier(
    plan: FusedPlan,
    frontier: Iterable[int],
    *,
    spec: FuseSpec = "auto",
    cost_of=None,
    fanin_cost: float = DEFAULT_FANIN_COST,
    group_cost: float = DEFAULT_GROUP_COST,
    keep_parallelism: int = DEFAULT_KEEP_PARALLELISM,
    next_cid: Optional[int] = None,
) -> Optional[Tuple[Tuple[int, ...], List[Tuple[int, Tuple[int, ...]]]]]:
    """Recompute the clustering of ``frontier`` (not-yet-dispatched
    cluster ids of ``plan``) under corrected member costs.

    Builds the frontier *member* subgraph — deps outside the frontier are
    already satisfied (a PENDING/READY cluster's external producers are
    all completed or in flight) and drop out — rescales each member's
    cost through ``cost_of(node)`` (the CostModel's profile correction),
    and runs the ordinary :func:`fuse` pass over it with the calibrated
    gates.  Completed/in-flight clusters are never touched: they are
    simply not in ``frontier``.

    Returns ``(retired, new_clusters)`` where ``retired`` is the sorted
    tuple of replaced frontier cids and ``new_clusters`` the replacement
    ``(cid, member_tids)`` list in cluster-topo order, with fresh ids
    starting at ``next_cid`` — or ``None`` when re-fusion reproduces the
    existing partition (nothing to do).  The result is exactly what the
    run log journals: :func:`splice_plan` applies it both live and on
    ``--resume`` replay.
    """
    graph = plan.graph
    frontier = sorted(frontier)
    old_parts = {frozenset(plan.members[c]) for c in frontier}
    member_ids = sorted(m for c in frontier for m in plan.members[c])
    mset = set(member_ids)
    sub = TaskGraph()
    for m in member_ids:
        n = graph.nodes[m]
        sub.nodes[m] = dataclasses.replace(
            n,
            deps=tuple(d for d in n.deps if d in mset),
            token_deps=tuple(d for d in n.token_deps if d in mset),
            cost=float(cost_of(n)) if cost_of is not None else n.cost,
            meta=dict(n.meta),
        )
    sub._next_id = member_ids[-1] + 1 if member_ids else 0
    sub.outputs = [m for m in member_ids if m in set(graph.outputs)]
    subplan = fuse(sub, spec, fanin_cost=fanin_cost, group_cost=group_cost,
                   keep_parallelism=keep_parallelism)
    new_parts = {frozenset(ms) for ms in subplan.members.values()}
    if new_parts == old_parts:
        return None
    if next_cid is None:
        next_cid = max(plan.cgraph.nodes, default=-1) + 1
    # sub-plan cids are topo-numbered (identity sub-plans use member tids,
    # also topo), so enumerating them sorted keeps new ids topo-ordered —
    # a new cluster's id is always greater than its new-cluster deps'
    new_clusters = [(next_cid + i, tuple(subplan.members[c]))
                    for i, c in enumerate(sorted(subplan.members))]
    return tuple(frontier), new_clusters


def splice_plan(plan: FusedPlan, retired: Iterable[int],
                new_clusters: List[Tuple[int, Tuple[int, ...]]],
                ) -> Dict[int, int]:
    """Apply one re-fusion decision to ``plan`` **in place**.

    Deterministic plan surgery over the output of
    :func:`refuse_frontier` (or a journaled copy of it): drop the retired
    cluster ids, install the new memberships, and rebuild every derived
    map — ``cluster_of``, per-cluster ``outputs``/``ext_deps``, the
    ``consumers`` index, and the cluster-level graph nodes — using
    exactly the :func:`_build_plan` rules, so a resumed driver replaying
    the journal reconstructs a bit-identical plan.

    Returns ``{value_tid: consumer_count_delta}`` for every externally
    visible value whose consuming-cluster set changed; the executor folds
    these into the object store's ``consumers_left`` refcounts (a merge
    of two consumers of the same value means one fewer pending read).
    """
    graph = plan.graph
    cgraph = plan.cgraph
    retired = set(retired)
    old_cons_len: Dict[int, int] = {}
    for c in retired:
        for v in plan.ext_deps.get(c, ()):
            old_cons_len.setdefault(v, len(plan.consumers.get(v, ())))
        plan.members.pop(c, None)
        plan.outputs.pop(c, None)
        plan.ext_deps.pop(c, None)
        plan._outset.pop(c, None)
        cgraph.nodes.pop(c, None)
    for cid, ms in new_clusters:
        plan.members[cid] = tuple(ms)
        for m in ms:
            plan.cluster_of[m] = cid
    succ = graph.successors()
    out_set = set(graph.outputs)
    for cid, ms in new_clusters:
        nodes = [graph.nodes[m] for m in ms]
        deps: Set[int] = set()
        token_deps: Set[int] = set()
        evals: Set[int] = set()
        for n in nodes:
            for d in n.deps:
                if plan.cluster_of[d] != cid:
                    deps.add(plan.cluster_of[d])
                    evals.add(d)
            for d in n.token_deps:
                if plan.cluster_of[d] != cid:
                    token_deps.add(plan.cluster_of[d])
                    evals.add(d)
        token_deps -= deps
        outs = tuple(m for m in ms
                     if m in out_set
                     or any(plan.cluster_of[s] != cid for s in succ[m]))
        plan.outputs[cid] = outs
        plan._outset[cid] = set(outs)
        plan.ext_deps[cid] = tuple(sorted(evals))
        for v in evals:
            old_cons_len.setdefault(v, len(plan.consumers.get(v, ())))
        name = (nodes[0].name if len(nodes) == 1
                else f"{nodes[0].name}+{len(nodes) - 1}")
        kind = nodes[0].kind if len(nodes) == 1 else TaskKind.PURE
        cgraph.nodes[cid] = TaskNode(
            tid=cid, name=name, fn=None, args=(), kwargs={}, kind=kind,
            deps=tuple(sorted(deps)),
            token_deps=tuple(sorted(token_deps)),
            cost=sum(n.cost for n in nodes),
            out_bytes=sum(graph.nodes[m].out_bytes for m in outs),
            meta={"members": tuple(ms)},
        )
        cgraph._next_id = max(cgraph._next_id, cid + 1)
    # consumer index: surviving old consumers + the new clusters, by cid
    delta: Dict[int, int] = {}
    for v, old_len in old_cons_len.items():
        cons = [c for c in plan.consumers.get(v, ()) if c not in retired]
        cons += [cid for cid, _ in new_clusters if v in plan.ext_deps[cid]]
        cons = sorted(set(cons))
        plan.consumers[v] = tuple(cons)
        if len(cons) != old_len:
            delta[v] = len(cons) - old_len
    # cluster-graph output marks follow the membership
    seen = {c for c in cgraph.outputs if c not in retired}
    cgraph.outputs = [c for c in cgraph.outputs if c not in retired]
    for o in graph.outputs:
        c = plan.cluster_of[o]
        if c not in seen:
            seen.add(c)
            cgraph.outputs.append(c)
    return delta
