"""Executors that really run a TaskGraph.

* :class:`Executor` — the protocol every runtime backend satisfies:
  ``run(graph, inputs) -> {tid: value}`` plus ``stats``/``wall_time``
  introspection.  Backends must be *oracle-faithful*: tasks are pure, so
  results have to be bit-identical to :func:`execute_sequential`.
* :func:`execute_sequential` — single-thread topo-order oracle (the paper's
  "single-thread baseline"); every parallel executor must match it exactly
  because tasks are pure.
* :class:`ThreadedExecutor` — worker threads with per-worker deques and work
  stealing (the paper's runtime, on one host).  Python threads still give real
  speedups here because task payloads release the GIL inside jitted JAX
  compute.
* :class:`repro.cluster.ClusterExecutor` — the multi-process backend (OS
  process workers, driver-side object store, lineage recovery); select it
  with ``run_graph(..., backend="process")``.
* Failure injection hooks drive the lineage-recovery tests.
"""
from __future__ import annotations

import threading
import time as _time
from typing import (Any, Callable, Dict, List, Optional, Protocol, Set,
                    runtime_checkable)

from .graph import TaskGraph
from .tracing import substitute_refs
from .lineage import recovery_plan


@runtime_checkable
class Executor(Protocol):
    """What the launchers/benchmarks require of a runtime backend.

    ``stats`` holds backend-specific counters (every backend reports at
    least ``recomputed``); ``wall_time`` is the last run's duration.
    """

    stats: Dict[str, int]
    wall_time: float

    def run(self, graph: TaskGraph,
            inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
        ...


class TaskFailed(RuntimeError):
    def __init__(self, tid: int, name: str, cause: BaseException):
        super().__init__(f"task {name}#{tid} failed: {cause!r}")
        self.tid = tid
        self.cause = cause


class MissingInput(KeyError):
    """A ``placeholder`` input was not provided — a caller error, raised
    as-is (never wrapped in TaskFailed)."""


def _run_node(graph: TaskGraph, tid: int, results: Dict[int, Any],
              inputs: Optional[Dict[str, Any]] = None) -> Any:
    node = graph.nodes[tid]
    if "input" in node.meta:
        if inputs is None or node.meta["input"] not in inputs:
            raise MissingInput(
                f"graph input {node.meta['input']!r} not provided")
        return inputs[node.meta["input"]]
    args = substitute_refs(node.args, results)
    kwargs = substitute_refs(node.kwargs, results)
    return node.fn(*args, **kwargs)


def execute_sequential(graph: TaskGraph,
                       inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
    """Oracle executor: topo order, one thread. Returns {tid: value}."""
    graph.validate()
    results: Dict[int, Any] = {}
    for tid in graph.topo_order():
        try:
            results[tid] = _run_node(graph, tid, results, inputs)
        except MissingInput:
            raise
        except Exception as e:
            raise TaskFailed(tid, graph.nodes[tid].name, e) from e
    return results


# threads share one address space, so the data-plane counters every backend
# reports (see ClusterExecutor) are structurally zero here — "zero-copy"
# is the hardware default in-process
_THREAD_STATS = {"steals": 0, "recomputed": 0, "bytes_moved": 0,
                 "transfers_direct": 0, "transfers_driver": 0}


class ThreadedExecutor:
    """Work-stealing thread-pool executor.

    Scheduling follows the paper: a task becomes *ready* the moment its last
    input materializes; the finishing worker pushes it onto its own deque
    (locality), idle workers steal from the most-loaded victim.  Effect
    (token) edges are ordinary dependencies, so ``IO`` tasks execute in
    program order.

    ``fail_task(worker, tid) -> bool`` optionally simulates losing the result
    of an execution (at most once per task) to exercise lineage recovery.
    """

    def __init__(self, n_workers: int = 4,
                 fail_task: Optional[Callable[[int, int], bool]] = None):
        if n_workers < 1:
            raise ValueError("n_workers >= 1")
        self.n_workers = n_workers
        self.fail_task = fail_task
        self.stats = dict(_THREAD_STATS)
        self.wall_time = 0.0

    def run(self, graph: TaskGraph,
            inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
        graph.validate()
        succ = graph.successors()
        n_total = len(graph.nodes)
        rank = graph.critical_path_rank()

        lock = threading.Lock()
        cv = threading.Condition(lock)
        results: Dict[int, Any] = {}
        deques: List[List[int]] = [[] for _ in range(self.n_workers)]
        queued: Set[int] = set()      # in some deque
        inflight: Set[int] = set()
        lost: Set[int] = set()        # tids already failure-injected once
        errors: List[BaseException] = []
        stats = self.stats = dict(_THREAD_STATS)

        def ready_p(tid: int) -> bool:
            return (tid not in results and tid not in inflight
                    and tid not in queued
                    and all(d in results for d in graph.nodes[tid].all_deps))

        def enqueue(w: int, tid: int) -> None:
            queued.add(tid)
            deques[w].append(tid)

        sources = sorted((t for t in graph.nodes
                          if not graph.nodes[t].all_deps),
                         key=lambda t: -rank[t])
        for i, t in enumerate(sources):
            enqueue(i % self.n_workers, t)

        def grab(w: int) -> Optional[int]:
            """Pop own deque (LIFO) else steal (FIFO from most-loaded)."""
            if deques[w]:
                tid = deques[w].pop()
            else:
                victim = max((v for v in range(self.n_workers)
                              if v != w and deques[v]),
                             key=lambda v: len(deques[v]), default=None)
                if victim is None:
                    return None
                stats["steals"] += 1
                tid = deques[victim].pop(0)
            queued.discard(tid)
            return tid

        def worker(w: int) -> None:
            while True:
                with cv:
                    while True:
                        if errors or len(results) >= n_total:
                            return
                        tid = grab(w)
                        if tid is not None:
                            break
                        cv.wait(timeout=0.02)
                    inflight.add(tid)
                    res_view = dict(results)
                try:
                    value = _run_node(graph, tid, res_view, inputs)
                    failed = bool(self.fail_task and tid not in lost
                                  and self.fail_task(w, tid))
                except BaseException as e:
                    with cv:
                        errors.append(TaskFailed(tid, graph.nodes[tid].name, e))
                        cv.notify_all()
                    return
                with cv:
                    inflight.discard(tid)
                    if failed:
                        lost.add(tid)
                        # the worker "lost" this result (and conceptually the
                        # ones it held); recompute the minimal lineage set
                        plan = recovery_plan(graph, {tid}, set(results))
                        stats["recomputed"] += len(plan)
                        for t in plan:
                            results.pop(t, None)
                            queued.discard(t)
                        for t in sorted(plan, key=lambda t: -rank[t]):
                            if ready_p(t):
                                enqueue(w, t)
                    else:
                        results[tid] = value
                        for s in sorted(succ[tid], key=lambda t: -rank[t]):
                            if ready_p(s):
                                enqueue(w, s)   # locality: run where produced
                    cv.notify_all()
                    if len(results) >= n_total:
                        return

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(self.n_workers)]
        t0 = _time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        self.wall_time = _time.perf_counter() - t0
        if errors:
            raise errors[0]
        if len(results) != n_total:
            raise RuntimeError(
                f"executor finished with {n_total - len(results)} tasks missing")
        return results


def make_executor(backend: str, n_workers: int, config=None,
                  **kw) -> Executor:
    """Factory over runtime backends: ``thread`` | ``process``.

    ``config`` is a :class:`repro.ClusterConfig` — the one object carrying
    every process-backend knob; the loose keyword arguments are the
    deprecated legacy spelling (still honored for one release, see
    ``repro/config.py``).  Cluster-only options (``transport``,
    ``channel``, ``connect``, ... — or a ``config`` at all) passed to the
    thread backend are named errors here, not ``TypeError`` shrapnel from
    ``ThreadedExecutor.__init__``: the thread backend runs in one address
    space and has no data or control plane to select.
    """
    if backend == "thread":
        cluster_only = sorted(
            k for k in ("transport", "channel", "connect", "workers",
                        "start_method", "shm_threshold", "token",
                        "speculate_after", "fuse", "collectives",
                        "checkpoint_dir",
                        "checkpoint_interval", "resume", "rejoin_timeout",
                        "rejoin_window", "fail_driver")
            if k in kw)
        if config is not None:
            cluster_only = ["config"] + cluster_only
        if cluster_only:
            raise ValueError(
                f"option(s) {cluster_only} apply only to the process "
                f"backend (backend='process'); the thread backend shares "
                f"one address space")
        return ThreadedExecutor(n_workers, **kw)
    if backend == "process":
        from repro.cluster import ClusterExecutor   # deferred: no cycle
        return ClusterExecutor(n_workers, config=config, **kw)
    raise ValueError(f"unknown backend {backend!r} "
                     "(expected 'thread' or 'process')")


def run_graph(graph: TaskGraph, n_workers: int = 1,
              inputs: Optional[Dict[str, Any]] = None,
              backend: str = "thread", with_report: bool = False,
              config=None, connect: Optional[str] = None,
              token: Optional[str] = None, **kw):
    """Run ``graph`` on the selected backend.

    ``connect="host:port"`` (with the default backend) submits the graph
    to a resident multi-tenant gateway at that address instead of running
    locally — the one-line change from local execution to a shared pool
    (``backend="process"`` keeps the historical meaning: the address the
    driver *binds* for dialing workers).  ``config`` is a
    :class:`repro.ClusterConfig` for the process backend.

    ``with_report=True`` returns ``(results, report)`` where ``report``
    carries the backend, worker count, wall time, and the backend's stats
    counters — including the data-plane fields ``bytes_moved`` /
    ``transfers_direct`` / ``transfers_driver``, and, for the process
    backend, the speculation fields ``n_speculative`` /
    ``speculative_wins`` / ``speculative_wasted_s`` (populated when
    ``speculate_after`` is set) plus the graph-compilation/control-plane
    fields ``n_clusters`` / ``tasks_fused`` / ``control_msgs`` /
    ``control_frames`` / ``dispatch_overhead_s`` (the fusion win,
    observable directly: pass ``fuse="auto"`` and watch ``control_msgs``
    and ``dispatch_overhead_s`` collapse while results stay bit-identical),
    and the adaptive-loop fields ``cost_unit_s`` / ``dispatch_cost_s`` /
    ``refusions`` / ``refusions_replayed`` / ``replan_triggers`` /
    ``adaptive_skew`` / ``adaptive_speculate_after`` (populated under
    ``adaptive="auto"`` — docs/adaptive.md).
    """
    if connect is not None and backend != "process":
        # gateway session: trace locally, execute on the shared pool
        from repro.gateway.client import connect as _gw_connect
        with _gw_connect(connect, token=token) as client:
            fut = client.submit(graph, inputs, config=config)
            results = fut.result()
        if with_report:
            return results, {"backend": "gateway", "n_workers": n_workers,
                             "wall_time": fut.wall_time,
                             "stats": dict(fut.stats or {})}
        return results
    if token is not None:
        kw["token"] = token
    if n_workers == 1 and backend == "thread":
        t0 = _time.perf_counter()
        results = execute_sequential(graph, inputs)
        if with_report:
            return results, {"backend": "sequential", "n_workers": 1,
                             "wall_time": _time.perf_counter() - t0,
                             "stats": {}}
        return results
    if connect is not None:
        kw["connect"] = connect
    ex = make_executor(backend, n_workers, config=config, **kw)
    results = ex.run(graph, inputs)
    if with_report:
        report = {"backend": backend, "n_workers": n_workers,
                  "wall_time": ex.wall_time, "stats": dict(ex.stats)}
        transport = getattr(ex, "transport_used", None)
        if transport is not None:
            report["transport"] = transport
        return results, report
    return results


def output_values(graph: TaskGraph, results: Dict[int, Any]) -> List[Any]:
    return [results[t] for t in graph.outputs]
