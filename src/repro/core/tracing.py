"""Driver tracing — the JAX replacement for the paper's shallow source parser.

The paper parses the user's ``main`` to recover the call-level dependency
graph.  We instead *run* the driver once with future-like :class:`TaskRef`
placeholders: every ``@task``-decorated call appends a DAG node and returns a
ref; plain Python glue (tuple packing, control flow on literals) runs
normally.  This is strictly more robust than shallow parsing — the paper's
own "future work" — while preserving its interface: the user marks the
driver, nothing else.

Effect ordering is the paper's RealWorld rule: each ``@io_task`` call depends
on the previous effectful call through a token edge.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .graph import TaskGraph, TaskKind
from . import purity

_STATE = threading.local()


def _current_trace() -> Optional["Trace"]:
    return getattr(_STATE, "trace", None)


class TaskRef:
    """Future-like placeholder for the value produced by a task."""

    __slots__ = ("trace", "tid", "length")

    def __init__(self, trace: "Trace", tid: int, length: Optional[int] = None):
        self.trace = trace
        self.tid = tid
        self.length = length  # known tuple-length of the output, if declared

    def __getitem__(self, idx: int) -> "TaskRef":
        if not isinstance(idx, int):
            raise TypeError("TaskRef only supports integer projection")
        return self.trace.add_projection(self, idx)

    def __iter__(self):
        if self.length is None:
            raise TypeError(
                "cannot unpack a TaskRef of unknown arity; declare "
                "@task(n_outputs=k) to enable `a, b = f(...)`")
        return (self[i] for i in range(self.length))

    def __repr__(self) -> str:
        return f"TaskRef<{self.trace.graph.nodes[self.tid].name}#{self.tid}>"

    # Refs must never silently leak into numeric Python — fail loudly.
    def __bool__(self):
        raise TypeError("TaskRef cannot be used in Python control flow; "
                        "branch on literals or move the branch inside a task")


def _find_refs(obj: Any, acc: List[TaskRef]) -> None:
    if isinstance(obj, TaskRef):
        acc.append(obj)
    elif isinstance(obj, (list, tuple)):
        for o in obj:
            _find_refs(o, acc)
    elif isinstance(obj, dict):
        for o in obj.values():
            _find_refs(o, acc)


class _Project:
    """Tuple-element projection node body.  A class (not a lambda) so traced
    graphs stay picklable for spawn-based cluster workers."""

    __slots__ = ("idx",)

    def __init__(self, idx: int):
        self.idx = idx

    def __call__(self, t):
        return t[self.idx]


def _barrier_fn(*xs):
    """Barrier node body: identity on one value, tuple otherwise (picklable
    module-level function — see :class:`_Project`)."""
    return xs if len(xs) != 1 else xs[0]


class Trace:
    """Active tracing context; builds a :class:`TaskGraph`."""

    def __init__(self) -> None:
        self.graph = TaskGraph()
        self._last_token_tid: Optional[int] = None

    # -- context manager ----------------------------------------------------
    def __enter__(self) -> "Trace":
        if _current_trace() is not None:
            raise RuntimeError("traces do not nest; one driver at a time")
        _STATE.trace = self
        return self

    def __exit__(self, *exc) -> None:
        _STATE.trace = None

    # -- node creation ------------------------------------------------------
    def add_call(self, fn: Callable, name: str, args: Tuple, kwargs: Dict,
                 pure: bool, cost: float, out_bytes: int,
                 n_outputs: Optional[int], meta: Optional[dict] = None) -> TaskRef:
        refs: List[TaskRef] = []
        _find_refs(args, refs)
        _find_refs(kwargs, refs)
        for r in refs:
            if r.trace is not self:
                raise RuntimeError("TaskRef from a different trace")
        deps = tuple(dict.fromkeys(r.tid for r in refs))
        token_deps: Tuple[int, ...] = ()
        kind = TaskKind.PURE
        if not pure:
            kind = TaskKind.EFFECTFUL
            if self._last_token_tid is not None:
                token_deps = (self._last_token_tid,)
        tid = self.graph.add_node(
            name=name, fn=fn, args=args, kwargs=kwargs, kind=kind,
            deps=deps, token_deps=token_deps, cost=cost, out_bytes=out_bytes,
            meta=meta,
        )
        if not pure:
            self._last_token_tid = tid
        return TaskRef(self, tid, length=n_outputs)

    def add_projection(self, ref: TaskRef, idx: int) -> TaskRef:
        tid = self.graph.add_node(
            name=f"π{idx}", fn=_Project(idx),
            args=(ref,), kwargs={}, kind=TaskKind.PROJECTION,
            deps=(ref.tid,), token_deps=(), cost=0.0, out_bytes=0,
        )
        return TaskRef(self, tid)

    def add_barrier(self, refs: Sequence[TaskRef], name: str = "checkpoint") -> TaskRef:
        """Materialization barrier — lineage recovery never recomputes past it."""
        deps = tuple(dict.fromkeys(r.tid for r in refs))
        tid = self.graph.add_node(
            name=name, fn=_barrier_fn,
            args=tuple(refs), kwargs={}, kind=TaskKind.BARRIER,
            deps=deps, token_deps=(), cost=0.0, out_bytes=0,
        )
        return TaskRef(self, tid)


# --------------------------------------------------------------------------
# decorators
# --------------------------------------------------------------------------

def task(fn: Optional[Callable] = None, *, cost: Any = 1.0, out_bytes: Any = 0,
         name: Optional[str] = None, n_outputs: Optional[int] = None,
         pure: bool = True, meta: Optional[dict] = None):
    """Mark ``fn`` as a schedulable unit.

    ``cost``/``out_bytes`` may be literals or callables of the call's
    (literal) arguments — used by the scheduler's cost model and the
    work-stealing policy.  Outside a trace the function runs eagerly, so
    decorated code keeps working as ordinary Python.
    """
    def wrap(f: Callable):
        purity.declare(f, pure)

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            tr = _current_trace()
            if tr is None:
                return f(*args, **kwargs)
            c = cost(*args, **kwargs) if callable(cost) else float(cost)
            b = out_bytes(*args, **kwargs) if callable(out_bytes) else int(out_bytes)
            return tr.add_call(f, name or f.__name__, args, kwargs,
                               pure=pure, cost=c, out_bytes=b,
                               n_outputs=n_outputs, meta=meta)

        wrapper.__wrapped_task__ = f
        wrapper.__task_pure__ = pure
        return wrapper

    return wrap(fn) if fn is not None else wrap


def io_task(fn: Optional[Callable] = None, **kw):
    """``IO``-typed task: ordered through the RealWorld token chain."""
    kw["pure"] = False
    return task(fn, **kw) if fn is not None else task(**kw)


def checkpoint_barrier(*refs: TaskRef, name: str = "checkpoint") -> TaskRef:
    tr = _current_trace()
    if tr is None:
        raise RuntimeError("checkpoint_barrier only makes sense inside trace()")
    return tr.add_barrier(refs, name=name)


def placeholder(name: str, *, out_bytes: int = 0) -> TaskRef:
    """Graph input: a zero-cost source node resolved from the executor's
    ``inputs`` dict at run time (the driver's arguments, in paper terms)."""
    tr = _current_trace()
    if tr is None:
        raise RuntimeError("placeholder only makes sense inside trace()")
    return tr.add_call(
        fn=None, name=f"input:{name}", args=(), kwargs={}, pure=True,
        cost=0.0, out_bytes=out_bytes, n_outputs=None, meta={"input": name})


# --------------------------------------------------------------------------
# collective primitives (repro.core.collectives holds the machinery; the
# imports are lazy because collectives.py imports helpers from this module)
# --------------------------------------------------------------------------

def _collective_trace() -> "Trace":
    tr = _current_trace()
    if tr is None:
        raise RuntimeError("collectives only make sense inside trace(); "
                           "outside a trace there is no graph to shape")
    return tr


def all_reduce(refs: Sequence[TaskRef], op="sum", *, arity: int = None,
               cost: float = 1.0, out_bytes: int = 0,
               name: str = None) -> TaskRef:
    """Reduce ``refs`` to one value with ``op`` (``"sum"``/``"max"``/
    ``"min"``/``"concat"`` or a picklable binary callable) along a
    deterministic combine tree.  The tree's bracketing is part of the
    value (float combines are not associative), so every backend —
    sequential oracle included — computes the identical bits.  Lowered to
    staged tree hops by :func:`repro.core.collectives.lower_collectives`."""
    from .collectives import DEFAULT_ARITY, add_all_reduce
    tr = _collective_trace()
    tid = add_all_reduce(tr.graph, [r.tid for r in refs], op,
                         arity=arity or DEFAULT_ARITY, name=name,
                         cost=cost, out_bytes=out_bytes)
    return TaskRef(tr, tid)


def gather(refs: Sequence[TaskRef], *, arity: int = None, cost: float = 1.0,
           out_bytes: int = 0, name: str = None) -> TaskRef:
    """Collect ``refs`` into one tuple (in order) via a concatenation
    tree — the many-to-one shape a wide fan-in consumer pays N
    point-to-point edges for today.  Unpackable: ``a, b, c = gather(...)``."""
    from .collectives import DEFAULT_ARITY, add_gather
    tr = _collective_trace()
    tid = add_gather(tr.graph, [r.tid for r in refs],
                     arity=arity or DEFAULT_ARITY, name=name,
                     cost=cost, out_bytes=out_bytes)
    return TaskRef(tr, tid, length=len(refs))


def broadcast(ref: TaskRef, *, arity: int = None, cost: float = 0.0,
              out_bytes: int = 0, name: str = None) -> TaskRef:
    """One-to-many replication: consumers of the returned ref are fanned
    out across a copy tree at lowering time (<= ``arity`` readers per
    copy), so no single worker serves every consumer of a hot value."""
    from .collectives import DEFAULT_ARITY, add_broadcast
    tr = _collective_trace()
    tid = add_broadcast(tr.graph, ref.tid, arity=arity or DEFAULT_ARITY,
                        name=name, cost=cost, out_bytes=out_bytes)
    return TaskRef(tr, tid)


def scatter(ref: TaskRef, n: int, *, arity: int = None, cost: float = 0.0,
            out_bytes: int = 0, name: str = None) -> TaskRef:
    """Split ``ref`` into ``n`` contiguous leading-axis chunks:
    ``parts = scatter(x, 4)`` then ``parts[i]`` (or unpack).  Lowering
    rewrites each projection into a direct chunk read off the source, so
    consumers pull their slice, never the whole value."""
    from .collectives import DEFAULT_ARITY, add_scatter
    tr = _collective_trace()
    tid = add_scatter(tr.graph, ref.tid, n, arity=arity or DEFAULT_ARITY,
                      name=name, cost=cost, out_bytes=out_bytes)
    return TaskRef(tr, tid, length=n)


# --------------------------------------------------------------------------
# ref substitution (shared by every executor)
# --------------------------------------------------------------------------

class RemappedRef:
    """A bare task-id reference used after graph transforms re-assign ids."""

    __slots__ = ("tid",)

    def __init__(self, tid: int):
        self.tid = tid

    def __repr__(self):
        return f"RemappedRef<{self.tid}>"


def substitute_refs(obj: Any, table: Dict[int, Any]) -> Any:
    """Replace every (Remapped)TaskRef in ``obj`` with ``table[ref.tid]``."""
    if isinstance(obj, (TaskRef, RemappedRef)):
        return table[obj.tid]
    if isinstance(obj, tuple):
        return tuple(substitute_refs(o, table) for o in obj)
    if isinstance(obj, list):
        return [substitute_refs(o, table) for o in obj]
    if isinstance(obj, dict):
        return {k: substitute_refs(v, table) for k, v in obj.items()}
    return obj


def _remap_arg_refs(obj: Any, old2new: Dict[int, int]) -> Any:
    if isinstance(obj, (TaskRef, RemappedRef)):
        return RemappedRef(old2new[obj.tid])
    if isinstance(obj, tuple):
        return tuple(_remap_arg_refs(o, old2new) for o in obj)
    if isinstance(obj, list):
        return [_remap_arg_refs(o, old2new) for o in obj]
    if isinstance(obj, dict):
        return {k: _remap_arg_refs(v, old2new) for k, v in obj.items()}
    return obj


def offset_graph(graph: TaskGraph, base: int,
                 input_ns: Optional[str] = None) -> TaskGraph:
    """Rebase every task id of ``graph`` by ``+base`` into a fresh graph.

    The gateway's resident executor runs many tenants' graphs inside ONE
    growing union graph; each admitted job gets a private, non-overlapping
    id range ``[base, base + len(graph))`` so that the object store, the
    lineage index and the run log never confuse two tenants' values.
    ``input_ns`` (e.g. ``"j3/"``) prefixes every placeholder name the same
    way, namespacing the ``inputs`` dict per job.

    The offset preserves topo order (a uniform shift keeps ``dep < tid``),
    so the result validates iff the input did.  Nodes are shared, not
    copied, except for ``meta`` when the input name is rewritten.
    """
    old2new = {t: t + base for t in graph.nodes}
    out = TaskGraph()
    for tid in sorted(graph.nodes):
        n = graph.nodes[tid]
        meta = n.meta
        if input_ns and "input" in meta:
            meta = dict(meta)
            meta["input"] = input_ns + meta["input"]
        out.nodes[tid + base] = dataclasses.replace(
            n,
            tid=tid + base,
            args=_remap_arg_refs(n.args, old2new),
            kwargs=_remap_arg_refs(n.kwargs, old2new),
            deps=tuple(d + base for d in n.deps),
            token_deps=tuple(d + base for d in n.token_deps),
            meta=meta,
        )
    out.outputs = [o + base for o in graph.outputs]
    out._next_id = base + (max(graph.nodes) + 1 if graph.nodes else 0)
    return out


# --------------------------------------------------------------------------
# trace entry point + granularity fusion
# --------------------------------------------------------------------------

def trace(driver: Callable, *args, fuse_below: float = 0.0, **kwargs):
    """Run ``driver`` under tracing; return ``(graph, outputs)``.

    ``outputs`` mirrors the driver's return structure (TaskRefs inside).
    ``fuse_below`` fuses linear chains of pure tasks whose cost is below the
    threshold (the paper's "user-specified granularity" future-work knob).
    """
    with Trace() as tr:
        out = driver(*args, **kwargs)
        refs: List[TaskRef] = []
        _find_refs(out, refs)
        for r in refs:
            tr.graph.mark_output(r.tid)
    graph = tr.graph
    if fuse_below > 0.0:
        graph = fuse_cheap_chains(graph, fuse_below)
    graph.validate()
    return graph, out


def fuse_cheap_chains(graph: TaskGraph, threshold: float) -> TaskGraph:
    """Granularity control: fuse linear chains ``a -> b`` when both are pure
    with cost < threshold, ``a`` has a single consumer and ``b`` a single
    value-dependency.  Returns a NEW graph (ids re-assigned, topo order
    preserved); fusion composes the Python callables so executors need no
    changes.
    """
    succ = graph.successors()
    chains: Dict[int, List[int]] = {}   # chain head -> members (exec order)
    absorbed: Dict[int, int] = {}       # member tid -> chain head

    for tid in graph.topo_order():
        node = graph.nodes[tid]
        if (node.kind is TaskKind.PURE and node.cost < threshold
                and len(node.deps) == 1 and not node.token_deps):
            head = absorbed.get(node.deps[0], node.deps[0])
            hnode = graph.nodes[head]
            if (hnode.kind is TaskKind.PURE and hnode.cost < threshold
                    and len(succ[node.deps[0]]) == 1
                    and node.deps[0] not in graph.outputs):
                chains.setdefault(head, [head]).append(tid)
                absorbed[tid] = head

    new = TaskGraph()
    old2new: Dict[int, int] = {}
    for tid in graph.topo_order():
        if tid in absorbed:
            continue   # id assigned when its chain head is emitted
        members = chains.get(tid, [tid])
        nodes = [graph.nodes[m] for m in members]
        head = nodes[0]
        if len(nodes) == 1:
            ntid = new.add_node(
                head.name, head.fn,
                _remap_arg_refs(head.args, old2new),
                _remap_arg_refs(head.kwargs, old2new),
                head.kind,
                deps=tuple(old2new[d] for d in head.deps),
                token_deps=tuple(old2new[d] for d in head.token_deps),
                cost=head.cost, out_bytes=head.out_bytes, meta=head.meta)
        else:
            tail = tuple(nodes[1:])

            def fused(*args, _head=head, _tail=tail, **kwargs):
                val = _head.fn(*args, **kwargs)
                for nd in _tail:
                    # each tail member's only refs point at its predecessor
                    tbl = {nd.deps[0]: val}
                    val = nd.fn(*substitute_refs(nd.args, tbl),
                                **substitute_refs(nd.kwargs, tbl))
                return val

            ntid = new.add_node(
                "+".join(n.name for n in nodes), fused,
                _remap_arg_refs(head.args, old2new),
                _remap_arg_refs(head.kwargs, old2new),
                TaskKind.PURE,
                deps=tuple(old2new[d] for d in head.deps),
                token_deps=(),
                cost=sum(n.cost for n in nodes),
                out_bytes=nodes[-1].out_bytes, meta=head.meta)
        for m in members:
            old2new[m] = ntid
    for o in graph.outputs:
        new.mark_output(old2new[o])
    new.meta_old2new = old2new  # type: ignore[attr-defined]
    return new
