"""SPMD mesh executor — lowering a TaskGraph onto a TPU mesh.

The Cloud Haskell backend in the paper ships closures to workers.  On a TPU
pod the efficient equivalent is to lower the *entire* task graph into one
XLA program over the device mesh: each task body is inlined in topological
order, every intermediate gets a sharding constraint chosen by the placement
pass, and XLA's SPMD partitioner + latency-hiding scheduler take the role of
the per-task message passing.

This keeps the paper's semantics exactly: pure tasks may be reordered /
fused / overlapped by XLA (they commute), while token edges become real data
dependencies so effect order is preserved.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import ensure_partitionable_rng
from .graph import TaskGraph
from .tracing import substitute_refs
from .placement import ValueInfo, refine_placements, logical_to_spec, Rule


class MeshExecutor:
    """Compile a TaskGraph to a single pjit'd callable.

    ``value_info`` (optional) enables the greedy placement refinement; tasks
    without info run with unconstrained (XLA-chosen) layouts.  Graph inputs
    (``placeholder`` nodes) become function arguments with rule-table
    shardings.
    """

    def __init__(
        self,
        graph: TaskGraph,
        mesh: Mesh,
        rules: Sequence[Rule],
        *,
        value_info: Optional[Dict[int, ValueInfo]] = None,
        input_axes: Optional[Dict[str, tuple]] = None,
        donate_inputs: Sequence[str] = (),
    ) -> None:
        ensure_partitionable_rng()
        graph.validate()
        self.graph = graph
        self.mesh = mesh
        self.rules = list(rules)
        self.input_axes = dict(input_axes or {})
        self.donate_inputs = tuple(donate_inputs)
        if value_info:
            self.specs = refine_placements(graph, value_info, self.rules, mesh)
        else:
            self.specs = {}
        self._compiled: Optional[Callable] = None

    # ------------------------------------------------------------------
    def _build_fn(self) -> Callable:
        graph = self.graph
        order = graph.topo_order()
        specs = self.specs

        def run(inputs: Dict[str, Any]) -> List[Any]:
            results: Dict[int, Any] = {}
            for tid in order:
                node = graph.nodes[tid]
                if "input" in node.meta:
                    val = inputs[node.meta["input"]]
                else:
                    args = substitute_refs(node.args, results)
                    kwargs = substitute_refs(node.kwargs, results)
                    val = node.fn(*args, **kwargs)
                spec = specs.get(tid)
                if spec is not None and spec != P():
                    val = jax.lax.with_sharding_constraint(
                        val, NamedSharding(self.mesh, spec))
                results[tid] = val
            return [results[t] for t in graph.outputs]

        return run

    def input_sharding(self, name: str) -> NamedSharding:
        axes = self.input_axes.get(name, ())
        return NamedSharding(self.mesh,
                             logical_to_spec(axes, self.rules, self.mesh))

    # ------------------------------------------------------------------
    def compile(self, example_inputs: Dict[str, Any]):
        """AOT lower+compile; ``example_inputs`` may be ShapeDtypeStructs
        (dry-run) or concrete arrays."""
        run = self._build_fn()
        in_shardings = ({k: self.input_sharding(k) for k in example_inputs},)
        jitted = jax.jit(run, in_shardings=in_shardings)
        with self.mesh:
            lowered = jitted.lower(example_inputs)
            compiled = lowered.compile()
        self._lowered, self._compiled = lowered, compiled
        return compiled

    def __call__(self, inputs: Dict[str, Any]) -> List[Any]:
        if self._compiled is None:
            self.compile(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), inputs))
        with self.mesh:
            return self._compiled(inputs)

    # -- introspection used by the roofline benchmarks -------------------
    def cost_analysis(self) -> Dict[str, Any]:
        assert self._compiled is not None, "compile() first"
        from repro.compat import cost_analysis_dict
        return cost_analysis_dict(self._compiled)

    def memory_analysis(self):
        assert self._compiled is not None, "compile() first"
        return self._compiled.memory_analysis()

    def hlo_text(self) -> str:
        assert self._compiled is not None, "compile() first"
        return self._compiled.as_text()
