"""Lineage-based fault tolerance (the Spark/RDD idea the paper points at).

Because every non-``IO`` task is pure, a lost result can always be
reconstructed by re-running its lineage — the minimal set of ancestor tasks
whose results are also unavailable.  Checkpoint BARRIER nodes cut lineage:
anything materialized at a barrier is durable, so recovery never recomputes
past one.

Effectful tasks are NOT replayed blindly (re-running ``IO`` may duplicate a
side effect); :func:`recovery_plan` flags them so callers can substitute a
checkpointed value or re-run only idempotent ones (``meta={'idempotent': True}``).
"""
from __future__ import annotations

from typing import Dict, Iterable, Set, Tuple

from .graph import TaskGraph, TaskKind


class NonIdempotentReplay(RuntimeError):
    pass


def recovery_plan(
    graph: TaskGraph,
    lost: Iterable[int],
    available: Set[int],
    *,
    allow_effect_replay: bool = True,
) -> Set[int]:
    """Minimal recompute set to rebuild ``lost`` given ``available`` results.

    Walks lineage upward from each lost task, stopping at results that are
    still available (or durable barriers).  Raises
    :class:`NonIdempotentReplay` if an effectful, non-idempotent task would
    have to be replayed and ``allow_effect_replay`` is False.
    """
    plan: Set[int] = set()
    stack = [t for t in lost if t not in available]
    while stack:
        tid = stack.pop()
        if tid in plan:
            continue
        node = graph.nodes[tid]
        if node.kind is TaskKind.EFFECTFUL and not allow_effect_replay:
            if not node.meta.get("idempotent", False):
                raise NonIdempotentReplay(
                    f"recovery would replay non-idempotent IO task "
                    f"{node.name}#{tid}; checkpoint its output instead")
        plan.add(tid)
        for d in node.all_deps:
            if d not in available and d not in plan:
                stack.append(d)
    return plan


def recovery_plan_clusters(
    fused_plan,
    needed: Iterable[int],
    available: Set[int],
) -> Set[int]:
    """Super-task-granularity recovery: the minimal set of *clusters* to
    re-run so every ``needed`` member value (and every external input a
    re-run cluster will read) exists again.

    ``fused_plan`` is a :class:`repro.core.fusion.FusedPlan`;
    ``needed``/``available`` are member-value tids, exactly as in
    :func:`recovery_plan`.  Walks the cluster DAG through each re-run
    cluster's **external** inputs — intra-cluster values are rebuilt by
    the cluster's own execution and never enter the walk.  For the
    identity plan this degenerates to :func:`recovery_plan` (one cluster
    per task, external inputs == ``all_deps``), which is what keeps
    ``--fuse off`` recovery bit-compatible.

    Collective trees get subtree-bounded recovery for free: a lowered
    stage node (:func:`repro.core.collectives.lower_collectives`) is
    always its own singleton cluster, so losing a mid-tree aggregator
    replays that stage plus whichever of its inputs also died — never
    the sibling subtrees, whose partials are alive on other workers
    (``repro.core.collectives.collective_stages`` enumerates a root's
    stage set; tests assert the plan stays inside it).
    """
    plan: Set[int] = set()
    stack = [fused_plan.cluster_of[v] for v in needed if v not in available]
    while stack:
        cid = stack.pop()
        if cid in plan:
            continue
        plan.add(cid)
        for v in fused_plan.ext_deps[cid]:
            pc = fused_plan.cluster_of[v]
            if v not in available and pc not in plan:
                stack.append(pc)
    return plan


def phantom_recovery_cost(
    fused_plan,
    suspect_values: Iterable[int],
    available: Set[int],
) -> Set[int]:
    """Clusters a *premature* death verdict would needlessly re-run.

    A partitioned-but-alive worker's values are all still there — just
    unreachable until the partition heals.  Declaring it dead anyway
    treats ``suspect_values`` (everything whose only copy it holds) as
    lost and replays their lineage.  This is the waste term the
    executor's ``suspect_grace`` window exists to avoid, and the cost a
    grace policy search (:func:`repro.core.simulator.search_suspect_grace`)
    weighs against the idle time of waiting out a worker that really is
    dead."""
    suspect = set(suspect_values)
    return recovery_plan_clusters(fused_plan, suspect,
                                  set(available) - suspect)


def outage_recovery(
    fused_plan,
    graph: TaskGraph,
    claimed_done: Set[int],
    available: Set[int],
    outputs_only: bool = False,
) -> Tuple[Set[int], Set[int], Set[int]]:
    """Recovery after a *driver* outage: reconcile checkpoint claims
    against surviving inventory.

    ``claimed_done`` is the set of clusters the run log says completed;
    ``available`` is every member value actually reachable right now
    (rejoined workers' inventories + reattached durable handles +
    checkpoint-spilled values).  Claims are monotone-but-stale — a value
    may have been produced, consumed, GC'd, and its producer legitimately
    never needs to re-run; or it may have died with a worker during the
    outage and must be replayed.

    Returns ``(lost, needed, plan)``: the claimed values that are gone,
    the subset a resumed run still has to rebuild (all of them in
    full-results mode; in ``outputs_only`` mode only graph outputs and
    values with unconsumed downstream clusters), and the cluster replay
    plan from :func:`recovery_plan_clusters` — exactly one plan per
    outage, however many workers died with it.
    """
    lost: Set[int] = set()
    for cid in claimed_done:
        for v in fused_plan.members[cid]:
            if v not in available:
                lost.add(v)
    if not outputs_only:
        needed = set(lost)
    else:
        needed = set()
        for v in lost:
            if v in graph.outputs:
                needed.add(v)
                continue
            for consumer in fused_plan.consumers.get(v, ()):
                if consumer not in claimed_done:
                    needed.add(v)
                    break
    plan = recovery_plan_clusters(fused_plan, needed, available)
    return lost, needed, plan


def replay(graph: TaskGraph, plan: Set[int], results: Dict[int, object]) -> None:
    """Execute ``plan`` in topo order, writing into ``results`` in place."""
    from .executor import _run_node   # local import to avoid a cycle
    order = [t for t in graph.topo_order() if t in plan]
    for tid in order:
        results[tid] = _run_node(graph, tid, results)


def recover(graph: TaskGraph, lost: Iterable[int],
            results: Dict[int, object], **kw) -> Set[int]:
    """Convenience: plan + replay. Returns the set of recomputed tasks."""
    lost = set(lost)
    for t in lost:
        results.pop(t, None)
    plan = recovery_plan(graph, lost, set(results), **kw)
    replay(graph, plan, results)
    return plan


def lineage_depth(graph: TaskGraph, tid: int, available: Set[int]) -> int:
    """How many tasks a single loss would force us to recompute — the metric
    that motivates checkpoint-barrier placement."""
    return len(recovery_plan(graph, {tid}, available - {tid}))
