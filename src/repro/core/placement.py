"""Auto-sharding placement — the intra-op half of the auto-parallelizer.

The paper schedules *whole function calls* onto workers.  On a TPU mesh the
equivalent decision is *which mesh axes shard which tensor axes*.  We use the
t5x/Alpa-style two-level scheme:

1. every tensor names its axes with **logical names** ("batch", "heads",
   "d_ff", "experts", ...);
2. a **rule table** maps logical names to mesh axes; first match wins and a
   mesh axis is never used twice in one spec (conflicts resolve to
   replication, which is always correct);
3. a greedy **cost refinement** pass (for the task-graph executor) picks, per
   intermediate value, the candidate spec minimizing estimated resharding
   bytes along graph edges — the same greedy principle as the paper's
   scheduler, applied to layouts.

Everything returns plain :class:`jax.sharding.PartitionSpec`, so the output
plugs directly into pjit / with_sharding_constraint.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
Rule = Tuple[str, MeshAxes]


# --------------------------------------------------------------------------
# rule tables
# --------------------------------------------------------------------------

def standard_rules(mode: str = "fsdp_tp", *, data_axes: Tuple[str, ...] = ("data",),
                   model_axis: str = "model", pod_axis: Optional[str] = "pod",
                   ) -> List[Rule]:
    """Built-in rule tables.

    ``mode``:
      * ``dp``       — pure data parallel (params replicated)
      * ``dp_tp``    — DP batch + TP on heads/ffn/vocab/experts
      * ``fsdp_tp``  — dp_tp + params/optimizer sharded over data axes (ZeRO-3)
      * ``dp_tp_ep`` — dp_tp with experts on the data axes (expert parallelism
                       orthogonal to TP)
    The ``pod`` axis (when present in the mesh) extends the batch axes, i.e.
    pods are data-parallel by default; the pipeline feature re-purposes it.
    """
    batch: Tuple[str, ...] = tuple(data_axes)
    if pod_axis:
        batch = (pod_axis,) + batch
    common: List[Rule] = [
        ("batch", batch),
        ("expert_group", batch),     # MoE token groups follow the batch
        ("seq", None),               # sequence sharding: see "sp" variants
        ("kv_seq", None),
    ]
    tp: List[Rule] = [
        ("vocab", model_axis),
        ("heads", model_axis),
        ("kv_heads", model_axis),
        ("heads_dim", model_axis),   # packed H*head_dim weight axis
        ("kv_dim", model_axis),      # packed KH*head_dim weight axis
        ("d_ff", model_axis),
        ("experts", model_axis),
        ("ssm_inner", model_axis),   # mamba d_inner
        ("ssm_heads", model_axis),
        ("conv_dim", model_axis),
        ("layers", None),
        ("norm_dim", None),
        ("state", None),
    ]
    if mode == "dp":
        return common + [(r, None) for r, _ in tp] + [("embed", None), ("d_model", None)]
    if mode == "dp_tp":
        return common + tp + [("embed", None), ("d_model", None)]
    if mode == "fsdp_tp":
        # params: the non-TP axis of each weight is sharded over the data
        # axes (ZeRO-3 / FSDP); "embed" marks that axis in weight pytrees.
        return common + tp + [("embed", tuple(data_axes)), ("d_model", None)]
    if mode == "dp_tp_ep":
        rules = common + [("experts", tuple(data_axes))] + tp
        return rules + [("embed", None), ("d_model", None)]
    if mode == "dp_tp_kvseq":
        # serving-oriented: KV cache sharded on the SEQUENCE dim over the TP
        # axis (divides any context length) instead of kv_heads (which is
        # often < TP ways — GQA — forcing replication + reshard copies);
        # weights stay TP'd.  Decode attention then reduces softmax stats
        # over the seq shards instead of gathering K/V.
        base = standard_rules("dp_tp", data_axes=data_axes,
                              model_axis=model_axis, pod_axis=pod_axis)
        return ([("kv_seq", model_axis), ("kv_heads", None)]
                + [(n, a) for (n, a) in base if n not in
                   ("kv_seq", "kv_heads")])
    if mode == "fsdp_tp_sp":
        # Megatron-style sequence parallelism: the residual stream between
        # blocks is sharded on seq over the TP axis (norms/elementwise run
        # S/tp), while tensors with a TP'd axis (heads/d_ff) keep it —
        # logical_to_spec gives "seq" the LOWEST claim priority, so inside
        # attention/MLP the seq axis yields the mesh axis to heads/d_ff and
        # the AR of the residual becomes a reduce-scatter + all-gather pair.
        base = standard_rules("fsdp_tp", data_axes=data_axes,
                              model_axis=model_axis, pod_axis=pod_axis)
        return sequence_parallel_rules(base, seq_axis=model_axis)
    raise ValueError(f"unknown mode {mode!r}")


def sequence_parallel_rules(base: List[Rule], *, seq_axis: str = "model") -> List[Rule]:
    """Enable sequence sharding (ring-attention-style SP) on top of a table."""
    out = [(n, a) for (n, a) in base if n not in ("seq", "kv_seq")]
    return [("seq", seq_axis), ("kv_seq", seq_axis)] + out


# --------------------------------------------------------------------------
# spec derivation
# --------------------------------------------------------------------------

def logical_to_spec(axes: Sequence[Optional[str]], rules: Sequence[Rule],
                    mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names to a PartitionSpec via first-match rules.

    A mesh axis already consumed by an earlier tensor dimension is dropped
    (replication instead of an invalid spec).  If ``mesh`` is given, mesh
    axes absent from it are dropped and divisibility is NOT checked here
    (XLA handles padding; the dry-run verifies real shapes).
    """
    rule_map: Dict[str, MeshAxes] = {}
    for name, target in rules:
        rule_map.setdefault(name, target)
    used: set = set()
    parts: List[MeshAxes] = [None] * len(axes)
    # two passes: "seq"/"kv_seq" claim mesh axes LAST, so when sequence
    # parallelism maps them onto the TP axis they yield to heads/d_ff
    # within any single tensor (Megatron-SP semantics)
    order = ([i for i, ax in enumerate(axes) if ax not in ("seq", "kv_seq")]
             + [i for i, ax in enumerate(axes) if ax in ("seq", "kv_seq")])
    for i in order:
        ax = axes[i]
        target = rule_map.get(ax) if ax is not None else None
        if target is None:
            continue
        cand = (target,) if isinstance(target, str) else tuple(target)
        if mesh is not None:
            cand = tuple(a for a in cand if a in mesh.axis_names)
        cand = tuple(a for a in cand if a not in used)
        used.update(cand)
        if not cand:
            continue
        parts[i] = cand[0] if len(cand) == 1 else cand
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_shards(spec: P, mesh: Mesh) -> int:
    n = 1
    for part in spec:
        if part is None:
            continue
        for ax in ((part,) if isinstance(part, str) else part):
            n *= mesh.shape[ax]
    return n


def sharding_for(axes: Sequence[Optional[str]], rules: Sequence[Rule],
                 mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules, mesh))


def tree_specs(logical_tree: Any, rules: Sequence[Rule],
               mesh: Optional[Mesh] = None) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_spec(axes, rules, mesh),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(logical_tree: Any, rules: Sequence[Rule], mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(logical_tree, rules, mesh))


# --------------------------------------------------------------------------
# greedy edge-cost refinement for task graphs
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ValueInfo:
    shape: Tuple[int, ...]
    dtype_bytes: int
    logical_axes: Tuple[Optional[str], ...]


def nbytes(info: ValueInfo) -> int:
    return int(np.prod(info.shape)) * info.dtype_bytes if info.shape else info.dtype_bytes


def resharding_bytes(info: ValueInfo, src: P, dst: P, mesh: Mesh) -> float:
    """Crude but monotone model: 0 if specs equal; otherwise each device
    gathers the union shard it is missing — approximated as
    ``bytes/dst_shards - bytes/(src∩dst shards)`` clipped at 0, plus an
    all-to-all term when both are sharded differently."""
    if src == dst:
        return 0.0
    total = nbytes(info)
    s_src = spec_shards(src, mesh)
    s_dst = spec_shards(dst, mesh)
    if s_src == 1:   # replicated -> anything: free (slice locally)
        return 0.0
    if s_dst == 1:   # sharded -> replicated: all-gather
        return total * (1.0 - 1.0 / s_src)
    return total / min(s_src, s_dst)   # resharding ~ all-to-all volume


def candidate_specs(info: ValueInfo, rules: Sequence[Rule], mesh: Mesh) -> List[P]:
    cands = [logical_to_spec(info.logical_axes, rules, mesh), P()]
    # also try sharding each single axis on each mesh axis (bounded set)
    for dim, size in enumerate(info.shape):
        for ax in mesh.axis_names:
            if size % mesh.shape[ax] == 0 and size >= mesh.shape[ax]:
                parts: List = [None] * len(info.shape)
                parts[dim] = ax
                cands.append(P(*parts))
    seen, out = set(), []
    for c in cands:
        key = tuple(c)
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def refine_placements(
    graph,                       # TaskGraph (duck-typed to avoid import cycle)
    value_info: Dict[int, ValueInfo],
    rules: Sequence[Rule],
    mesh: Mesh,
    *,
    sweeps: int = 2,
) -> Dict[int, P]:
    """Greedy coordinate-descent over per-task output specs.

    Initialize from the rule table, then for each task (topo order) pick the
    candidate spec minimizing resharding bytes to/from its neighbours.  Two
    sweeps are enough in practice (the cost model is submodular-ish); the
    result is guaranteed no worse than the rule-table initialization.
    """
    specs: Dict[int, P] = {
        tid: logical_to_spec(value_info[tid].logical_axes, rules, mesh)
        if tid in value_info else P()
        for tid in graph.nodes
    }
    succ = graph.successors()

    def edge_cost(tid: int, spec: P) -> float:
        c = 0.0
        info = value_info.get(tid)
        if info is None:
            return 0.0
        for s in succ[tid]:
            c += resharding_bytes(info, spec, specs[s], mesh) if s in value_info \
                else 0.0
        for d in graph.nodes[tid].deps:
            if d in value_info:
                c += resharding_bytes(value_info[d], specs[d], spec, mesh)
        return c

    for _ in range(sweeps):
        for tid in graph.topo_order():
            if tid not in value_info:
                continue
            best = min(candidate_specs(value_info[tid], rules, mesh),
                       key=lambda sp: edge_cost(tid, sp))
            specs[tid] = best
    return specs


def total_resharding_bytes(graph, value_info: Dict[int, ValueInfo],
                           specs: Dict[int, P], mesh: Mesh) -> float:
    c = 0.0
    for node in graph.nodes.values():
        for d in node.deps:
            if d in value_info and node.tid in value_info:
                c += resharding_bytes(value_info[d], specs[d],
                                      specs[node.tid], mesh)
    return c
