"""Modality frontend STUBS (per assignment: backbone only).

``[vlm]``/``[audio]`` cells feed precomputed patch/frame embeddings; these
helpers produce the matching ShapeDtypeStructs for the dry-run and synthetic
arrays for smoke tests.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig


def vision_patch_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model), cfg.cdtype)


def audio_frame_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), cfg.cdtype)


def synth_patches(cfg: ModelConfig, batch: int, seed: int = 0) -> jax.Array:
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (batch, cfg.n_patches, cfg.d_model),
                             cfg.cdtype) * 0.02


def synth_frames(cfg: ModelConfig, batch: int, seed: int = 0) -> jax.Array:
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (batch, cfg.enc_seq, cfg.d_model),
                             cfg.cdtype) * 0.02
