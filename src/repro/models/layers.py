"""Shared neural-net layers (pure JAX, functional params).

Conventions
-----------
* Params are nested dicts of arrays; a mirror pytree of **logical axis
  tuples** is produced by the same builder code (``mode="axes"``), which is
  what the auto-sharding placement pass consumes.
* Weight logical axes use ``"embed"`` for the FSDP-shardable dimension and
  ``"heads"/"d_ff"/"experts"/"ssm_inner"/"vocab"`` for the TP dimension.
* Activation logical axes use ``"batch"/"seq"/"heads"/"d_model"``.
* Compute runs in ``cfg.compute_dtype`` (bf16 on TPU), softmax/norm/loss
  statistics in float32.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Axes = Tuple[Optional[str], ...]


class Builder:
    """Creates params (mode='init') or their logical-axes mirror (mode='axes')."""

    def __init__(self, cfg: ModelConfig, key: Optional[jax.Array] = None,
                 mode: str = "init"):
        assert mode in ("init", "axes")
        self.cfg = cfg
        self.key = key
        self.mode = mode

    def p(self, name: str, shape: Tuple[int, ...], axes: Axes,
          init: str = "normal", scale: Optional[float] = None):
        assert len(shape) == len(axes), (name, shape, axes)
        if self.mode == "axes":
            return axes
        k = jax.random.fold_in(self.key, zlib.crc32(name.encode()))
        dt = self.cfg.pdtype
        if init == "zeros":
            return jnp.zeros(shape, dt)
        if init == "ones":
            return jnp.ones(shape, dt)
        if init == "normal":
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = fan_in ** -0.5
            return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)
        if init == "mamba_A":       # log-spaced negative eigenvalues
            n = shape[-1]
            a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), shape[:-1] + (1,))
            return jnp.log(a.reshape(shape)).astype(dt)
        if init == "mamba_dt":      # dt bias so softplus(dt) ∈ [1e-3, 1e-1]
            u = jax.random.uniform(k, shape, jnp.float32)
            dtv = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
            return (dtv + jnp.log(-jnp.expm1(-dtv))).astype(dt)  # inv-softplus
        raise ValueError(init)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_norm(b: Builder, name: str, cfg: ModelConfig, dim: Optional[int] = None,
              stacked: int = 0) -> Dict:
    d = dim or cfg.d_model
    shp: Tuple[int, ...] = (d,)
    axes: Axes = ("norm_dim",)
    if stacked:
        shp = (stacked,) + shp
        axes = ("layers",) + axes
    out = {"scale": b.p(f"{name}/scale", shp, axes, "ones")}
    if cfg.norm_type == "layernorm":
        out["bias"] = b.p(f"{name}/bias", shp, axes, "zeros")
    return out


def apply_norm(x: jax.Array, p: Dict, cfg: ModelConfig) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(b: Builder, name: str, cfg: ModelConfig,
                   stacked: int = 0, cross: bool = False) -> Dict:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L: Tuple[int, ...] = (stacked,) if stacked else ()
    A: Axes = ("layers",) if stacked else ()
    p = {
        "wq": b.p(f"{name}/wq", L + (d, H * hd), A + ("embed", "heads_dim")),
        "wk": b.p(f"{name}/wk", L + (d, KH * hd), A + ("embed", "kv_dim")),
        "wv": b.p(f"{name}/wv", L + (d, KH * hd), A + ("embed", "kv_dim")),
        "wo": b.p(f"{name}/wo", L + (H * hd, d), A + ("heads_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = b.p(f"{name}/bq", L + (H * hd,), A + ("heads_dim",), "zeros")
        p["bk"] = b.p(f"{name}/bk", L + (KH * hd,), A + ("kv_dim",), "zeros")
        p["bv"] = b.p(f"{name}/bv", L + (KH * hd,), A + ("kv_dim",), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = b.p(f"{name}/q_norm", L + (hd,), A + ("norm_dim",), "ones")
        p["k_norm"] = b.p(f"{name}/k_norm", L + (hd,), A + ("norm_dim",), "ones")
    return p


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 over the head_dim axis: x (B, S, KH, hd) →
    (int8 values, bf16 scales (B, S, KH))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array,
                     *, causal: bool, q_pos: Optional[jax.Array] = None,
                     kv_len: Optional[jax.Array] = None,
                     softcap: float = 0.0, grouped: bool = False) -> jax.Array:
    """Reference (XLA) attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KH, D).  ``kv_len`` masks cache slots
    beyond the valid length (decode); ``q_pos`` gives absolute positions of
    the queries for causal masking against cache positions.

    ``grouped``: GQA by grouped einsum — K/V are contracted in their
    (B, Sk, KH, D) layout instead of being repeat-materialized to H heads,
    which keeps a sharded KV cache sharded through the contraction.
    """
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = D ** -0.5
    if grouped and G > 1:
        qg = q.reshape(B, Sq, KH, G, D)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
    else:
        k = _repeat_kv(k, G)
        v = _repeat_kv(v, G)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    Sk = k.shape[1]
    kpos = jnp.arange(Sk)[None, None, None, :]
    if grouped and G > 1:
        kpos = jnp.arange(Sk)[None, None, None, None, :]
        mask = jnp.zeros((1, 1, 1, 1, Sk), jnp.bool_)
        if causal:
            qpos = (q_pos[:, None, None, :, None] if q_pos is not None
                    else jnp.arange(Sq)[None, None, None, :, None])
            mask = mask | (kpos > qpos)
        if kv_len is not None:
            mask = mask | (kpos >= kv_len[:, None, None, None, None])
        logits = jnp.where(mask, -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return out.reshape(B, Sq, H, D)
    mask = jnp.zeros((1, 1, 1, Sk), jnp.bool_)
    if causal:
        qpos = (q_pos[:, None, :, None] if q_pos is not None
                else jnp.arange(Sq)[None, None, :, None])
        mask = mask | (kpos > qpos)
    if kv_len is not None:
        mask = mask | (kpos >= kv_len[:, None, None, None])
    logits = jnp.where(mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def shard_act(x: jax.Array, axes: Axes, ctx) -> jax.Array:
    """Apply an activation sharding constraint when a mesh context is active."""
    if ctx is None or ctx.mesh is None:
        return x
    return ctx.constrain(x, axes)


def maybe_scan(cfg: ModelConfig, body, carry, xs, length: int):
    """``lax.scan`` when ``cfg.scan_layers`` (O(1) HLO in depth) else an
    unrolled Python loop (used by the dry-run's per-layer cost probes —
    XLA's cost_analysis counts a scan body once regardless of trip count,
    so probe models unroll a few layers and extrapolate per-layer cost)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if not ys or ys[0] is None:
        return carry, None
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


def attention_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array,
                    cache: Optional[Dict] = None,
                    cache_pos: Optional[jax.Array] = None,
                    causal: bool = True,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    ctx=None) -> Tuple[jax.Array, Optional[Dict]]:
    """GQA attention with optional KV cache.

    * train/prefill: ``cache=None`` → full self-attention over x.
    * prefill-with-cache: pass a fresh cache and ``cache_pos=0`` to fill it.
    * decode: x is (B, 1, d); cache holds (B, S_max, KH, D), updated at
      ``cache_pos``.
    * cross-attention: ``kv_override=(k, v)`` skips projections/cache.
    """
    B, S, d = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.cdtype

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
    q = q.reshape(B, S, H, hd)

    if kv_override is None:
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(cd))
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(cd))
        if "bk" in p:
            k = k + p["bk"].astype(cd)
            v = v + p["bv"].astype(cd)
        k = k.reshape(B, S, KH, hd)
        v = v.reshape(B, S, KH, hd)
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    if cfg.use_rope and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q = shard_act(q, ("batch", "seq", "heads", None), ctx)
    new_cache = None
    kv_len = None
    q_pos: Optional[jax.Array] = positions
    if cache is not None and kv_override is None:
        if "k_scale" in cache:
            # int8 cache: per-(token, head) symmetric quantization; the
            # dequant multiply fuses into the attention contraction, so
            # HBM reads the cache at half width (§Perf cell B follow-up)
            ks, ksc = _quantize_kv(k)
            vs, vsc = _quantize_kv(v)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], ks, (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], vs, (0, cache_pos, 0, 0))
            cks = jax.lax.dynamic_update_slice(
                cache["k_scale"], ksc, (0, cache_pos, 0))
            cvs = jax.lax.dynamic_update_slice(
                cache["v_scale"], vsc, (0, cache_pos, 0))
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            k = ck.astype(cd) * cks[..., None].astype(cd)
            v = cv.astype(cd) * cvs[..., None].astype(cd)
        else:
            ck, cv = cache["k"], cache["v"]
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, cache_pos, 0, 0))
            new_cache = {"k": ck, "v": cv}
            k, v = ck.astype(cd), cv.astype(cd)
        kv_len = jnp.broadcast_to(cache_pos + S, (B,))

    out = attention_scores(q, k.astype(cd), v.astype(cd), causal=causal,
                           q_pos=q_pos if causal else None, kv_len=kv_len,
                           softcap=0.0, grouped=cfg.gqa_grouped)
    out = out.reshape(B, S, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cd))
    return out, new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(b: Builder, name: str, cfg: ModelConfig, stacked: int = 0,
             d_ff: Optional[int] = None) -> Dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    L: Tuple[int, ...] = (stacked,) if stacked else ()
    A: Axes = ("layers",) if stacked else ()
    if cfg.mlp_act == "swiglu":
        return {
            "wi_gate": b.p(f"{name}/wi_gate", L + (d, ff), A + ("embed", "d_ff")),
            "wi_up": b.p(f"{name}/wi_up", L + (d, ff), A + ("embed", "d_ff")),
            "wo": b.p(f"{name}/wo", L + (ff, d), A + ("d_ff", "embed")),
        }
    return {
        "wi": b.p(f"{name}/wi", L + (d, ff), A + ("embed", "d_ff")),
        "bi": b.p(f"{name}/bi", L + (ff,), A + ("d_ff",), "zeros"),
        "wo": b.p(f"{name}/wo", L + (ff, d), A + ("d_ff", "embed")),
        "bo": b.p(f"{name}/bo", L + (d,), A + ("norm_dim",), "zeros"),
    }


def mlp_block(p: Dict, x: jax.Array, cfg: ModelConfig, ctx=None) -> jax.Array:
    cd = cfg.cdtype
    if "wi_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(cd))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(cd))
        h = jax.nn.silu(g) * u
        h = shard_act(h, ("batch", "seq", "d_ff"), ctx)
        return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd))
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(cd)) + p["bi"].astype(cd)
    h = jax.nn.gelu(h)
    h = shard_act(h, ("batch", "seq", "d_ff"), ctx)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd)) + p["bo"].astype(cd)


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------

def init_embed(b: Builder, cfg: ModelConfig) -> Dict:
    p = {"tok": b.p("embed/tok", (cfg.vocab_size, cfg.d_model),
                    ("vocab", "embed"), scale=1.0)}
    if not cfg.use_rope:
        p["pos"] = b.p("embed/pos", (8192, cfg.d_model), (None, "embed"),
                       scale=0.02)
    if not cfg.tie_embeddings:
        p["unembed"] = b.p("embed/unembed", (cfg.d_model, cfg.vocab_size),
                           ("embed", "vocab"))
    return p


def embed_tokens(p: Dict, tokens: jax.Array, cfg: ModelConfig,
                 positions: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.cdtype)
    if not cfg.use_rope and positions is not None:
        x = x + jnp.take(p["pos"], positions, axis=0).astype(cfg.cdtype)
    return x


def unembed(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"]).astype(cfg.cdtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits
