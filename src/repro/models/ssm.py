"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD).

Both use **chunked** scans: a sequential ``lax.scan`` over chunks carrying the
SSM state, with parallel (associative-scan / quadratic-intra) work inside the
chunk.  This bounds activation memory to O(B · chunk · d_inner · N) instead of
O(B · S · d_inner · N) — at falcon-mamba's 32k-prefill cell the naive form
would materialize ~0.5 TB of decay products; chunking is what makes the
dry-run memory analysis come out sane.  The chunk loop also maps 1:1 onto the
Pallas kernel's grid (see ``repro.kernels.ssm_scan``).

Decode uses the O(1) recurrent step with a carried (conv window, state) cache.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Builder, Axes, rmsnorm, shard_act


def dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


# ==========================================================================
# Mamba1
# ==========================================================================

def init_mamba1(b: Builder, name: str, cfg: ModelConfig, stacked: int = 0) -> Dict:
    d, di, N, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    R = dt_rank(cfg)
    L: Tuple[int, ...] = (stacked,) if stacked else ()
    A: Axes = ("layers",) if stacked else ()
    return {
        "in_proj": b.p(f"{name}/in_proj", L + (d, 2 * di), A + ("embed", "ssm_inner")),
        "conv_w": b.p(f"{name}/conv_w", L + (k, di), A + (None, "ssm_inner"),
                      scale=k ** -0.5),
        "conv_b": b.p(f"{name}/conv_b", L + (di,), A + ("ssm_inner",), "zeros"),
        "x_proj": b.p(f"{name}/x_proj", L + (di, R + 2 * N), A + ("ssm_inner", None)),
        "dt_proj": b.p(f"{name}/dt_proj", L + (R, di), A + (None, "ssm_inner"),
                       scale=R ** -0.5),
        "dt_bias": b.p(f"{name}/dt_bias", L + (di,), A + ("ssm_inner",), "mamba_dt"),
        "A_log": b.p(f"{name}/A_log", L + (di, N), A + ("ssm_inner", "state"),
                     "mamba_A"),
        "D": b.p(f"{name}/D", L + (di,), A + ("ssm_inner",), "ones"),
        "out_proj": b.p(f"{name}/out_proj", L + (di, d), A + ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (k, C).

    ``state`` is the trailing (k-1) inputs from the previous call (decode /
    chunk streaming); returns (output, new_state).
    """
    Bsz, S, C = x.shape
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((Bsz, k - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+k-1, C)
    out = jnp.zeros((Bsz, S, C), x.dtype)
    for i in range(k):                                   # k is 4: unrolled
        out = out + xp[:, i:i + S, :] * w[i][None, None, :].astype(x.dtype)
    new_state = xp[:, S:, :] if S >= 1 else state
    return out + b.astype(x.dtype), xp[:, -(k - 1):, :]


def selective_scan(xs: jax.Array, dt: jax.Array, Bc: jax.Array, Cc: jax.Array,
                   A: jax.Array, h0: Optional[jax.Array], chunk: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan.

    xs, dt: (B, S, D);  Bc, Cc: (B, S, N);  A: (D, N) (negative reals).
    Returns (y: (B, S, D), h_final: (B, D, N)).  float32 state math.
    """
    Bsz, S, D = xs.shape
    N = A.shape[-1]
    if S % chunk != 0:
        chunk = S            # fall back to one chunk (small inputs)
    nc = S // chunk

    xs = xs.reshape(Bsz, nc, chunk, D).astype(jnp.float32)
    dt = dt.reshape(Bsz, nc, chunk, D).astype(jnp.float32)
    Bc = Bc.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cc.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    h = (jnp.zeros((Bsz, D, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp                       # (B, Q, D), ..., (B, Q, N)
        # decay a_t = exp(dt_t * A): (B, Q, D, N); input b_t = dt*x ⊗ B
        a = jnp.exp(dtc[..., None] * A[None, None])             # (B,Q,D,N)
        u = (dtc * xc)[..., None] * bc[:, :, None, :]           # (B,Q,D,N)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        Acum, Bsum = jax.lax.associative_scan(comb, (a, u), axis=1)
        hs = Acum * h[:, None] + Bsum                           # (B,Q,D,N)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cc)
        return hs[:, -1], y

    h, ys = jax.lax.scan(
        chunk_step, h,
        (xs.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, S, D)
    return y, h


def mamba1_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                 cache: Optional[Dict] = None, ctx=None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d).  cache = {"conv": (B,k-1,di), "h": (B,di,N)} for decode."""
    Bsz, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    R = dt_rank(cfg)
    cd = cfg.cdtype

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard_act(xs, ("batch", "seq", "ssm_inner"), ctx)

    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("bsd,de->bse", xs, p["x_proj"].astype(cd))
    dt_lr, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_lr, p["dt_proj"].astype(cd))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di, N)

    h0 = cache["h"] if cache is not None else None
    y, h = selective_scan(xs, dt, Bc, Cc, A, h0, cfg.ssm_chunk)
    y = (y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(cd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    new_cache = ({"conv": new_conv, "h": h} if cache is not None else None)
    return out, new_cache


# ==========================================================================
# Mamba2 (SSD — scalar A per head, chunked dual form)
# ==========================================================================

def init_mamba2(b: Builder, name: str, cfg: ModelConfig, stacked: int = 0) -> Dict:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.n_ssm_heads
    conv_dim = di + 2 * N
    k = cfg.ssm_conv
    L: Tuple[int, ...] = (stacked,) if stacked else ()
    A: Axes = ("layers",) if stacked else ()
    return {
        # order: [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": b.p(f"{name}/in_proj", L + (d, 2 * di + 2 * N + H),
                       A + ("embed", "ssm_inner")),
        "conv_w": b.p(f"{name}/conv_w", L + (k, conv_dim),
                      A + (None, "conv_dim"), scale=k ** -0.5),
        "conv_b": b.p(f"{name}/conv_b", L + (conv_dim,), A + ("conv_dim",), "zeros"),
        "A_log": b.p(f"{name}/A_log", L + (H,), A + ("ssm_heads",), "mamba_A"),
        "dt_bias": b.p(f"{name}/dt_bias", L + (H,), A + ("ssm_heads",), "mamba_dt"),
        "D": b.p(f"{name}/D", L + (H,), A + ("ssm_heads",), "ones"),
        "norm": b.p(f"{name}/norm", L + (di,), A + ("norm_dim",), "ones"),
        "out_proj": b.p(f"{name}/out_proj", L + (di, d), A + ("ssm_inner", "embed")),
    }


def ssd_chunked(xh: jax.Array, dt: jax.Array, Bc: jax.Array, Cc: jax.Array,
                A: jax.Array, h0: Optional[jax.Array], chunk: int,
                io_dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (Mamba2) forward.

    xh: (B, S, H, P); dt: (B, S, H); Bc, Cc: (B, S, N); A: (H,) negative.
    Returns (y: (B, S, H, P), h_final: (B, H, P, N)).

    ``io_dtype``: width of the big intra-chunk tensors/matmuls (x, B, C,
    decay matrix).  bfloat16 matches the reference Mamba2 training recipe
    (states, dt and cumulative decays stay f32) and halves the dominant
    HLO bytes — §Perf hillclimb lever.
    """
    Bsz, S, H, P = xh.shape
    N = Bc.shape[-1]
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    xh = xh.reshape(Bsz, nc, chunk, H, P).astype(io_dtype)
    dt = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bc.reshape(Bsz, nc, chunk, N).astype(io_dtype)
    Cc = Cc.reshape(Bsz, nc, chunk, N).astype(io_dtype)
    h = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp
        dA = dtc * A[None, None]                         # (B,Q,H) negative
        cum = jnp.cumsum(dA, axis=1)                     # (B,Q,H) f32
        # intra-chunk (quadratic) term: masked "attention" with decay
        Lmat = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,K,H)
        q = jnp.arange(xc.shape[1])
        causal = (q[:, None] >= q[None, :])[None, :, :, None]
        Lmat = jnp.where(causal, Lmat, 0.0).astype(io_dtype)
        scores = jnp.einsum("bqn,bkn->bqk", cc, bc,
                            preferred_element_type=jnp.float32)  # (B,Q,K)
        att = (scores.astype(io_dtype)[..., None] * Lmat)        # (B,Q,K,H)
        y_intra = jnp.einsum("bqkh,bkh,bkhp->bqhp", att,
                             dtc.astype(io_dtype), xc,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cum).astype(io_dtype)         # decay from chunk start
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", cc, decay_in,
                             h.astype(io_dtype),
                             preferred_element_type=jnp.float32)
        # new state: h' = exp(sum dA) h + sum_k decay_to_end * dt x ⊗ B
        tot = cum[:, -1]                                 # (B,H) f32
        decay_out = jnp.exp(tot[:, None] - cum).astype(io_dtype)  # (B,Q,H)
        h_new = (jnp.exp(tot)[..., None, None] * h
                 + jnp.einsum("bkh,bkh,bkhp,bkn->bhpn",
                              decay_out, dtc.astype(io_dtype), xc, bc,
                              preferred_element_type=jnp.float32))
        return h_new, y_intra + y_inter

    h, ys = jax.lax.scan(
        chunk_step, h,
        (xh.transpose(1, 0, 2, 3, 4), dt.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h


def mamba2_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                 cache: Optional[Dict] = None, ctx=None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d). cache = {"conv": (B,k-1,conv_dim), "h": (B,H,P,N)}."""
    Bsz, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    H, P = cfg.n_ssm_heads, cfg.ssm_head_dim
    cd = cfg.cdtype

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)

    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)
    xs = shard_act(xs, ("batch", "seq", "ssm_inner"), ctx)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,)

    xh = xs.reshape(Bsz, S, H, P)
    h0 = cache["h"] if cache is not None else None
    y, h = ssd_chunked(xh, dt, Bc, Cc, A, h0, cfg.ssm_chunk,
                       io_dtype=(jnp.bfloat16 if cfg.ssd_bf16
                                 else jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(cd)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    new_cache = ({"conv": new_conv, "h": h} if cache is not None else None)
    return out, new_cache


# -- O(1) decode steps ------------------------------------------------------

def mamba1_decode_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba2_decode_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32),
    }


def ssm_flops_per_token(cfg: ModelConfig, kind: str) -> int:
    """Matmul-ish FLOPs per token for one SSM layer (fwd)."""
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    if kind == "mamba1":
        R = dt_rank(cfg)
        f = 2 * d * 2 * di + 2 * di * (R + 2 * N) + 2 * R * di + 2 * di * d
        f += 2 * cfg.ssm_conv * di          # conv
        f += 6 * di * N                      # scan update+output (per token)
        return f
    H, P = cfg.n_ssm_heads, cfg.ssm_head_dim
    f = 2 * d * (2 * di + 2 * N + H) + 2 * di * d
    f += 2 * cfg.ssm_conv * (di + 2 * N)
    f += 2 * cfg.ssm_chunk * (N + H * P)     # intra-chunk quadratic amortized
    f += 6 * H * P * N                       # state update/output
    return f
