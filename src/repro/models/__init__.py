from .config import ModelConfig, ShapeSpec, SHAPES
from .transformer import (init_params, logical_axes, forward, make_train_step,
                          make_prefill_step, make_decode_step, init_cache,
                          count_params, model_flops_per_token)
