"""Mixture-of-Experts layer: top-k router + grouped capacity dispatch.

GShard-style formulation: tokens are split into fixed-size groups (the
``expert_group`` logical axis, sharded over the batch mesh axes); each group
dispatches into per-expert capacity buffers through one-hot einsums.  The
group size bounds the dispatch tensor to ``group × E × C`` elements
regardless of global batch — without it, a flat one-hot dispatch at
llama4-maverick scale (1M tokens × 128 experts) would materialize a ~TB
intermediate and the dry-run could never fit.

FLOPs equal the *active* expert compute (what the roofline's ``6·N_active·D``
expects).  A Pallas grouped-matmul kernel (`repro.kernels.grouped_matmul`)
can replace the einsum path on TPU.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Builder, Axes, shard_act


def init_moe(b: Builder, name: str, cfg: ModelConfig, stacked: int = 0) -> Dict:
    d, ff, E = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    L: Tuple[int, ...] = (stacked,) if stacked else ()
    A: Axes = ("layers",) if stacked else ()
    p = {
        "router": b.p(f"{name}/router", L + (d, E), A + ("embed", None),
                      scale=d ** -0.5),
        "wi_gate": b.p(f"{name}/wi_gate", L + (E, d, ff),
                       A + ("experts", "embed", "d_ff")),
        "wi_up": b.p(f"{name}/wi_up", L + (E, d, ff),
                     A + ("experts", "embed", "d_ff")),
        "wo": b.p(f"{name}/wo", L + (E, ff, d),
                  A + ("experts", "d_ff", "embed")),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        p["shared_wi_gate"] = b.p(f"{name}/shared_wi_gate", L + (d, sff),
                                  A + ("embed", "d_ff"))
        p["shared_wi_up"] = b.p(f"{name}/shared_wi_up", L + (d, sff),
                                A + ("embed", "d_ff"))
        p["shared_wo"] = b.p(f"{name}/shared_wo", L + (sff, d),
                             A + ("d_ff", "embed"))
    return p


def moe_block(p: Dict, x: jax.Array, cfg: ModelConfig,
              ctx=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  x: (B, S, d)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    cd = cfg.cdtype
    T = B * S
    gs = cfg.moe_group if (T % cfg.moe_group == 0 and T >= cfg.moe_group) else T
    G = T // gs
    # ceil, not floor: small groups (decode: gs = batch) otherwise round the
    # capacity to 0-ish and drop almost everything
    C = max(-(-int(cfg.capacity_factor * gs * K) // E), 1)
    xt = x.reshape(G, gs, d)
    xt = shard_act(xt, ("expert_group", None, None), ctx)

    # ---- router (float32 for numerics)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (G, gs, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                 # (G, gs, K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style), global over tokens
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- capacity positions: per-group running count per expert
    onehot_e = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)       # (G,gs,K,E)
    flat = onehot_e.reshape(G, gs * K, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                    # (G,gs*K,E)
    pos = jnp.sum(flat * pos_flat, axis=-1).reshape(G, gs, K)     # (G,gs,K)
    keep = pos < C                                                # drop overflow
    gate_vals = gate_vals * keep.astype(jnp.float32)

    # ---- dispatch/combine, accumulated over k to avoid a (gs,K,E,C) tensor
    dispatch = jnp.zeros((G, gs, E, C), cd)
    combine = jnp.zeros((G, gs, E, C), cd)
    for k in range(K):
        oe = jax.nn.one_hot(gate_idx[..., k], E, dtype=cd) \
            * keep[..., k, None].astype(cd)                       # (G,gs,E)
        oc = jax.nn.one_hot(pos[..., k], C, dtype=cd)             # (G,gs,C)
        dk = jnp.einsum("gte,gtc->gtec", oe, oc)
        dispatch = dispatch + dk
        combine = combine + dk * gate_vals[..., k, None, None].astype(cd)
    dispatch = shard_act(dispatch, ("expert_group", None, "experts", None), ctx)

    # ---- expert computation on capacity buffers
    xe = jnp.einsum("gtd,gtec->gecd", xt.astype(cd), dispatch)    # (G,E,C,d)
    xe = shard_act(xe, ("expert_group", "experts", None, None), ctx)
    g = jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"].astype(cd))
    u = jnp.einsum("gecd,edf->gecf", xe, p["wi_up"].astype(cd))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(cd))      # (G,E,C,d)
    y = jnp.einsum("gecd,gtec->gtd", ye, combine)                 # (G,gs,d)

    if "shared_wi_gate" in p:
        gsh = jnp.einsum("gtd,df->gtf", xt.astype(cd),
                         p["shared_wi_gate"].astype(cd))
        ush = jnp.einsum("gtd,df->gtf", xt.astype(cd),
                         p["shared_wi_up"].astype(cd))
        y = y + jnp.einsum("gtf,fd->gtd", jax.nn.silu(gsh) * ush,
                           p["shared_wo"].astype(cd))

    return y.reshape(B, S, d), aux.astype(jnp.float32)


def moe_flops_per_token(cfg: ModelConfig) -> int:
    """Active-parameter matmul FLOPs per token for one MoE layer (fwd)."""
    d, ff, K = cfg.d_model, cfg.expert_d_ff, cfg.experts_per_token
    f = 2 * d * cfg.n_experts                      # router
    f += K * (3 * 2 * d * ff)                      # K experts, swiglu
    if cfg.n_shared_experts:
        f += cfg.n_shared_experts * 3 * 2 * d * ff
    return f
