"""Unified decoder-only LM covering dense / MoE / SSM / hybrid / VLM configs.

Layers are **stacked** (leading ``n_layers`` dim) and executed with
``jax.lax.scan`` so the HLO stays O(1) in depth — essential for compiling
81-layer configs on 512 host devices in the dry-run.  The per-layer plan
(attention / mamba1 / mamba2 / mamba2+shared_attn / MLP-vs-MoE) must be
homogeneous across layers for the scan; the zamba2 "shared attention block"
is handled *inside* the scan body with a layer-index condition and a shared
(unstacked) parameter set — its KV caches live at ``n_sites`` cache slots.

Encoder-decoder models (whisper) are in :mod:`repro.models.encdec`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (Builder, init_attention, attention_block, init_mlp,
                     mlp_block, init_norm, apply_norm, init_embed,
                     embed_tokens, unembed, shard_act, maybe_scan)
from .moe import init_moe, moe_block, moe_flops_per_token
from .ssm import (init_mamba1, init_mamba2, mamba1_block, mamba2_block,
                  mamba1_decode_cache, mamba2_decode_cache, ssm_flops_per_token)


# --------------------------------------------------------------------------
# plan helpers
# --------------------------------------------------------------------------

def _plan_kind(cfg: ModelConfig) -> str:
    kinds = set(cfg.layer_plan)
    if kinds == {"attn"}:
        return "attn"
    if kinds == {"mamba1"}:
        return "mamba1"
    if kinds == {"mamba2"}:
        return "mamba2"
    if kinds <= {"mamba2", "mamba2+shared_attn"}:
        return "mamba2_shared"
    raise ValueError(f"unsupported layer plan {kinds} (scan needs homogeneity)")


def _n_shared_sites(cfg: ModelConfig) -> int:
    return sum(1 for p in cfg.layer_plan if p == "mamba2+shared_attn")


def _mixer_init(b: Builder, cfg: ModelConfig, kind: str, L: int) -> Dict:
    if kind == "attn":
        return init_attention(b, "layers/attn", cfg, stacked=L)
    if kind == "mamba1":
        return init_mamba1(b, "layers/mamba1", cfg, stacked=L)
    return init_mamba2(b, "layers/mamba2", cfg, stacked=L)


def _superblock(cfg: ModelConfig) -> int:
    """Scan super-block size: llama4-style interleaved MoE scans blocks of
    ``moe_every`` layers (k-1 dense + 1 MoE) to keep xs homogeneous."""
    if cfg.n_experts and cfg.moe_every > 1 and _plan_kind(cfg) == "attn":
        assert cfg.n_layers % cfg.moe_every == 0
        return cfg.moe_every
    return 1


def _ffn_init(b: Builder, cfg: ModelConfig, L: int) -> Optional[Dict]:
    kind = _plan_kind(cfg)
    if kind != "attn":
        return None                      # mamba blocks have no separate FFN
    if cfg.n_experts:
        k = _superblock(cfg)
        if k > 1:
            L2 = L // k
            return {"mlp": init_mlp(b, "layers/mlp", cfg, stacked=L - L2),
                    "moe": init_moe(b, "layers/moe", cfg, stacked=L2)}
        return init_moe(b, "layers/moe", cfg, stacked=L)
    return init_mlp(b, "layers/mlp", cfg, stacked=L)


def _build(cfg: ModelConfig, b: Builder) -> Dict:
    L = cfg.n_layers
    kind = _plan_kind(cfg)
    params: Dict[str, Any] = {
        "embed": init_embed(b, cfg),
        "final_norm": init_norm(b, "final_norm", cfg),
        "layers": {
            "mixer": _mixer_init(b, cfg, kind, L),
            "norm1": init_norm(b, "layers/norm1", cfg, stacked=L),
        },
    }
    ffn = _ffn_init(b, cfg, L)
    if ffn is not None:
        params["layers"]["ffn"] = ffn
        params["layers"]["norm2"] = init_norm(b, "layers/norm2", cfg, stacked=L)
    if kind == "mamba2_shared":
        # zamba2's shared block is a full transformer block (attn + MLP),
        # ONE parameter set reused at every site.
        params["shared_attn"] = init_attention(b, "shared_attn", cfg)
        params["shared_norm"] = init_norm(b, "shared_norm", cfg)
        params["shared_mlp"] = init_mlp(b, "shared_mlp", cfg)
        params["shared_norm2"] = init_norm(b, "shared_norm2", cfg)
    return params


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    return _build(cfg, Builder(cfg, key, mode="init"))


def logical_axes(cfg: ModelConfig) -> Dict:
    return _build(cfg, Builder(cfg, mode="axes"))


def abstract_params(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict:
    """Decode cache pytree. ``pos`` is the write cursor (same for the batch)."""
    dt = dtype or cfg.cdtype
    kind = _plan_kind(cfg)
    L = cfg.n_layers
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if kind == "attn":
        if cfg.kv_cache_dtype == "int8":
            cache["layers"] = {
                "k": jnp.zeros((L, batch, max_len, KH, hd), jnp.int8),
                "v": jnp.zeros((L, batch, max_len, KH, hd), jnp.int8),
                "k_scale": jnp.zeros((L, batch, max_len, KH), jnp.bfloat16),
                "v_scale": jnp.zeros((L, batch, max_len, KH), jnp.bfloat16),
            }
            return cache
        cache["layers"] = {
            "k": jnp.zeros((L, batch, max_len, KH, hd), dt),
            "v": jnp.zeros((L, batch, max_len, KH, hd), dt),
        }
    elif kind == "mamba1":
        c = mamba1_decode_cache(cfg, batch, dt)
        cache["layers"] = jax.tree.map(
            lambda x: jnp.zeros((L,) + x.shape, x.dtype), c)
    else:  # mamba2 / mamba2_shared
        c = mamba2_decode_cache(cfg, batch, dt)
        cache["layers"] = jax.tree.map(
            lambda x: jnp.zeros((L,) + x.shape, x.dtype), c)
        if kind == "mamba2_shared":
            sites = _n_shared_sites(cfg)
            cache["shared"] = {
                "k": jnp.zeros((sites, batch, max_len, KH, hd), dt),
                "v": jnp.zeros((sites, batch, max_len, KH, hd), dt),
            }
    return cache


def cache_logical_axes(cfg: ModelConfig) -> Dict:
    kind = _plan_kind(cfg)
    ax: Dict[str, Any] = {"pos": ()}
    if kind == "attn":
        ax["layers"] = {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
                        "v": ("layers", "batch", "kv_seq", "kv_heads", None)}
        if cfg.kv_cache_dtype == "int8":
            ax["layers"]["k_scale"] = ("layers", "batch", "kv_seq", "kv_heads")
            ax["layers"]["v_scale"] = ("layers", "batch", "kv_seq", "kv_heads")
    elif kind == "mamba1":
        ax["layers"] = {"conv": ("layers", "batch", None, "ssm_inner"),
                        "h": ("layers", "batch", "ssm_inner", "state")}
    else:
        ax["layers"] = {"conv": ("layers", "batch", None, "conv_dim"),
                        "h": ("layers", "batch", "ssm_heads", None, "state")}
        if kind == "mamba2_shared":
            ax["shared"] = {"k": (None, "batch", "kv_seq", "kv_heads", None),
                            "v": (None, "batch", "kv_seq", "kv_heads", None)}
    return ax


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _layer_body(cfg: ModelConfig, ctx, *, use_cache: bool, train: bool,
                positions, cache_pos, shared_params, shared_norm,
                shared_mlp=None, shared_norm2=None, apply_remat: bool = True,
                static_idx: Optional[int] = None):
    """Returns fn(carry, xs) for lax.scan over stacked layers.

    ``positions``/``cache_pos``/``shared_*`` are loop invariants closed over
    (scan hoists them as constants — broadcasting them into xs would
    materialize L copies of the shared-attention weights)."""
    kind = _plan_kind(cfg)
    every = cfg.shared_attn_every

    def body(carry, xs):
        x, aux, shared_k, shared_v = carry
        lp, lcache = xs["params"], xs.get("cache")
        # static_idx is bound by closure (NOT through xs) so that remat /
        # checkpoint wrapping cannot re-trace it into a dynamic value
        idx = static_idx if static_idx is not None else xs["idx"]

        h = apply_norm(x, lp["norm1"], cfg)
        new_cache = None
        if kind == "attn":
            attn_cache = dict(lcache) if use_cache else None
            h, new_cache = attention_block(
                lp["mixer"], h, cfg, positions=positions,
                cache=attn_cache, cache_pos=cache_pos, causal=True, ctx=ctx)
        elif kind == "mamba1":
            h, new_cache = mamba1_block(lp["mixer"], h, cfg,
                                        cache=lcache if use_cache else None,
                                        ctx=ctx)
        else:
            h, new_cache = mamba2_block(lp["mixer"], h, cfg,
                                        cache=lcache if use_cache else None,
                                        ctx=ctx)
        x = x + h

        if "ffn" in lp:
            h = apply_norm(x, lp["norm2"], cfg)
            if "router" in lp["ffn"]:           # MoE vs dense by structure
                h, a = moe_block(lp["ffn"], h, cfg, ctx=ctx)
                aux = aux + a
            else:
                h = mlp_block(lp["ffn"], h, cfg, ctx=ctx)
            x = x + h

        if kind == "mamba2_shared" and every:
            # zamba2: one SHARED attention block applied after every
            # ``every``-th layer.
            def apply_shared(operands, site):
                x, sk, sv = operands
                h = apply_norm(x, shared_norm, cfg)
                if use_cache:
                    ck = jax.lax.dynamic_index_in_dim(sk, site, 0, keepdims=False)
                    cv = jax.lax.dynamic_index_in_dim(sv, site, 0, keepdims=False)
                    h, nc = attention_block(
                        shared_params, h, cfg, positions=positions,
                        cache={"k": ck, "v": cv}, cache_pos=cache_pos,
                        causal=True, ctx=ctx)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, nc["k"], site, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, nc["v"], site, 0)
                else:
                    h, _ = attention_block(shared_params, h, cfg,
                                           positions=positions,
                                           causal=True, ctx=ctx)
                x = x + h
                if shared_mlp is not None:
                    h = apply_norm(x, shared_norm2, cfg)
                    x = x + mlp_block(shared_mlp, h, cfg, ctx=ctx)
                return x, sk, sv

            if isinstance(idx, (int, np.integer)):
                # STATIC idx (unrolled cost probes / unrolled execution):
                # the site test resolves at trace time, so the emitted HLO
                # has shared-attn ops only at the real sites — important
                # because cost_analysis counts BOTH branches of an HLO cond
                # at every layer otherwise (§Perf cell C).
                if (int(idx) + 1) % every == 0:
                    x, shared_k, shared_v = apply_shared(
                        (x, shared_k, shared_v), (int(idx) + 1) // every - 1)
            else:
                # scan path: lax.cond so non-site layers pay nothing at
                # runtime (one branch executes on TPU)
                is_site = (idx + 1) % every == 0
                site = jnp.maximum((idx + 1) // every - 1, 0)
                if shared_k is None:     # no cache: carry only x through cond
                    x = jax.lax.cond(
                        is_site,
                        lambda x: apply_shared((x, None, None), site)[0],
                        lambda x: x, x)
                else:
                    x, shared_k, shared_v = jax.lax.cond(
                        is_site, lambda o: apply_shared(o, site),
                        lambda o: o, (x, shared_k, shared_v))

        x = shard_act(x, ("batch", "seq", "d_model"), ctx)
        return (x, aux, shared_k, shared_v), new_cache

    if apply_remat and train and cfg.remat != "none":
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    return body


def _remat_policy(cfg: ModelConfig):
    return (None if cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def forward(params: Dict, tokens: jax.Array, cfg: ModelConfig, *,
            ctx=None, cache: Optional[Dict] = None,
            patch_embeds: Optional[jax.Array] = None,
            train: bool = False) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (logits, new_cache, aux_loss).

    tokens: (B, S).  With ``cache``: prefill (pos=0, S>1) or decode (S==1,
    write at ``cache['pos']``).  ``patch_embeds`` (B, P, d) overrides the
    first P embeddings (VLM stub frontend).
    """
    B, S = tokens.shape
    kind = _plan_kind(cfg)
    pos0 = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = pos0[None, None] + jnp.arange(S)[None, :]          # (B=1bc, S)
    positions = jnp.broadcast_to(positions, (B, S))

    x = embed_tokens(params["embed"], tokens, cfg, positions)
    if patch_embeds is not None:
        P = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, P:, :]], axis=1)
    x = shard_act(x, ("batch", "seq", "d_model"), ctx)

    use_cache = cache is not None
    shared_k = shared_v = None
    if use_cache and "shared" in cache:
        shared_k, shared_v = cache["shared"]["k"], cache["shared"]["v"]

    L = cfg.n_layers
    k_super = _superblock(cfg)
    aux0 = jnp.zeros((), jnp.float32)
    carry0 = (x, aux0, shared_k, shared_v)
    body_kw = dict(
        use_cache=use_cache, train=train,
        positions=positions, cache_pos=pos0,
        shared_params=params.get("shared_attn"),
        shared_norm=params.get("shared_norm"),
        shared_mlp=params.get("shared_mlp"),
        shared_norm2=params.get("shared_norm2"))

    if k_super == 1:
        xs: Dict[str, Any] = {"params": params["layers"],
                              "idx": jnp.arange(L, dtype=jnp.int32)}
        if use_cache:
            xs["cache"] = cache["layers"]
        if cfg.scan_layers:
            body = _layer_body(cfg, ctx, **body_kw)
            carry, layer_caches = maybe_scan(cfg, body, carry0, xs, L)
        else:
            # unrolled (cost probes): per-layer bodies with a STATIC index
            # so per-layer branches (zamba2 shared-attn sites) resolve at
            # trace time — cost_analysis counts both branches of an HLO
            # cond, which would charge every layer for the shared block
            carry, ys = carry0, []
            for i in range(L):
                bi = _layer_body(cfg, ctx, static_idx=i, **body_kw)
                carry, y = bi(carry, jax.tree.map(lambda a: a[i], xs))
                ys.append(y)
            layer_caches = (None if not ys or ys[0] is None else
                            jax.tree.map(lambda *a: jnp.stack(a), *ys))
    else:
        # interleaved-MoE super-blocks: scan over L/k blocks of (k-1 dense
        # + 1 MoE) layers so the xs pytree stays homogeneous.
        L2 = L // k_super
        to_super = lambda t: jax.tree.map(
            lambda a: a.reshape((L2, k_super) + a.shape[1:]), t)
        lay = params["layers"]
        xs = {"mixer": to_super(lay["mixer"]),
              "norm1": to_super(lay["norm1"]),
              "norm2": to_super(lay["norm2"]),
              "mlp": jax.tree.map(
                  lambda a: a.reshape((L2, k_super - 1) + a.shape[1:]),
                  lay["ffn"]["mlp"]),
              "moe": lay["ffn"]["moe"],
              "idx": jnp.arange(L, dtype=jnp.int32).reshape(L2, k_super)}
        if use_cache:
            xs["cache"] = to_super(cache["layers"])
        sub_body = _layer_body(cfg, ctx, apply_remat=False, **body_kw)
        tree_i = lambda t, i: jax.tree.map(lambda a: a[i], t)

        def super_body(carry, xsb):
            new_caches = []
            for i in range(k_super):
                lp = {"mixer": tree_i(xsb["mixer"], i),
                      "norm1": tree_i(xsb["norm1"], i),
                      "norm2": tree_i(xsb["norm2"], i),
                      "ffn": (tree_i(xsb["mlp"], i) if i < k_super - 1
                              else xsb["moe"])}
                sub = {"params": lp, "idx": xsb["idx"][i]}
                if use_cache:
                    sub["cache"] = tree_i(xsb["cache"], i)
                carry, nc = sub_body(carry, sub)
                new_caches.append(nc)
            ys = (None if new_caches[0] is None else
                  jax.tree.map(lambda *a: jnp.stack(a), *new_caches))
            return carry, ys

        if train and cfg.remat != "none":
            super_body = jax.checkpoint(super_body, policy=_remat_policy(cfg))
        carry, layer_caches = maybe_scan(cfg, super_body, carry0, xs, L2)
        if use_cache:
            layer_caches = jax.tree.map(
                lambda a: a.reshape((L,) + a.shape[2:]), layer_caches)
    x, aux, shared_k, shared_v = carry

    x = apply_norm(x, params["final_norm"], cfg)
    logits = unembed(params["embed"], x, cfg)
    logits = shard_act(logits, ("batch", "seq", "vocab"), ctx)

    new_cache = None
    if use_cache:
        new_cache = {"pos": pos0 + S, "layers": layer_caches}
        if "shared" in cache:
            new_cache["shared"] = {"k": shared_k, "v": shared_v}
    return logits, new_cache, aux


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy, float32; logits (B, S, V), labels (B, S)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


@functools.lru_cache(maxsize=None)
def _xent_with_bwd_dtype(dtype_name: str):
    """Cross-entropy whose backward emits ``dtype_name`` cotangents.

    The softmax-xent gradient is (softmax(z) - onehot)/count — every entry
    in [-1, 1], perfectly representable in bf16 — but jax's automatic VJP
    inherits float32 from the f32 loss math, which doubles the width of the
    ENTIRE backward pass: every activation-grad all-reduce (TP), every
    gradient reduce-scatter (FSDP), every remat fusion.  This custom VJP
    confines f32 to the loss statistics (still exact) and hands the model a
    half-width cotangent.  §Perf hillclimb lever.
    """
    dt = jnp.dtype(dtype_name)

    @jax.custom_vjp
    def xent(logits, labels):
        return softmax_xent(logits, labels)

    def fwd(logits, labels):
        return softmax_xent(logits, labels), (logits, labels)

    def bwd(res, g):
        logits, labels = res
        z = logits.astype(jnp.float32)
        p = jax.nn.softmax(z, axis=-1)
        onehot = jax.nn.one_hot(labels, z.shape[-1], dtype=jnp.float32)
        count = labels.size
        dlogits = ((p - onehot) * (g / count)).astype(dt)
        import numpy as _np
        return dlogits, _np.zeros(labels.shape, jax.dtypes.float0)

    xent.defvjp(fwd, bwd)
    return xent


def make_loss_fn(cfg: ModelConfig, ctx=None):
    xent = (softmax_xent if cfg.grad_dtype == "float32"
            else _xent_with_bwd_dtype(cfg.grad_dtype))

    def loss_fn(params, batch):
        logits, _, aux = forward(
            params, batch["tokens"], cfg, ctx=ctx,
            patch_embeds=batch.get("patch_embeds"), train=True)
        loss = xent(logits[:, :-1], batch["labels"][:, 1:])
        return loss + aux, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer, ctx=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg, ctx)

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (total, metrics), grads = grad_fn(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        gnorm = optimizer.global_norm(grads)
        metrics = dict(metrics, total_loss=total, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx=None, max_len: Optional[int] = None):
    """(params, tokens[, patch_embeds]) -> (next_token_logits, cache)."""
    def prefill(params, tokens, patch_embeds=None):
        B, S = tokens.shape
        cache = init_cache(cfg, B, max_len or cfg.max_cache_len or S)
        logits, cache, _ = forward(params, tokens, cfg, ctx=ctx, cache=cache,
                                   patch_embeds=patch_embeds)
        return logits[:, -1, :], cache
    return prefill


def make_decode_step(cfg: ModelConfig, ctx=None):
    """(params, cache, token (B,1)) -> (logits (B, V), cache)."""
    def decode(params, cache, token):
        logits, cache, _ = forward(params, token, cfg, ctx=ctx, cache=cache)
        return logits[:, -1, :], cache
    return decode


# --------------------------------------------------------------------------
# analytics
# --------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    import math
    return sum(math.prod(s.shape) for s in
               jax.tree.leaves(abstract_params(cfg)))


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k experts only; interleaved MoE
    counts only the L//moe_every layers that actually have experts)."""
    total = count_params(cfg)
    if not cfg.n_experts:
        return total
    d, ff, E, K = cfg.d_model, cfg.expert_d_ff, cfg.n_experts, cfg.experts_per_token
    n_moe_layers = cfg.n_layers // cfg.moe_every
    expert_params_per_layer = 3 * d * ff
    inactive = n_moe_layers * (E - K) * expert_params_per_layer
    return total - inactive


def model_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """Forward matmul FLOPs per token (the 6·N·D convention divides into
    2·N_active fwd + 4·N_active bwd; attention adds the S-dependent term)."""
    d, hd = cfg.d_model, cfg.head_dim
    f = 0.0
    for plan in cfg.layer_plan:
        if plan == "attn":
            f += 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd  # qkv
            f += 2 * cfg.n_heads * hd * d                         # out
            f += 2 * 2 * cfg.n_heads * hd * seq_len / 2           # scores+pv (causal avg)
            if cfg.n_experts:
                f += moe_flops_per_token(cfg)
            else:
                mult = 3 if cfg.mlp_act == "swiglu" else 2
                f += mult * 2 * d * cfg.d_ff
        else:
            f += ssm_flops_per_token(cfg, "mamba1" if plan == "mamba1" else "mamba2")
            if "shared_attn" in plan:
                f += 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
                f += 2 * cfg.n_heads * hd * d
                f += 2 * 2 * cfg.n_heads * hd * seq_len / 2
    f += 2 * d * cfg.vocab_size          # unembed
    return f
