"""Encoder-decoder transformer (Whisper-style backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, enc_seq, d_model) directly to the encoder.
Decoder uses learned positional embeddings, LayerNorm, GeLU MLP, biases —
i.e. ``cfg.norm_type='layernorm', mlp_act='gelu', qkv_bias=True,
use_rope=False`` as set by ``configs/whisper_tiny.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Builder, init_attention, attention_block, init_mlp,
                     mlp_block, init_norm, apply_norm, init_embed,
                     embed_tokens, unembed, shard_act, maybe_scan)


def _build(cfg: ModelConfig, b: Builder) -> Dict:
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    return {
        "embed": init_embed(b, cfg),
        "enc_pos": b.p("enc_pos", (cfg.enc_seq, cfg.d_model), (None, "embed"),
                       scale=0.02),
        "enc_layers": {
            "attn": init_attention(b, "enc/attn", cfg, stacked=Le),
            "norm1": init_norm(b, "enc/norm1", cfg, stacked=Le),
            "mlp": init_mlp(b, "enc/mlp", cfg, stacked=Le),
            "norm2": init_norm(b, "enc/norm2", cfg, stacked=Le),
        },
        "enc_norm": init_norm(b, "enc_norm", cfg),
        "dec_layers": {
            "self_attn": init_attention(b, "dec/self_attn", cfg, stacked=Ld),
            "norm1": init_norm(b, "dec/norm1", cfg, stacked=Ld),
            "cross_attn": init_attention(b, "dec/cross_attn", cfg, stacked=Ld),
            "normx": init_norm(b, "dec/normx", cfg, stacked=Ld),
            "mlp": init_mlp(b, "dec/mlp", cfg, stacked=Ld),
            "norm2": init_norm(b, "dec/norm2", cfg, stacked=Ld),
        },
        "dec_norm": init_norm(b, "dec_norm", cfg),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    return _build(cfg, Builder(cfg, key, mode="init"))


def logical_axes(cfg: ModelConfig) -> Dict:
    return _build(cfg, Builder(cfg, mode="axes"))


def abstract_params(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# --------------------------------------------------------------------------

def encode(params: Dict, frames: jax.Array, cfg: ModelConfig, ctx=None) -> jax.Array:
    """frames: (B, enc_seq, d_model) — stub frontend output."""
    B, S, d = frames.shape
    x = frames.astype(cfg.cdtype) + params["enc_pos"][None, :S].astype(cfg.cdtype)
    x = shard_act(x, ("batch", "seq", "d_model"), ctx)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, lp):
        h = apply_norm(x, lp["norm1"], cfg)
        h, _ = attention_block(lp["attn"], h, cfg, positions=positions,
                               causal=False, ctx=ctx)
        x = x + h
        h = apply_norm(x, lp["norm2"], cfg)
        x = x + mlp_block(lp["mlp"], h, cfg, ctx=ctx)
        return shard_act(x, ("batch", "seq", "d_model"), ctx), None

    x, _ = maybe_scan(cfg, body, x, params["enc_layers"], cfg.n_enc_layers)
    return apply_norm(x, params["enc_norm"], cfg)


def cross_kv(params: Dict, enc_out: jax.Array, cfg: ModelConfig) -> Dict:
    """Precompute per-layer cross-attention K/V: (L, B, enc_seq, KH, hd)."""
    cd = cfg.cdtype
    B, S, _ = enc_out.shape
    KH, hd = cfg.n_kv_heads, cfg.head_dim

    def one(lp):
        k = jnp.einsum("bsd,dh->bsh", enc_out, lp["wk"].astype(cd))
        v = jnp.einsum("bsd,dh->bsh", enc_out, lp["wv"].astype(cd))
        if "bk" in lp:
            k = k + lp["bk"].astype(cd)
            v = v + lp["bv"].astype(cd)
        return {"k": k.reshape(B, S, KH, hd), "v": v.reshape(B, S, KH, hd)}

    return jax.vmap(one)(params["dec_layers"]["cross_attn"])


def decoder_forward(params: Dict, tokens: jax.Array, xkv: Dict,
                    cfg: ModelConfig, *, ctx=None,
                    cache: Optional[Dict] = None
                    ) -> Tuple[jax.Array, Optional[Dict]]:
    B, S = tokens.shape
    pos0 = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(pos0[None, None] + jnp.arange(S)[None], (B, S))
    x = embed_tokens(params["embed"], tokens, cfg, positions)
    x = shard_act(x, ("batch", "seq", "d_model"), ctx)
    use_cache = cache is not None

    def body(carry, xs):
        x = carry
        lp, lkv, lcache = xs["params"], xs["xkv"], xs.get("cache")
        h = apply_norm(x, lp["norm1"], cfg)
        self_cache = ({"k": lcache["k"], "v": lcache["v"]}
                      if use_cache else None)
        h, nc = attention_block(lp["self_attn"], h, cfg, positions=positions,
                                cache=self_cache, cache_pos=pos0,
                                causal=True, ctx=ctx)
        x = x + h
        h = apply_norm(x, lp["normx"], cfg)
        h, _ = attention_block(lp["cross_attn"], h, cfg, positions=positions,
                               kv_override=(lkv["k"], lkv["v"]),
                               causal=False, ctx=ctx)
        x = x + h
        h = apply_norm(x, lp["norm2"], cfg)
        x = x + mlp_block(lp["mlp"], h, cfg, ctx=ctx)
        return shard_act(x, ("batch", "seq", "d_model"), ctx), nc

    xs: Dict[str, Any] = {"params": params["dec_layers"], "xkv": xkv}
    if use_cache:
        xs["cache"] = cache["layers"]
    x, layer_caches = maybe_scan(cfg, body, x, xs, cfg.n_layers)
    x = apply_norm(x, params["dec_norm"], cfg)
    logits = unembed(params["embed"], x, cfg)
    new_cache = None
    if use_cache:
        new_cache = {"pos": pos0 + S, "layers": layer_caches, "xkv": xkv}
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict:
    dt = dtype or cfg.cdtype
    L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "pos": jnp.zeros((), jnp.int32),
        "layers": {"k": jnp.zeros((L, batch, max_len, KH, hd), dt),
                   "v": jnp.zeros((L, batch, max_len, KH, hd), dt)},
        "xkv": {"k": jnp.zeros((L, batch, cfg.enc_seq, KH, hd), dt),
                "v": jnp.zeros((L, batch, cfg.enc_seq, KH, hd), dt)},
    }


def cache_logical_axes(cfg: ModelConfig) -> Dict:
    kv = {"k": ("layers", "batch", "kv_seq", "kv_heads", None),
          "v": ("layers", "batch", "kv_seq", "kv_heads", None)}
    return {"pos": (), "layers": dict(kv), "xkv": dict(kv)}


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, ctx=None):
    from .transformer import softmax_xent

    def loss_fn(params, batch):
        enc_out = encode(params, batch["frames"], cfg, ctx=ctx)
        xkv = cross_kv(params, enc_out, cfg)
        logits, _ = decoder_forward(params, batch["tokens"], xkv, cfg, ctx=ctx)
        loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:])
        return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}
    return loss_fn


def make_prefill_step(cfg: ModelConfig, ctx=None, max_len: Optional[int] = None):
    def prefill(params, tokens, frames):
        B, S = tokens.shape
        enc_out = encode(params, frames, cfg, ctx=ctx)
        xkv = cross_kv(params, enc_out, cfg)
        cache = init_cache(cfg, B, max_len or cfg.max_cache_len or S)
        cache["xkv"] = xkv
        logits, cache = decoder_forward(params, tokens, xkv, cfg, ctx=ctx,
                                        cache=cache)
        return logits[:, -1, :], cache
    return prefill


def make_decode_step(cfg: ModelConfig, ctx=None):
    def decode(params, cache, token):
        logits, cache = decoder_forward(params, token, cache["xkv"], cfg,
                                        ctx=ctx, cache=cache)
        return logits[:, -1, :], cache
    return decode
