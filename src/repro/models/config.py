"""Unified model configuration covering the whole assigned pool.

One ``ModelConfig`` describes every architecture family (dense / MoE / SSM /
hybrid / enc-dec / VLM-audio backbones) through a per-layer ``layer_plan``;
``src/repro/configs/<arch>.py`` instantiates the exact published configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None         # default: d_model // n_heads
    # ---- attention options
    qk_norm: bool = False                  # per-head RMSNorm on q,k (qwen3)
    qkv_bias: bool = False                 # (qwen2)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # ---- MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1                     # MoE on every k-th layer (llama4: 2)
    moe_d_ff: Optional[int] = None         # expert hidden dim (defaults d_ff)
    n_shared_experts: int = 0              # always-on experts (llama4 style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group: int = 4096                  # tokens per dispatch group (GShard)
    # ---- SSM (mamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64                 # mamba2 (SSD) head size
    ssm_chunk: int = 256                   # SSD chunk length
    # ---- layer plan: per-layer block type; empty = all "attn" (or "mamba1"
    #      for family=="ssm").  Valid: attn, mamba1, mamba2, shared_attn.
    layer_plan: Tuple[str, ...] = ()
    shared_attn_every: int = 0             # zamba2: shared block cadence
    # ---- encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                    # stub frontend sequence length
    # ---- modality frontend stub: none | vision | audio
    frontend: str = "none"
    n_patches: int = 0                     # vlm: patch embeddings per sample
    # ---- numerics / policy
    scan_layers: bool = True               # False: unroll the layer loop
    #   (dry-run cost probes: XLA cost_analysis counts a scan body ONCE, so
    #    per-layer costs are measured on small unrolled models and
    #    extrapolated to full depth — see launch/dryrun.py)
    mlp_act: str = "swiglu"                # swiglu | gelu
    norm_type: str = "rmsnorm"             # rmsnorm | layernorm
    use_rope: bool = True                  # whisper uses learned abs-pos
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "selective"               # none | selective | full
    logit_softcap: float = 0.0
    grad_dtype: str = "float32"            # "bfloat16": custom-vjp xent emits
    #   bf16 cotangents so the whole backward (and its TP/FSDP collectives)
    #   runs at half width — §Perf hillclimb lever, off by default to keep
    #   the paper-faithful baseline
    shard_grads: bool = False              # constrain grads to the param
    #   shardings so the DP gradient reduction lowers as reduce-scatter
    #   (1× wire) instead of all-reduce (2× wire) — §Perf hillclimb lever
    gqa_grouped: bool = False              # GQA via grouped einsum instead
    #   of jnp.repeat(k/v): never materializes the expanded K/V, so the
    #   sharded KV cache is contracted in place — §Perf hillclimb lever
    ssd_bf16: bool = False                 # Mamba2 SSD intra-chunk tensors
    #   and matmuls in bf16 (f32 states/decays/accumulation — the reference
    #   Mamba2 training recipe) — §Perf hillclimb lever
    kv_cache_dtype: str = "compute"        # "int8": store the attention KV
    #   cache quantized per (token, head) with bf16 scales — halves the
    #   decode weight+cache read floor (§Perf cell B follow-up)
    # ---- serving
    max_cache_len: int = 0                 # set by the shape cell

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.layer_plan:
            default = {"ssm": "mamba1", "hybrid": "mamba2"}.get(self.family, "attn")
            plan = [default] * self.n_layers
            if self.shared_attn_every:
                for i in range(self.n_layers):
                    if (i + 1) % self.shared_attn_every == 0:
                        plan[i] = "mamba2+shared_attn"
            object.__setattr__(self, "layer_plan", tuple(plan))
        assert len(self.layer_plan) == self.n_layers

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_inner(self) -> int:      # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:  # mamba2 heads
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def uses_attention(self) -> bool:
        return any("attn" in p for p in self.layer_plan) or self.is_encoder_decoder

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context (500k) cells are runnable: no full-attention
        layer whose KV cache would be materialized at full seq length —
        SSM/hybrid qualify (hybrid's few shared-attn sites use a bounded
        sliding window at 500k; see transformer.py)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: same family/topology, tiny sizes."""
        small = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            moe_d_ff=128 if self.n_experts else None,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            layer_plan=(),
            param_dtype="float32",
            compute_dtype="float32",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}
