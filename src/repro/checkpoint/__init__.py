"""Checkpointing: array-tree store (jax-backed) + control-plane run log.

``repro.checkpoint.store`` imports jax at module scope; the cluster
driver and its workers only need :mod:`repro.checkpoint.runlog`, so the
store's names are re-exported lazily (PEP 562) to keep the accelerator
runtime out of control-plane processes.
"""
from .runlog import (RunLog, RunState, load_run, latest_run,  # noqa: F401
                     graph_fingerprint, plan_fingerprint)

_STORE_NAMES = ("save_checkpoint", "restore_checkpoint", "latest_step",
                "AsyncCheckpointer", "CheckpointManager")

__all__ = list(_STORE_NAMES) + [
    "RunLog", "RunState", "load_run", "latest_run",
    "graph_fingerprint", "plan_fingerprint",
]


def __getattr__(name):
    if name in _STORE_NAMES:
        from . import store
        return getattr(store, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
