"""Checkpointing: sharded-friendly, async, restart- and reshard-safe.

Layout per step::

    <dir>/step_000042/
        manifest.json      # pytree structure, shapes, dtypes, logical axes
        arr_00000.npz ...  # leaf payloads, chunked

Restore rebuilds the pytree on host, then (optionally) ``jax.device_put``'s
each leaf to a target sharding — so a checkpoint written on one mesh shape
restores onto another (elastic rescale): logical axes live in the manifest,
the new mesh's rule table decides the new physical layout.

:class:`AsyncCheckpointer` snapshots to host memory synchronously (cheap)
and writes to disk on a background thread — keeping the save off the train
step's critical path (overlap trick #3 in DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """np.dtype by name, including ml_dtypes extras (bfloat16, fp8 ...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode(arr: np.ndarray) -> np.ndarray:
    """npz cannot round-trip ml_dtypes arrays — store them as a same-width
    uint view; the manifest keeps the logical dtype for decode."""
    if arr.dtype.name not in np.sctypeDict:
        return arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
    return arr


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    want = _np_dtype(dtype_name)
    if arr.dtype != want and arr.dtype.itemsize == want.itemsize \
            and arr.dtype.kind in ("u", "V"):
        return arr.view(want)
    return arr


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None,
                    chunk_leaves: int = 64) -> str:
    """Write ``tree`` (params/opt state/... pytree) atomically."""
    paths, leaves, _ = _flatten_with_paths(tree)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": [],
        "n_files": 0,
    }
    for i in range(0, len(leaves), chunk_leaves):
        fname = f"arr_{i // chunk_leaves:05d}.npz"
        payload = {}
        for j, (p, leaf) in enumerate(
                zip(paths[i:i + chunk_leaves], leaves[i:i + chunk_leaves])):
            arr = np.asarray(leaf)
            payload[f"a{j}"] = _encode(arr)
            manifest["leaves"].append(
                {"path": p, "file": fname, "key": f"a{j}",
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        np.savez(os.path.join(tmp, fname), **payload)
        manifest["n_files"] += 1
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)          # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int] = None,
                       target: Any = None, shardings: Any = None):
    """Returns (tree, extra).  ``target`` provides the pytree structure;
    ``shardings`` (same structure) device_puts each leaf (resharding)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_file: Dict[str, Any] = {}
    values: Dict[str, np.ndarray] = {}
    for leaf in manifest["leaves"]:
        if leaf["file"] not in by_file:
            by_file[leaf["file"]] = np.load(os.path.join(d, leaf["file"]))
        values[leaf["path"]] = _decode(by_file[leaf["file"]][leaf["key"]],
                                       leaf["dtype"])

    if target is None:
        return values, manifest["extra"]

    paths, leaves, treedef = _flatten_with_paths(target)
    out = []
    flat_shardings = [None] * len(paths)
    if shardings is not None:
        _, flat_shardings, _ = _flatten_with_paths(shardings)
    for p, ref, shd in zip(paths, leaves, flat_shardings):
        if p not in values:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = values[p]
        want = tuple(ref.shape) if hasattr(ref, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {want}")
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree.unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, persist on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()                                  # one in flight at a time
        # device→host snapshot; np.array (not asarray) so host-resident
        # leaves are COPIED — the caller may mutate them after save()
        host_tree = jax.tree.map(lambda x: np.array(x), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self.saved_steps.append(step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(s for d in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d))
                       for s in [int(m.group(1))])
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)


class CheckpointManager:
    """Save-every-N policy + resume helper used by ``launch/train.py``."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.every = every
        self.ckpt = AsyncCheckpointer(directory, keep)
        self.async_save = async_save
        self.directory = directory

    def maybe_save(self, step: int, tree: Any, extra=None) -> bool:
        if step % self.every != 0:
            return False
        if self.async_save:
            self.ckpt.save(step, tree, extra)
        else:
            save_checkpoint(self.directory, step, jax.tree.map(np.asarray, tree),
                            extra)
        return True

    def restore_latest(self, target: Any, shardings: Any = None):
        return restore_checkpoint(self.directory, None, target, shardings)

    def finish(self) -> None:
        self.ckpt.wait()
