"""Append-only run log: the driver's durable control-plane state.

The :class:`ClusterExecutor` driver keeps all ownership metadata — which
worker holds which value, per-value sizes, refcounts, the execution
frontier — in its own heap.  PRs 1-5 made *worker* death survivable via
lineage; this module makes *driver* death survivable by journaling that
metadata as it changes.

Design constraints, in order:

1. **Hot-path cost must be flat in worker count.**  Records are deltas
   keyed by *events* (a cluster completed, a handle became durable), not
   snapshots of per-worker state.  A 64-worker run writes the same number
   of bytes per completion as a 2-worker run.
2. **SIGKILL-safe.**  The log is append-only, length-prefixed, and
   fsync'd on a timer.  A driver killed mid-write leaves at most one
   *torn tail* record, which the loader detects and truncates; a driver
   killed between flushes loses at most ``interval`` seconds of claims.
   Claims are monotone over a *pure* graph — a stale claim is reconciled
   against worker inventory at resume and replayed via lineage, never
   trusted blindly — so losing the tail is a performance cost, not a
   correctness one.
3. **No heavyweight deps.**  Unlike :mod:`repro.checkpoint.store` (array
   trees, jax), the run log is pickled control metadata only; workers
   and the resume path must be able to import it without pulling in an
   accelerator runtime.

Record kinds (a tuple per record, first element the kind tag):

=========  ===============================================================
``begin``  ``(meta,)`` — run identity: graph/plan fingerprints, fuse
           spec, listener address, channel, seg prefix.  Always first.
``resume`` ``(meta,)`` — a new driver incarnation appended to the log;
           carries its fresh ``seg_prefix`` so every incarnation's shm
           segments can be swept at final shutdown.
``worker`` ``(wid, host)`` — a worker was adopted (or re-adopted).
``dead``   ``(wid,)`` — a worker's loss was confirmed and recovered.
``done``   ``(cid, wid, sizes)`` — cluster ``cid`` completed on ``wid``
           producing ``{tid: nbytes}``.  The hot-path record.
``redo``   ``(cids,)`` — recovery demoted these clusters; their ``done``
           claims are retracted.
``refuse`` ``(retired, clusters)`` — adaptive re-fusion replaced the
           not-yet-dispatched ``retired`` cluster ids with ``clusters``
           (``(cid, member_tids)`` pairs).  Replayed in order on resume
           so journaled ``done`` claims of post-refusion cids resolve
           against the same plan that produced them (docs/adaptive.md).
``gc``     ``(tids,)`` — values dropped by the consumed-refcount GC.
``live``   ``(tids,)`` — recovery retracted GC marks; the values are
           being recomputed and are no longer "gone everywhere".
``hnd``    ``(tid, handle_bytes)`` — a *durable* handle (inline bytes or
           an shm segment that outlives the driver) for ``tid``.
``val``    ``(tid, value_bytes)`` — a driver-cached value (barrier
           results, collected finals) spilled into the log itself.
``session``  ``(tenant, info)`` — a gateway tenant session opened (or its
           quotas changed); ``info`` carries the quota/config dict.  A
           resumed gateway re-creates these sessions so clients reconnect
           into their old identity.
``sessionend``  ``(tenant,)`` — the session was closed by the client.
``job``    ``(job_id, info)`` — a tenant job was admitted into the
           resident run; ``info`` records tenant, id base and size.
``jobdone``  ``(job_id,)`` — the job finished (collected or failed) and
           its id range was retired.
=========  ===============================================================

Loaders skip unknown kinds (forward compatibility), so logs carrying the
gateway records stay readable by older tooling.
"""
from __future__ import annotations

import hashlib
import io
import os
import pickle
import struct
import time
from typing import Any, Dict, List, Optional, Set, Tuple

_LEN = struct.Struct(">I")

__all__ = ["RunLog", "RunState", "load_run", "latest_run",
           "graph_fingerprint", "plan_fingerprint"]


# --------------------------------------------------------------- identity

def graph_fingerprint(graph) -> str:
    """Stable digest of the task graph's *shape* (names + dependency
    structure + kinds).  Function bodies are deliberately excluded: a
    resumed driver re-imports the same code, and pickling closures here
    would make fingerprints fragile across interpreter runs."""
    h = hashlib.sha1()
    for tid in sorted(graph.nodes):
        n = graph.nodes[tid]
        h.update(repr((tid, n.name, tuple(n.all_deps),
                       getattr(n.kind, "name", str(n.kind)))).encode())
    h.update(repr(sorted(graph.outputs)).encode())
    return h.hexdigest()


def plan_fingerprint(plan) -> str:
    """Digest of the fused plan: cluster membership and the cluster DAG.
    Fusion is deterministic, so a resumed driver with the same graph and
    fuse spec reproduces this exactly — a mismatch means the checkpoint's
    cluster ids don't mean what we think they mean."""
    h = hashlib.sha1()
    for cid in sorted(plan.members):
        deps = tuple(sorted(plan.cgraph.nodes[cid].all_deps))
        h.update(repr((cid, tuple(plan.members[cid]), deps)).encode())
    return h.hexdigest()


# ------------------------------------------------------------------ writer

class RunLog:
    """Buffered append-only writer with timed fsync.

    ``append()`` is called from the driver's dispatch hot path and only
    pickles into an in-memory buffer; ``maybe_flush()`` is called from
    the pump loop and pays the write+fsync at most once per
    ``interval`` seconds (or when the buffer grows past ``max_buffer``).
    """

    def __init__(self, path: str, interval: float = 0.25,
                 max_buffer: int = 1 << 20):
        self.path = path
        self.interval = interval
        self.max_buffer = max_buffer
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")
        self._buf = io.BytesIO()
        self._last_flush = time.monotonic()
        self.bytes_written = 0
        self.n_records = 0

    def append(self, *record: Any) -> None:
        payload = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
        self._buf.write(_LEN.pack(len(payload)))
        self._buf.write(payload)
        self.n_records += 1

    def maybe_flush(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        if self._buf.tell() == 0:
            return False
        if (now - self._last_flush < self.interval
                and self._buf.tell() < self.max_buffer):
            return False
        self.flush()
        return True

    def flush(self) -> None:
        data = self._buf.getvalue()
        if data:
            self._f.write(data)
            self._f.flush()
            os.fsync(self._f.fileno())
            self.bytes_written += len(data)
            self._buf = io.BytesIO()
        self._last_flush = time.monotonic()

    def close(self) -> None:
        try:
            self.flush()
        finally:
            self._f.close()


# ------------------------------------------------------------------ loader

class RunState:
    """Replayed view of a run log: the last-known control-plane state."""

    def __init__(self) -> None:
        self.meta: Dict[str, Any] = {}
        self.seg_prefixes: List[str] = []
        self.workers: Dict[int, str] = {}          # wid -> host
        self.dead: Set[int] = set()
        self.done: Dict[int, Tuple[int, Dict[int, int]]] = {}
        self.dropped: Set[int] = set()
        self.handles: Dict[int, bytes] = {}        # tid -> pickled handle
        self.values: Dict[int, bytes] = {}         # tid -> pickled value
        self.sessions: Dict[str, Dict[str, Any]] = {}   # tenant -> quotas
        self.jobs: Dict[int, Dict[str, Any]] = {}  # in-flight admitted jobs
        # adaptive re-fusion decisions, in journal order: each entry is
        # (retired_cids, ((cid, member_tids), ...)) — replayed through
        # fusion.splice_plan before the resume frontier is seeded
        self.refusions: List[Tuple[Tuple[int, ...], Tuple]] = []
        self.truncated = False                     # torn tail was cut
        self.n_records = 0

    @property
    def live_workers(self) -> Dict[int, str]:
        return {w: h for w, h in self.workers.items() if w not in self.dead}

    def apply(self, record: Tuple[Any, ...]) -> None:
        kind = record[0]
        if kind == "begin":
            self.meta = dict(record[1])
            self.seg_prefixes.append(self.meta["seg_prefix"])
        elif kind == "resume":
            self.seg_prefixes.append(record[1]["seg_prefix"])
        elif kind == "worker":
            self.workers[record[1]] = record[2]
            self.dead.discard(record[1])
        elif kind == "dead":
            self.dead.add(record[1])
        elif kind == "done":
            self.done[record[1]] = (record[2], dict(record[3]))
        elif kind == "redo":
            for cid in record[1]:
                self.done.pop(cid, None)
        elif kind == "refuse":
            self.refusions.append((tuple(record[1]), tuple(record[2])))
        elif kind == "gc":
            self.dropped.update(record[1])
        elif kind == "live":
            # recovery retracted GC marks: these values are being
            # recomputed, so a resume must not treat them as swept
            self.dropped.difference_update(record[1])
        elif kind == "hnd":
            self.handles[record[1]] = record[2]
        elif kind == "val":
            self.values[record[1]] = record[2]
        elif kind == "session":
            self.sessions[record[1]] = dict(record[2])
        elif kind == "sessionend":
            self.sessions.pop(record[1], None)
        elif kind == "job":
            self.jobs[record[1]] = dict(record[2])
        elif kind == "jobdone":
            self.jobs.pop(record[1], None)
        # unknown kinds are skipped: forward compatibility
        self.n_records += 1


def load_run(path: str, repair: bool = True) -> RunState:
    """Replay ``path`` into a :class:`RunState`, truncating a torn tail.

    A driver SIGKILL'd mid-``flush`` can leave a partial final record
    (short length prefix, short payload, or an unpicklable payload).
    Everything before the tear is intact — the file is append-only — so
    the loader keeps the longest clean prefix and (when ``repair``)
    truncates the file to it, making the next append well-formed.
    """
    state = RunState()
    good = 0
    with open(path, "rb") as f:
        while True:
            head = f.read(_LEN.size)
            if len(head) < _LEN.size:
                state.truncated = bool(head)
                break
            (n,) = _LEN.unpack(head)
            payload = f.read(n)
            if len(payload) < n:
                state.truncated = True
                break
            try:
                record = pickle.loads(payload)
            except Exception:
                state.truncated = True
                break
            state.apply(record)
            good = f.tell()
    if state.truncated and repair:
        with open(path, "r+b") as f:
            f.truncate(good)
    if not state.meta:
        raise ValueError(f"run log {path!r} has no intact 'begin' record")
    return state


def latest_run(checkpoint_dir: str) -> Optional[str]:
    """Most recently modified run id under ``checkpoint_dir``."""
    best, best_t = None, -1.0
    try:
        names = os.listdir(checkpoint_dir)
    except FileNotFoundError:
        return None
    for name in names:
        if not name.endswith(".log"):
            continue
        t = os.path.getmtime(os.path.join(checkpoint_dir, name))
        if t > best_t:
            best, best_t = name[:-4], t
    return best
