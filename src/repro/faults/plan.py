"""Deterministic, seeded fault plans for the control and data planes.

A :class:`FaultPlan` is a replayable description of *what goes wrong* in a
run: a seed plus a list of :class:`FaultRule`\\ s addressed by
``(src, dst, verb, nth-match)``.  The same plan against the same workload
injects the same faults — so a chaos failure found by a randomized soak is
reproduced by re-running its logged seed, and a test pins an exact failure
sequence instead of hoping a sleep races the right way.

Rules fire at two injection points:

* **Frames** — :class:`~repro.faults.wrappers.FaultyChannel` consults
  :meth:`frame_actions` for every control-plane message it carries, in
  both directions.  Actions: ``drop`` (the frame vanishes — sensible for
  keepalives; the control verbs assume TCP's reliable-or-dead contract),
  ``delay`` (held ``delay`` seconds, order preserved), ``dup`` (delivered
  twice — handlers must be idempotent), ``reorder`` (swapped with the
  next frame on the link), and ``sever`` (the matching frame *starts a
  timed partition*: for ``window`` seconds every frame in both directions
  is withheld and delivered when the window closes, exactly what a
  transient network partition does to an established TCP stream).
* **Peer fetches** — :meth:`fetch_hook` returns a per-worker callback
  installed into :func:`repro.cluster.serde.peer_fetch`; ``fail_fetch``
  rules make the matched transfer attempt raise ``TransferLost``
  (``nth=N`` fails exactly the Nth matching attempt), ``delay`` rules
  stall it.

Determinism: each rule draws from its own ``random.Random`` seeded by
``(plan seed, rule index)``, and ``nth`` counters are kept per concrete
``(rule, src, dst)`` link — so concurrency elsewhere in the run cannot
perturb which frame a rule hits.  Plans pickle cleanly (state resets in
the new process: a worker's copy counts its own fetch attempts, which is
exactly the addressing the fetch rules use).
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

__all__ = ["FaultRule", "FaultPlan", "ACTIONS"]

ACTIONS = ("drop", "delay", "dup", "reorder", "sever", "fail_fetch")

#: wildcard matching any endpoint / verb
ANY = "*"


@dataclass(frozen=True)
class FaultRule:
    """One addressable fault: *when* frames matching ``(src, dst, verb)``
    pass, fire ``action`` on the ``nth`` match (1-based; ``None`` means
    every match, gated by ``prob``), at most ``count`` times total per
    link (``None`` = unlimited)."""

    action: str
    src: Any = ANY              # "driver", a worker id, or "*"
    dst: Any = ANY
    verb: str = ANY             # frame verb ("done", "hb", ...) or
    #                             "peer_fetch" for data-plane rules
    nth: Optional[int] = None   # fire on the Nth match of this rule
    prob: float = 1.0           # else fire per-match with this probability
    count: Optional[int] = None  # max firings per link (None = unlimited;
    #                              an ``nth`` rule defaults to firing once)
    delay: float = 0.05         # seconds (delay action)
    window: float = 1.0         # partition length in seconds (sever action)

    def __post_init__(self) -> None:
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} "
                             f"(expected one of {ACTIONS})")
        if self.nth is not None and self.nth < 1:
            raise ValueError("nth is 1-based")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError("prob must be in [0, 1]")

    def matches(self, src: Any, dst: Any, verb: str) -> bool:
        return ((self.src == ANY or self.src == src)
                and (self.dst == ANY or self.dst == dst)
                and (self.verb == ANY or self.verb == verb))


def _link(a: Any, b: Any) -> FrozenSet[Any]:
    return frozenset((a, b))


@dataclass
class FaultPlan:
    """A seeded set of fault rules plus the runtime state that makes them
    deterministic.  Build with the fluent helpers::

        plan = (FaultPlan(seed=7)
                .drop(verb="hb", prob=0.5)
                .sever(src=2, dst="driver", verb="done", nth=2, window=3.0)
                .fail_fetch(dst=1, nth=1))
    """

    seed: int = 0
    rules: List[FaultRule] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        # per-(rule idx, link) match and fire counters
        self._matches: Dict[Tuple[int, FrozenSet[Any]], int] = {}
        self._fired: Dict[Tuple[int, FrozenSet[Any]], int] = {}
        self._rngs: Dict[int, random.Random] = {}
        # active partitions: link -> monotonic end time
        self._severed: Dict[FrozenSet[Any], float] = {}
        self._stats: Dict[str, int] = {}

    # pickling ships the *description*; counters restart in the new
    # process (each process addresses its own injection points)
    def __getstate__(self) -> dict:
        return {"seed": self.seed, "rules": list(self.rules)}

    def __setstate__(self, state: dict) -> None:
        self.seed = state["seed"]
        self.rules = state["rules"]
        self.__post_init__()

    # ------------------------------------------------------ rule builders
    def add(self, rule: FaultRule) -> "FaultPlan":
        self.rules.append(rule)
        return self

    def _mk(self, action: str, **kw: Any) -> "FaultPlan":
        if kw.get("nth") is not None and "count" not in kw:
            kw["count"] = 1     # "the Nth match" fires once by default
        return self.add(FaultRule(action=action, **kw))

    def drop(self, **kw: Any) -> "FaultPlan":
        return self._mk("drop", **kw)

    def delay(self, seconds: float = 0.05, **kw: Any) -> "FaultPlan":
        return self._mk("delay", delay=seconds, **kw)

    def duplicate(self, **kw: Any) -> "FaultPlan":
        return self._mk("dup", **kw)

    def reorder(self, **kw: Any) -> "FaultPlan":
        return self._mk("reorder", **kw)

    def sever(self, window: float = 1.0, **kw: Any) -> "FaultPlan":
        return self._mk("sever", window=window, **kw)

    def fail_fetch(self, **kw: Any) -> "FaultPlan":
        kw.setdefault("verb", "peer_fetch")
        return self._mk("fail_fetch", **kw)

    # -------------------------------------------------------- evaluation
    def _rng(self, idx: int) -> random.Random:
        rng = self._rngs.get(idx)
        if rng is None:
            # rule-scoped stream: cross-channel interleaving cannot shift
            # which draws a rule sees
            rng = self._rngs[idx] = random.Random((self.seed << 16) ^ idx)
        return rng

    def frame_actions(self, src: Any, dst: Any, verb: str
                      ) -> List[FaultRule]:
        """Rules that fire for one frame travelling ``src -> dst``.
        Evaluating is the side effect: match counters advance, ``sever``
        firings open their partition window."""
        fired: List[FaultRule] = []
        with self._lock:
            link = _link(src, dst)
            for idx, rule in enumerate(self.rules):
                if rule.action == "fail_fetch":
                    continue            # fetch rules live in fetch_hook
                if not rule.matches(src, dst, verb):
                    continue
                key = (idx, link)
                n = self._matches[key] = self._matches.get(key, 0) + 1
                if not self._should_fire(rule, idx, key, n):
                    continue
                fired.append(rule)
                if rule.action == "sever":
                    end = time.monotonic() + rule.window
                    if end > self._severed.get(link, 0.0):
                        self._severed[link] = end
                self._stats[rule.action] = \
                    self._stats.get(rule.action, 0) + 1
        return fired

    def _should_fire(self, rule: FaultRule, idx: int,
                     key: Tuple[int, FrozenSet[Any]], n: int) -> bool:
        if rule.count is not None and self._fired.get(key, 0) >= rule.count:
            return False
        if rule.nth is not None:
            if n < rule.nth:
                return False
        elif rule.prob < 1.0 and self._rng(idx).random() >= rule.prob:
            return False
        self._fired[key] = self._fired.get(key, 0) + 1
        return True

    def severed(self, a: Any, b: Any) -> Optional[float]:
        """End time (monotonic) of an active partition on link ``{a, b}``,
        or ``None``.  Partitions are symmetric: a severed link withholds
        frames in both directions."""
        with self._lock:
            end = self._severed.get(_link(a, b))
            if end is not None and end <= time.monotonic():
                del self._severed[_link(a, b)]
                return None
            return end

    def fetch_hook(self, wid: Any):
        """Per-worker callback for :func:`repro.cluster.serde.peer_fetch`:
        called as ``hook(ref, attempt)`` at the top of every fetch attempt
        by worker ``wid``.  ``fail_fetch`` rules raise ``TransferLost``
        (marked ``injected``), ``delay`` rules sleep."""

        def hook(ref: Any, attempt: int) -> None:
            owner = getattr(ref, "wid", ANY)
            fired: List[FaultRule] = []
            with self._lock:
                link = _link(wid, owner)
                for idx, rule in enumerate(self.rules):
                    if rule.action not in ("fail_fetch", "delay"):
                        continue
                    if rule.verb not in (ANY, "peer_fetch"):
                        continue
                    if not rule.matches(wid, owner, "peer_fetch"):
                        continue
                    key = (idx, link)
                    n = self._matches[key] = self._matches.get(key, 0) + 1
                    if self._should_fire(rule, idx, key, n):
                        fired.append(rule)
                        self._stats[rule.action] = \
                            self._stats.get(rule.action, 0) + 1
            for rule in fired:
                if rule.action == "delay":
                    time.sleep(rule.delay)
                else:
                    from repro.cluster.serde import TransferLost
                    e = TransferLost(
                        f"fault injection: peer fetch of task "
                        f"{getattr(ref, 'tid', '?')} from worker {owner} "
                        f"failed (rule {rule})")
                    e.injected = True
                    raise e

        return hook

    def stats(self) -> Dict[str, int]:
        """Fired counts per action — what the plan actually did."""
        with self._lock:
            return dict(self._stats)


def scaled(plan: FaultPlan, prob_scale: float) -> FaultPlan:
    """A copy of ``plan`` with every probabilistic rule's ``prob`` scaled
    (clamped to [0, 1]); ``nth`` rules are left exact.  The knob the bench
    matrix turns to sweep loss/delay intensity without rebuilding rules."""
    out = FaultPlan(seed=plan.seed)
    for r in plan.rules:
        out.add(replace(r, prob=max(0.0, min(1.0, r.prob * prob_scale)))
                if r.nth is None else r)
    return out
