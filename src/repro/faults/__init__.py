"""Deterministic fault injection + the policies that survive it.

The network between driver and workers is no longer assumed
perfect-or-dead: this package makes every failure mode *injectable and
replayable* (seeded :class:`FaultPlan` driving channel/listener wrappers
and a peer-fetch hook) and every survival decision *a policy*
(:class:`RetryPolicy` backoff for fetches and dials; the executor's
suspect-vs-dead grace window, relay-fallback degradation, and
quarantine/probe/re-admit scoring are configured knobs, not constants).
See ``docs/faults.md``.
"""
from .plan import ACTIONS, FaultPlan, FaultRule, scaled
from .retry import RetryPolicy
from .wrappers import FaultyChannel, FaultyListener

__all__ = ["ACTIONS", "FaultPlan", "FaultRule", "RetryPolicy",
           "FaultyChannel", "FaultyListener", "scaled"]
