"""RetryPolicy — the one retry/backoff vocabulary for the whole runtime.

Every network-facing operation that can transiently fail (a peer data-plane
fetch, a TCP dial into the driver's listener) retries through one of these
instead of hand-rolled ``while``/``sleep`` loops: bounded attempts,
exponential backoff with jitter (so a thundering herd of consumers retrying
against one recovering owner de-phases instead of re-synchronizing), and an
optional overall deadline that caps the *total* time spent regardless of
how the per-attempt delays add up.

The policy is a frozen description, safe to share across threads and to
pickle into worker config; the mutable state (attempt counter, start time)
lives in each :meth:`run` call.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

__all__ = ["RetryPolicy"]


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff, jitter, and a deadline.

    * ``attempts`` — total tries (1 = no retry).
    * ``base_delay`` — sleep after the first failure, in seconds.
    * ``factor`` — backoff multiplier per further failure.
    * ``max_delay`` — per-sleep ceiling.
    * ``jitter`` — fraction of the computed delay added uniformly at
      random (``0.5`` means each sleep lands in ``[d, 1.5d]``); this is
      what keeps a fleet of retriers from phase-locking.
    * ``deadline`` — optional overall wall budget in seconds, measured
      from the first attempt; once exceeded the last error is raised
      even if attempts remain.
    """

    attempts: int = 3
    base_delay: float = 0.05
    factor: float = 2.0
    max_delay: float = 2.0
    jitter: float = 0.5
    deadline: Optional[float] = None

    def backoff(self, attempt: int, rng: Optional[random.Random] = None
                ) -> float:
        """Sleep before attempt ``attempt+1`` (attempt is 0-based and names
        the try that just failed)."""
        d = min(self.base_delay * (self.factor ** attempt), self.max_delay)
        if self.jitter > 0:
            r = rng.random() if rng is not None else random.random()
            d *= 1.0 + self.jitter * r
        return d

    def run(self, fn: Callable[[int], Any], *,
            retryable: Optional[Callable[[BaseException], bool]] = None,
            on_retry: Optional[Callable[[int, BaseException], None]] = None,
            rng: Optional[random.Random] = None) -> Any:
        """Call ``fn(attempt)`` until it returns, retrying failures.

        ``retryable(exc)`` gates each retry (default: everything retries);
        a non-retryable error, the last attempt's error, or any error past
        the deadline propagates unchanged.  ``on_retry(attempt, exc)`` is
        observability only — exceptions it raises are swallowed.
        """
        start = time.monotonic()
        for attempt in range(max(1, self.attempts)):
            try:
                return fn(attempt)
            except BaseException as e:      # noqa: BLE001 — re-raised below
                last = attempt >= max(1, self.attempts) - 1
                if last or (retryable is not None and not retryable(e)):
                    raise
                delay = self.backoff(attempt, rng)
                if self.deadline is not None:
                    left = self.deadline - (time.monotonic() - start)
                    if left <= 0:
                        raise
                    delay = min(delay, left)
                if on_retry is not None:
                    try:
                        on_retry(attempt, e)
                    except Exception:
                        pass
                time.sleep(max(0.0, delay))
        raise AssertionError("unreachable")     # pragma: no cover
