"""Fault-injecting wrappers over the control-plane Channel/Listener.

:class:`FaultyChannel` decorates any driver-side channel (pipe, spawn, or
TCP) with the faults a :class:`~repro.faults.plan.FaultPlan` prescribes,
conforming to the same protocol the executor already speaks — so every
deployment shape is injectable without touching the transports themselves.

Mechanics worth knowing:

* **Withheld frames stay ordered.**  Delay and sever never reorder: once a
  frame is parked, later frames on the same direction queue behind it
  (release times are monotone per direction) — exactly how a congested or
  partitioned TCP stream behaves.  Only an explicit ``reorder`` rule swaps
  adjacent frames.
* **Delivery without wire traffic.**  A parked inbound frame whose release
  time passes may have no new socket bytes to piggyback on, and the
  driver's ``wait()`` will not report the channel readable.  The wrapper
  therefore exposes ``has_ready()``/``drain_ready()``, and the executor's
  pump drains them every iteration; parked *outbound* frames flush from
  :meth:`maybe_heartbeat`, which the driver loop calls every iteration on
  every live channel.
* **Partitions are visible as silence.**  The wrapper keeps its own
  ``last_delivered`` clock; while a sever window is open (and the wrapped
  transport still looks healthy underneath — bytes do arrive, the wrapper
  just withholds them), :meth:`dead` reports the standard
  ``"no heartbeat for ..."`` verdict once the silence exceeds the
  heartbeat timeout.  The executor's suspect/grace machinery then sees a
  partitioned worker exactly as it would a real one.

:class:`FaultyListener` wraps the driver's accept loop: ``accept``-verb
rules can drop a handshaken dial (the socket closes; the worker's
:class:`~repro.faults.retry.RetryPolicy` re-dials) or delay its adoption.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional, Tuple

from .plan import FaultPlan, FaultRule

__all__ = ["FaultyChannel", "FaultyListener"]

DRIVER = "driver"


class _Direction:
    """Parked frames for one direction of the link (FIFO + one reorder
    hold).  Not locked: both directions are touched only from the driver
    loop thread."""

    __slots__ = ("queue", "hold", "hold_deadline")

    def __init__(self) -> None:
        self.queue: List[Tuple[float, tuple]] = []   # (release, msg)
        self.hold: Optional[tuple] = None            # reorder-held frame
        self.hold_deadline = 0.0

    def park(self, msg: tuple, release: float) -> None:
        if self.queue:
            release = max(release, self.queue[-1][0])   # keep FIFO order
        self.queue.append((release, msg))

    def ripe(self, now: float) -> List[tuple]:
        out: List[tuple] = []
        while self.queue and self.queue[0][0] <= now:
            out.append(self.queue.pop(0)[1])
        if self.hold is not None and now >= self.hold_deadline:
            out.append(self.hold)
            self.hold = None
        return out

    def pending(self, now: float) -> bool:
        return (bool(self.queue) and self.queue[0][0] <= now) or \
            (self.hold is not None and now >= self.hold_deadline)


class FaultyChannel:
    """Driver-side channel decorated with a fault plan.

    ``wid`` names the worker endpoint for rule addressing; the driver end
    is always ``"driver"``.  Every attribute the executor pokes beyond the
    Channel protocol (``proc``, ``kind``, ``sock``, ``last_seen``, ...)
    delegates to the wrapped channel.
    """

    #: max seconds a reorder rule holds a frame waiting for its swap
    #: partner before giving up and delivering it anyway
    REORDER_HOLD = 0.25

    def __init__(self, inner: Any, plan: FaultPlan, wid: Any, *,
                 silence_timeout: Optional[float] = None) -> None:
        self.inner = inner
        self.plan = plan
        self.wid = wid
        self.silence_timeout = (
            silence_timeout if silence_timeout is not None
            else getattr(inner, "heartbeat_timeout", 5.0))
        self._out = _Direction()        # driver -> worker
        self._in = _Direction()         # worker -> driver
        self._last_delivered = time.monotonic()

    def __getattr__(self, name: str) -> Any:
        return getattr(self.inner, name)

    # ------------------------------------------------------------ helpers
    def _severed_until(self) -> Optional[float]:
        return self.plan.severed(DRIVER, self.wid)

    def _apply(self, msg: tuple, src: Any, dst: Any, d: _Direction,
               emit: List[tuple], now: float) -> None:
        """Run one frame through the plan; survivors land in ``emit`` (to
        send/deliver now) or are parked in ``d``."""
        verb = msg[0] if msg else "?"
        rules = self.plan.frame_actions(src, dst, verb)
        sev = self._severed_until()
        if sev is not None:
            # the partition swallows everything, including the frame whose
            # match opened the window; delivery resumes when it closes
            d.park(msg, sev)
            return
        dup = False
        release: Optional[float] = None
        for r in rules:
            if r.action == "drop":
                return
            if r.action == "delay":
                release = max(release or 0.0, now + r.delay)
            elif r.action == "dup":
                dup = True
            elif r.action == "reorder" and d.hold is None \
                    and release is None:
                d.hold = msg
                d.hold_deadline = now + self.REORDER_HOLD
                return
        if release is not None or d.queue:
            d.park(msg, release if release is not None else now)
            if dup:
                d.park(msg, release if release is not None else now)
            return
        emit.append(msg)
        if dup:
            emit.append(msg)
        if d.hold is not None:      # the swap partner passed: release hold
            emit.append(d.hold)
            d.hold = None

    def _flush_out(self, now: float) -> None:
        for msg in self._out.ripe(now):
            self.inner.send(msg)

    # ----------------------------------------------------- write side
    def send(self, msg: tuple) -> None:
        now = time.monotonic()
        self._flush_out(now)
        emit: List[tuple] = []
        self._apply(msg, DRIVER, self.wid, self._out, emit, now)
        for m in emit:
            self.inner.send(m)

    def send_many(self, msgs: List[tuple]) -> None:
        now = time.monotonic()
        self._flush_out(now)
        emit: List[tuple] = []
        for msg in msgs:
            self._apply(msg, DRIVER, self.wid, self._out, emit, now)
        if emit:
            self.inner.send_many(emit)

    def maybe_heartbeat(self) -> None:
        self._flush_out(time.monotonic())
        if self._severed_until() is None:
            self.inner.maybe_heartbeat()
        # during a partition the driver's keepalives are withheld too —
        # the worker-side silence watchdog must see a real outage

    # ------------------------------------------------------ read side
    def selectable(self):
        return self.inner.selectable()

    def recv_available(self) -> List[tuple]:
        now = time.monotonic()
        emit: List[tuple] = []
        for msg in self.inner.recv_available():
            self._apply(msg, self.wid, DRIVER, self._in, emit, now)
        out = self._in.ripe(now) + emit
        if out:
            self._last_delivered = now
        return out

    def has_ready(self) -> bool:
        """Parked inbound frames whose release time has passed (the pump
        drains these even when the wire is silent)."""
        return self._in.pending(time.monotonic())

    def drain_ready(self) -> List[tuple]:
        now = time.monotonic()
        out = self._in.ripe(now)
        if out:
            self._last_delivered = now
        return out

    # ------------------------------------------------------- liveness
    def dead(self) -> Optional[str]:
        r = self.inner.dead()
        if r is not None:
            return r
        if self._severed_until() is not None:
            silent = time.monotonic() - self._last_delivered
            if silent > self.silence_timeout:
                # same verdict string a silent TcpChannel produces, so the
                # executor's silence classifier treats both alike
                return (f"no heartbeat for {silent:.1f}s "
                        f"(timeout {self.silence_timeout}s)")
        return None

    def close(self) -> None:
        # best-effort flush of parked outbound frames (a die/stop queued
        # behind a delay should still reach the worker)
        try:
            for _, msg in self._out.queue:
                self.inner.send(msg)
            if self._out.hold is not None:
                self.inner.send(self._out.hold)
        except Exception:
            pass
        self._out.queue.clear()
        self._out.hold = None
        self.inner.close()


class FaultyListener:
    """Accept-side fault injection: ``verb="accept"`` rules fire per
    handshaken dial.  ``drop`` closes the fresh socket (the worker's dial
    retry policy re-dials), ``delay`` stalls adoption."""

    def __init__(self, inner: Any, plan: FaultPlan) -> None:
        self.inner = inner
        self.plan = plan

    def __getattr__(self, name: str) -> Any:
        return getattr(self.inner, name)

    @property
    def address(self) -> str:
        return self.inner.address

    def _filter(self, pair):
        sock, hello = pair
        src = hello.get("wid", hello.get("pid", "?"))
        for r in self.plan.frame_actions(src, DRIVER, "accept"):
            if r.action == "delay":
                time.sleep(r.delay)
            elif r.action == "drop":
                try:
                    sock.close()
                except OSError:
                    pass
                return None
        return pair

    def get_worker(self, timeout: float):
        deadline = time.monotonic() + timeout
        while True:
            left = max(0.001, deadline - time.monotonic())
            pair = self._filter(self.inner.get_worker(left))
            if pair is not None:
                return pair

    def poll_worker(self):
        pair = self.inner.poll_worker()
        if pair is None:
            return None
        return self._filter(pair)

    def fileno(self) -> int:
        return self.inner.fileno()

    def close(self) -> None:
        self.inner.close()
