"""Client half of the multi-tenant gateway: ``repro.connect``.

A :class:`Client` is one tenant session on a resident gateway
(:class:`repro.gateway.GatewayService`).  It dials the gateway's client
port with the same framed handshake repro workers use — JSON hello,
constant-time token check, pickled frames only after authentication —
except the hello carries ``role: client``, so the service routes it to a
tenant session instead of adopting it into the worker pool.

Usage::

    with repro.connect("gw-host:7777", token=tok, tenant="serve") as c:
        fut = c.submit(graph, {"x": batch})     # non-blocking
        results = fut.result()                  # keyed by graph's own ids

Concurrency model: ``submit`` is non-blocking (the graph is pickled and
framed on the caller's thread, so unpicklable task functions fail *here*
with a clear error, not on the gateway); one reader thread per client
resolves futures as ``result``/``failed`` frames arrive, so any number
of submissions can be in flight and complete out of order.  Results are
bit-identical to ``repro.execute_sequential`` of the same graph — the
gateway runs the same deterministic lower/fuse/execute passes every
other backend uses.

Failure semantics: a quota rejection or task failure fails only that
future, with the service's original typed exception
(:class:`repro.gateway.QuotaExceeded`, ``TaskFailed``, ``MissingInput``
...) re-raised from ``future.result()``.  A dropped connection or
``close()`` fails every pending future with
:class:`repro.gateway.SessionClosed`.
"""
from __future__ import annotations

import pickle
import queue
import threading
from typing import Any, Dict, Optional

from repro.cluster.channel import (ChannelClosed, _dial_and_welcome,
                                   _recv_frame, _send_frame)
from repro.cluster.futures import ClusterFuture
from repro.config import TENANT_FIELDS
from repro.core.graph import TaskGraph

from .errors import GatewayError, SessionClosed

__all__ = ["Client", "connect"]


class Client:
    """One authenticated tenant session on a gateway.  Thread-safe:
    ``submit``/``stats``/``close`` may be called from any thread."""

    def __init__(self, sock, session_id: int, config: Dict[str, Any],
                 address: str) -> None:
        self._sock = sock
        self.session_id = session_id
        self.address = address
        self.tenant: str = config.get("tenant", "default")
        self.quota: Dict[str, Any] = dict(config.get("quota") or {})
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._pending: Dict[int, ClusterFuture] = {}
        self._next_id = 0
        self._stats_replies: "queue.Queue[dict]" = queue.Queue()
        self._closed = False
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True,
            name=f"gateway-client-{self.tenant}")
        self._reader.start()

    # ------------------------------------------------------------- submit
    def submit(self, graph: TaskGraph,
               inputs: Optional[Dict[str, Any]] = None, *,
               config=None, outputs_only: Optional[bool] = None,
               label: str = "") -> ClusterFuture:
        """Submit ``graph`` for execution on the shared pool; returns a
        :class:`~repro.cluster.futures.ClusterFuture` resolving to the
        result dict keyed by the graph's own task ids.

        Task functions must be picklable (module-level functions or
        ``functools.partial`` over them) — the graph ships to another
        process.  ``config`` accepts a :class:`repro.ClusterConfig` for
        ``run_graph`` compatibility, but only its ``outputs_only`` field
        travels: pool-level knobs are the operator's, not the tenant's
        (see ``repro.config.TENANT_FIELDS``).
        """
        if config is not None and outputs_only is None:
            oo = getattr(config, "outputs_only", False)
            outputs_only = True if oo else None
        opts: Dict[str, Any] = {}
        if outputs_only is not None:
            opts["outputs_only"] = bool(outputs_only)
        if label:
            opts["label"] = str(label)
        assert set(opts) <= TENANT_FIELDS
        # pickle on the caller's thread: an unpicklable task fn fails
        # HERE with the standard pickle error, not as a gateway reject
        blob = pickle.dumps((graph, dict(inputs or {})), protocol=5)
        with self._lock:
            if self._closed:
                raise SessionClosed("client is closed")
            cjid = self._next_id
            self._next_id += 1
            fut = ClusterFuture(label or f"{self.tenant}/c{cjid}")
            self._pending[cjid] = fut
        try:
            _send_frame(self._sock,
                        pickle.dumps(("submit", cjid, blob, opts),
                                     protocol=5),
                        lock=self._send_lock)
        except OSError as e:
            with self._lock:
                self._pending.pop(cjid, None)
            raise SessionClosed(f"gateway connection lost: {e!r}") from e
        return fut

    def gather(self, *futures: ClusterFuture,
               timeout: Optional[float] = None):
        """Resolve several futures, re-raising the first error."""
        from repro.cluster.futures import gather as _gather
        return _gather(*futures, timeout=timeout)

    # -------------------------------------------------------------- stats
    def stats(self, timeout: float = 30.0) -> Dict[str, Any]:
        """Per-tenant gateway statistics (admission counters, in-flight
        accounting, and submit-to-dispatch / submit-to-gather latency
        percentiles), as one snapshot dict keyed by tenant."""
        with self._lock:
            if self._closed:
                raise SessionClosed("client is closed")
        _send_frame(self._sock, pickle.dumps(("stats",), protocol=5),
                    lock=self._send_lock)
        try:
            return self._stats_replies.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError(
                f"no stats reply from {self.address} in {timeout}s"
            ) from None

    # ------------------------------------------------------------ teardown
    def close(self) -> None:
        """End the session.  Pending futures fail with
        :class:`SessionClosed`; the gateway cancels their jobs and
        collects their values (other tenants are untouched)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        try:
            _send_frame(self._sock, pickle.dumps(("bye",), protocol=5),
                        lock=self._send_lock)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._reader.join(timeout=5.0)
        self._fail_pending(SessionClosed("client closed with futures "
                                         "still pending"))

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- reader
    def _read_loop(self) -> None:
        try:
            while True:
                msg = _recv_frame(self._sock)
                verb = msg[0]
                if verb == "result":
                    _, cjid, result_blob, report = msg
                    fut = self._take(cjid)
                    if fut is not None:
                        fut._set_result(
                            pickle.loads(result_blob),
                            stats=report.get("stats"),
                            wall_time=report.get("wall_time", 0.0))
                elif verb == "failed":
                    _, cjid, exc_blob = msg
                    fut = self._take(cjid)
                    if fut is not None:
                        try:
                            exc = pickle.loads(exc_blob)
                        except Exception:
                            exc = GatewayError(
                                "job failed (error not picklable)")
                        fut._set_error(exc)
                elif verb == "stats":
                    self._stats_replies.put(msg[1])
                # unknown verbs are skipped: forward compatibility
        except (ChannelClosed, OSError, EOFError, pickle.UnpicklingError):
            pass
        self._fail_pending(SessionClosed(
            f"gateway session to {self.address} ended"))

    def _take(self, cjid: int) -> Optional[ClusterFuture]:
        with self._lock:
            return self._pending.pop(cjid, None)

    def _fail_pending(self, exc: BaseException) -> None:
        with self._lock:
            pending, self._pending = self._pending, {}
        for fut in pending.values():
            fut._set_error(exc)


def connect(address: str, token: Optional[str] = None, *,
            tenant: str = "default", priority: float = 1.0,
            timeout: float = 30.0) -> Client:
    """Open a tenant session on the gateway at ``address``
    (``"host:port"``).  ``tenant`` names the accounting/quota/fair-share
    identity — two clients with the same tenant share one budget;
    ``priority`` is the tenant's fair-share weight in the resident
    dispatch tier (higher ⇒ more dispatch slots under contention).
    Context-manager friendly: ``with repro.connect(...) as c: ...``.
    """
    sock, sid, config, _ = _dial_and_welcome(
        address, token=token, has_graph=True, timeout=timeout,
        retry_interval=0.2,
        extra={"role": "client", "tenant": str(tenant),
               "priority": float(priority)})
    if not config.get("gateway"):
        # a plain driver/worker listener answered: tell the operator they
        # pointed the client at the worker port, not the client port
        try:
            sock.close()
        except OSError:
            pass
        raise GatewayError(
            f"{address} accepted the dial but is not a gateway client "
            "port (did you connect to the worker listener?)")
    return Client(sock, sid, config, address)
