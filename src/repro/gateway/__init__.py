"""Multi-tenant job gateway: the cluster runtime as a resident service.

The rest of ``repro.cluster`` is a *library*: one driver process owns a
worker pool for the duration of one ``run()``.  This package is the
*service* shape of the same engine — a long-lived
:class:`GatewayService` owns one resident pool and any number of
tenants submit task graphs to it concurrently over TCP via
:func:`repro.connect` (or ``run_graph(..., connect="host:port")``),
with per-tenant admission quotas, fair-share dispatch, failure
isolation, and SLO accounting.  Results remain bit-identical to
``execute_sequential`` — same deterministic trace/lower/fuse passes,
shared pool or not.
"""
from .client import Client, connect
from .errors import GatewayError, QuotaExceeded, SessionClosed
from .service import GatewayService, TenantQuota

__all__ = ["Client", "connect", "GatewayError", "QuotaExceeded",
           "SessionClosed", "GatewayService", "TenantQuota"]
