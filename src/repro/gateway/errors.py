"""Typed errors for the multi-tenant gateway.

These cross the wire: the service pickles the exception instance into the
``failed`` frame and the client re-raises it from ``future.result()``, so
a quota rejection is caught as ``except repro.QuotaExceeded`` — not
string-matched out of a generic ``RuntimeError``.  Every class here must
therefore survive a pickle round-trip with its attributes intact
(``__reduce__`` pins the constructor args).
"""
from __future__ import annotations

__all__ = ["GatewayError", "QuotaExceeded", "SessionClosed"]


class GatewayError(RuntimeError):
    """Base class for gateway-side failures: protocol violations,
    rejected submissions, a service that is shutting down."""


class QuotaExceeded(GatewayError):
    """A submission was rejected by per-tenant admission control before
    any of its tasks ran.

    Attributes name the failed check so callers can back off sensibly:
    ``resource`` is ``"inflight_clusters"`` or ``"store_bytes"``,
    ``limit`` the tenant's configured ceiling, ``requested`` what
    admitting the job would have brought the total to.
    """

    def __init__(self, message: str, tenant: str = "",
                 resource: str = "", limit: int = 0,
                 requested: int = 0) -> None:
        super().__init__(message)
        self.tenant = tenant
        self.resource = resource
        self.limit = limit
        self.requested = requested

    def __reduce__(self):
        return (QuotaExceeded, (self.args[0], self.tenant, self.resource,
                                self.limit, self.requested))


class SessionClosed(GatewayError):
    """The client session ended (``close()``, gateway shutdown, or a
    dropped connection) while futures were still pending; those futures
    fail with this error rather than hanging forever."""
