"""Resident gateway service: one shared worker pool, many tenants.

:class:`GatewayService` turns the cluster runtime from a per-run library
into a long-lived *service*: it owns a single resident
:class:`~repro.cluster.executor.ClusterExecutor` (one worker pool, one
union run — see ``start_resident``/``submit_job``), binds a **client
listener**, and multiplexes any number of authenticated tenant sessions
onto that pool.  ``repro.connect`` (:mod:`repro.gateway.client`) is the
other half.

Two listeners, one protocol
---------------------------
Workers and clients speak the same framed handshake (JSON hello, token,
pickled frames after auth), but land on *different ports*: the
executor's worker listener adopts every successful dial into the pool
(any `repro-worker` dialing a live run is an elastic join), so client
dials must not reach it.  The gateway binds its own
:class:`~repro.cluster.channel.TcpListener` for hellos carrying
``role: client``; anything else on that port is rejected with a clear
"wrong port" reason.

Admission control
-----------------
Per-tenant quotas are enforced *before* a job consumes any executor
state, via ``submit_job``'s admission gate (called post-fusion, when the
job's true cluster count is known, pre-enqueue):

* ``max_inflight_clusters`` — ceiling on the tenant's not-yet-finished
  clusters across all its in-flight jobs;
* ``max_store_bytes`` — ceiling on the tenant's *declared* object-store
  footprint (sum of ``out_bytes`` over in-flight jobs' tasks; declared
  rather than measured, so admission is a pure function of the submitted
  graphs, not of runtime racing).

A rejected submission fails only its own future with a picklable
:class:`~repro.gateway.errors.QuotaExceeded`; nothing was admitted, so
there is nothing to clean up.

Isolation & accounting
----------------------
Task failures, cancellations and client disconnects are scoped to the
owning tenant by the resident executor (``fail_job``); the service adds
the session layer: a dropped client cancels exactly that session's
in-flight jobs.  Per-tenant counters and SLO latency reservoirs
(submit→first-dispatch, submit→gather) feed :meth:`GatewayService.stats`
and the ``repro-gateway`` CLI's periodic report; ``session`` /
``sessionend`` records go to the resident run log so a restarted gateway
can re-create tenant quotas (jobs in flight at the crash fail; clients
resubmit — graphs are pure, so a resubmit is bit-identical).
"""
from __future__ import annotations

import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.cluster.channel import (ChannelClosed, TcpListener, _recv_frame,
                                   _send_frame)
from repro.cluster.executor import ClusterExecutor
from repro.config import ClusterConfig, TENANT_FIELDS

from .errors import GatewayError, QuotaExceeded

__all__ = ["GatewayService", "TenantQuota"]


def _pctl(xs: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]); None on empty."""
    if not xs:
        return None
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return s[k]


@dataclass(frozen=True)
class TenantQuota:
    """Admission ceilings for one tenant; ``None`` means unlimited."""
    max_inflight_clusters: Optional[int] = None
    max_store_bytes: Optional[int] = None

    def as_dict(self) -> Dict[str, Optional[int]]:
        return {"max_inflight_clusters": self.max_inflight_clusters,
                "max_store_bytes": self.max_store_bytes}

    @classmethod
    def of(cls, v) -> "TenantQuota":
        if v is None:
            return cls()
        if isinstance(v, cls):
            return v
        return cls(**{k: v[k] for k in
                      ("max_inflight_clusters", "max_store_bytes")
                      if k in v})


class _TenantState:
    """Aggregated accounting for one tenant (all its sessions)."""

    def __init__(self, quota: TenantQuota) -> None:
        self.quota = quota
        self.sessions = 0
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self.inflight_jobs = 0
        self.inflight_clusters = 0
        self.inflight_bytes = 0
        self.lat_dispatch: List[float] = []   # submit -> first dispatch
        self.lat_gather: List[float] = []     # submit -> result collected

    def snapshot(self) -> Dict[str, Any]:
        return {
            "sessions": self.sessions,
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "rejected": self.rejected,
            "inflight_jobs": self.inflight_jobs,
            "inflight_clusters": self.inflight_clusters,
            "inflight_bytes": self.inflight_bytes,
            "quota": self.quota.as_dict(),
            "slo": {
                "submit_to_first_dispatch_s": {
                    "p50": _pctl(self.lat_dispatch, 50),
                    "p99": _pctl(self.lat_dispatch, 99)},
                "submit_to_gather_s": {
                    "p50": _pctl(self.lat_gather, 50),
                    "p99": _pctl(self.lat_gather, 99)},
            },
        }


class _Session:
    """One client connection: a read loop on its own thread, plus one
    small waiter thread per in-flight job (bounded by the tenant's
    cluster quota) that ships the result frame when the future
    resolves."""

    def __init__(self, service: "GatewayService", sock, sid: int,
                 tenant: str) -> None:
        self.service = service
        self.sock = sock
        self.sid = sid
        self.tenant = tenant
        self.send_lock = threading.Lock()
        self.jobs_lock = threading.Lock()
        self.jobs: Dict[int, Any] = {}       # client job id -> future
        self.closed = False
        self.thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"gateway-session-{tenant}-{sid}")

    # ---------------------------------------------------------------- wire
    def _send(self, *frame: Any) -> None:
        try:
            _send_frame(self.sock, pickle.dumps(frame, protocol=5),
                        lock=self.send_lock)
        except OSError:
            pass                      # read loop notices the dead socket

    def _fail(self, cjid: int, exc: BaseException) -> None:
        try:
            blob = pickle.dumps(exc, protocol=5)
        except Exception:
            blob = pickle.dumps(GatewayError(repr(exc)), protocol=5)
        self._send("failed", cjid, blob)

    # ---------------------------------------------------------------- loop
    def _run(self) -> None:
        svc = self.service
        try:
            while True:
                try:
                    msg = _recv_frame(self.sock)
                except (ChannelClosed, OSError, EOFError,
                        pickle.UnpicklingError):
                    break
                verb = msg[0]
                if verb == "submit":
                    self._handle_submit(msg[1], msg[2], msg[3])
                elif verb == "stats":
                    self._send("stats", svc.stats())
                elif verb == "bye":
                    break
                # unknown verbs skipped: forward compatibility
        finally:
            self.closed = True
            with self.jobs_lock:
                live = dict(self.jobs)
            for fut in live.values():
                svc.executor.cancel_job(fut.job_id, "client disconnected")
            try:
                self.sock.close()
            except OSError:
                pass
            svc._end_session(self)

    # -------------------------------------------------------------- submit
    def _handle_submit(self, cjid: int, blob: bytes,
                       opts: Dict[str, Any]) -> None:
        svc = self.service
        bad = set(opts) - TENANT_FIELDS
        if bad:
            self._fail(cjid, GatewayError(
                f"submit options {sorted(bad)} are not tenant-settable "
                f"(allowed: {sorted(TENANT_FIELDS)})"))
            return
        try:
            graph, inputs = pickle.loads(blob)
        except Exception as e:
            self._fail(cjid, GatewayError(f"undecodable job graph: {e!r}"))
            return
        declared = sum(getattr(n, "out_bytes", 0) or 0
                       for n in graph.nodes.values())
        tenant = self.tenant

        def admission(n_clusters: int) -> None:
            # called by submit_job post-fusion, pre-enqueue; raising
            # aborts the submission with no executor residue
            with svc._lock:
                t = svc._tenant(tenant)
                q = t.quota
                if (q.max_inflight_clusters is not None
                        and t.inflight_clusters + n_clusters
                        > q.max_inflight_clusters):
                    t.rejected += 1
                    raise QuotaExceeded(
                        f"tenant {tenant!r}: admitting {n_clusters} "
                        f"cluster(s) would put {t.inflight_clusters + n_clusters} "
                        f"in flight (limit {q.max_inflight_clusters})",
                        tenant, "inflight_clusters",
                        q.max_inflight_clusters,
                        t.inflight_clusters + n_clusters)
                if (q.max_store_bytes is not None
                        and t.inflight_bytes + declared
                        > q.max_store_bytes):
                    t.rejected += 1
                    raise QuotaExceeded(
                        f"tenant {tenant!r}: job declares {declared} "
                        f"store bytes, would put "
                        f"{t.inflight_bytes + declared} in flight "
                        f"(limit {q.max_store_bytes})",
                        tenant, "store_bytes", q.max_store_bytes,
                        t.inflight_bytes + declared)
                # reserve atomically with the check
                t.submitted += 1
                t.inflight_jobs += 1
                t.inflight_clusters += n_clusters
                t.inflight_bytes += declared

        try:
            fut = svc.executor.submit_job(
                graph, inputs, tenant=tenant,
                outputs_only=opts.get("outputs_only"),
                label=opts.get("label", ""), admission=admission)
        except QuotaExceeded as e:
            self._fail(cjid, e)
            return
        except Exception as e:     # bad graph (validate), pool down, ...
            self._fail(cjid, GatewayError(f"submission failed: {e!r}"))
            return
        with self.jobs_lock:
            self.jobs[cjid] = fut
        threading.Thread(
            target=self._await, args=(cjid, fut, declared), daemon=True,
            name=f"gateway-wait-{tenant}-j{fut.job_id}").start()

    def _await(self, cjid: int, fut, declared: int) -> None:
        svc = self.service
        exc = fut.exception(None)          # blocks until the job resolves
        with self.jobs_lock:
            self.jobs.pop(cjid, None)
        with svc._lock:
            t = svc._tenant(self.tenant)
            t.inflight_jobs -= 1
            t.inflight_clusters -= fut.n_clusters
            t.inflight_bytes -= declared
            if exc is None:
                t.completed += 1
                s = fut.stats
                if s.get("submit_to_first_dispatch_s") is not None:
                    t.lat_dispatch.append(s["submit_to_first_dispatch_s"])
                if s.get("submit_to_gather_s") is not None:
                    t.lat_gather.append(s["submit_to_gather_s"])
            else:
                t.failed += 1
        if exc is None:
            self._send("result", cjid,
                       pickle.dumps(fut.result(), protocol=5),
                       {"wall_time": fut.wall_time, "stats": fut.stats})
        else:
            self._fail(cjid, exc)


class GatewayService:
    """The resident multi-tenant service.  Construct with the pool's
    :class:`repro.ClusterConfig` (worker count, transport, channel,
    token, checkpointing, fault policy — all operator-owned), then
    :meth:`start` to bring up the pool and begin accepting clients::

        cfg = repro.ClusterConfig(n_workers=8, token=tok)
        with GatewayService(cfg, quotas={"serve": TenantQuota(64)}) as gw:
            print("clients dial", gw.address)
            gw.serve_forever()

    ``config.resume`` is interpreted at the *gateway* level: tenant
    sessions (quotas, fair-share weights) are restored from the named
    run log, but the pool starts a fresh run — jobs in flight at the
    crash fail on their clients, which resubmit (pure graphs make the
    resubmission bit-identical).
    """

    def __init__(self, config: Optional[ClusterConfig] = None, *,
                 client_address: str = "127.0.0.1:0",
                 quotas: Optional[Dict[str, Any]] = None,
                 default_quota: Any = None,
                 **legacy: Any) -> None:
        from repro.config import resolve_config
        cfg = resolve_config(config, legacy, owner="GatewayService")
        self._restored_sessions: Dict[str, Dict[str, Any]] = {}
        if cfg.resume is not None:
            import os
            from repro.checkpoint.runlog import load_run
            state = load_run(os.path.join(
                cfg.checkpoint_dir, f"{cfg.resume}.log"))
            self._restored_sessions = dict(state.sessions)
            cfg = cfg.replace(resume=None)     # fresh pool run id
        self.config = cfg
        self.client_address_spec = client_address
        self.default_quota = TenantQuota.of(default_quota)
        self.quotas = {t: TenantQuota.of(q)
                       for t, q in (quotas or {}).items()}
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantState] = {}
        self._sessions: Dict[int, _Session] = {}
        self._session_seq = 0
        self._stop = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None
        self.executor: Optional[ClusterExecutor] = None
        self.listener: Optional[TcpListener] = None
        self.started = time.time()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "GatewayService":
        if self.executor is not None:
            return self
        self.executor = ClusterExecutor(config=self.config)
        self.executor.start_resident()
        self.listener = TcpListener(self.client_address_spec,
                                    token=self.config.token)
        for tenant, info in self._restored_sessions.items():
            q = TenantQuota.of(info.get("quota"))
            self.quotas.setdefault(tenant, q)
            with self._lock:
                self._tenant(tenant)
            if info.get("priority") is not None:
                self.executor.set_tenant_weight(tenant, info["priority"])
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="gateway-accept")
        self._accept_thread.start()
        return self

    @property
    def address(self) -> str:
        """The client port (``host:port``) — what ``repro.connect`` and
        ``run_graph(connect=...)`` dial.  Distinct from the executor's
        worker listener."""
        if self.listener is None:
            raise RuntimeError("gateway not started")
        return self.listener.address

    def stop(self, timeout: float = 30.0) -> None:
        """Drain: stop accepting, close every session (their pending
        futures fail client-side with ``SessionClosed``), then shut the
        resident pool down."""
        self._stop.set()
        if self.listener is not None:
            self.listener.close()
        with self._lock:
            sessions = list(self._sessions.values())
        for s in sessions:
            try:
                s.sock.close()
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        if self.executor is not None:
            self.executor.shutdown_resident(timeout=timeout)
            self.executor.close()

    def __enter__(self) -> "GatewayService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def serve_forever(self, poll: float = 0.5) -> None:
        """Block until :meth:`stop` (or KeyboardInterrupt).  Re-raises
        the resident driver's error if the pool dies underneath the
        service — a gateway with no pool must crash loudly, not keep
        accepting doomed submissions."""
        while not self._stop.wait(poll):
            ex = self.executor
            if ex is None:
                break
            if ex._resident is not None and not ex._resident.is_alive():
                self._stop.set()
                if ex._resident_error is not None:
                    raise ex._resident_error

    # -------------------------------------------------------------- accept
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            pair = self.listener.poll_worker()
            if pair is None:
                time.sleep(0.02)
                continue
            sock, hello = pair
            if hello.get("role") != "client":
                # a worker (or rejoiner) dialed the CLIENT port: tell it
                # where it went wrong instead of adopting or hanging it
                try:
                    _send_frame(sock, pickle.dumps(
                        ("reject", "this is the gateway client port; "
                         "workers dial the pool's worker listener"),
                        protocol=5))
                except OSError:
                    pass
                try:
                    sock.close()
                except OSError:
                    pass
                continue
            self._open_session(sock, hello)

    def _open_session(self, sock, hello: Dict[str, Any]) -> None:
        tenant = str(hello.get("tenant") or "default")
        priority = hello.get("priority")
        with self._lock:
            sid = self._session_seq
            self._session_seq += 1
            t = self._tenant(tenant)
            t.sessions += 1
            first = t.sessions == 1
            session = _Session(self, sock, sid, tenant)
            self._sessions[sid] = session
        if priority is not None:
            try:
                self.executor.set_tenant_weight(tenant, float(priority))
            except (TypeError, ValueError):
                priority = None
        if first:
            self.executor.log_record("session", tenant, {
                "quota": t.quota.as_dict(), "priority": priority})
        try:
            _send_frame(sock, pickle.dumps(
                ("welcome", sid,
                 {"gateway": True, "tenant": tenant,
                  "quota": t.quota.as_dict()},
                 None), protocol=5))
        except OSError:
            with self._lock:
                self._sessions.pop(sid, None)
                t.sessions -= 1
            try:
                sock.close()
            except OSError:
                pass
            return
        session.thread.start()

    def _end_session(self, session: _Session) -> None:
        with self._lock:
            self._sessions.pop(session.sid, None)
            t = self._tenant(session.tenant)
            t.sessions -= 1
            last = t.sessions == 0
        if last and not self._stop.is_set():
            self.executor.log_record("sessionend", session.tenant)

    # ---------------------------------------------------------------- state
    def _tenant(self, tenant: str) -> _TenantState:
        """Caller holds ``self._lock``."""
        t = self._tenants.get(tenant)
        if t is None:
            t = _TenantState(self.quotas.get(tenant, self.default_quota))
            self._tenants[tenant] = t
        return t

    def stats(self) -> Dict[str, Any]:
        """Snapshot: per-tenant accounting + SLO percentiles, plus the
        pool's own counters under ``"pool"``."""
        with self._lock:
            out: Dict[str, Any] = {
                t: st.snapshot() for t, st in self._tenants.items()}
        ex = self.executor
        out["pool"] = {
            "n_workers": len(ex.worker_specs) if ex is not None else 0,
            "uptime_s": time.time() - self.started,
            "stats": dict(ex.stats) if ex is not None else {},
        }
        return out
