"""Mesh construction (kept as FUNCTIONS so importing never touches devices).

``jax.sharding.AxisType`` (explicit-sharding axis annotations) only exists in
newer JAX releases; feature-detect it so ``repro.parallel`` imports — and the
test suite collects — on any installed JAX.  When absent, meshes are built
without axis types, which is exactly the old (implicit/auto) behaviour.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.compat import ensure_partitionable_rng

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType
except ImportError:  # older JAX: no explicit axis types
    AxisType = None

# sharded programs must see the same RNG stream as the sequential oracle
ensure_partitionable_rng()


def _axis_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        if hasattr(jax, "make_mesh"):
            return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))
        return Mesh(np.asarray(devs).reshape(shape), axes,
                    **_axis_kwargs(len(axes)))
    if len(devs) < n:
        raise ValueError(f"need {n} devices for mesh {shape}, have {len(devs)}")
    # more devices than the mesh needs (e.g. the 512-device dry-run world
    # building a single-pod 256-chip mesh): take a prefix
    arr = np.asarray(devs[:n]).reshape(shape)
    return Mesh(arr, axes, **_axis_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assignment's production mesh: one v5e pod = (16, 16) over
    (data, model); two pods = (2, 16, 16) over (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1,
                  pods: int = 1) -> Mesh:
    """Generic mesh builder for tests/examples on arbitrary device counts."""
    assert n_devices % (model_parallel * pods) == 0
    data = n_devices // (model_parallel * pods)
    if pods > 1:
        return _make((pods, data, model_parallel), ("pod", "data", "model"))
    return _make((data, model_parallel), ("data", "model"))


def single_device_mesh() -> Mesh:
    return _make((1, 1), ("data", "model"))
