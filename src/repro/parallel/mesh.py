"""Mesh construction (kept as FUNCTIONS so importing never touches devices)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if len(devs) < n:
        raise ValueError(f"need {n} devices for mesh {shape}, have {len(devs)}")
    # more devices than the mesh needs (e.g. the 512-device dry-run world
    # building a single-pod 256-chip mesh): take a prefix
    arr = np.asarray(devs[:n]).reshape(shape)
    return Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assignment's production mesh: one v5e pod = (16, 16) over
    (data, model); two pods = (2, 16, 16) over (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1,
                  pods: int = 1) -> Mesh:
    """Generic mesh builder for tests/examples on arbitrary device counts."""
    assert n_devices % (model_parallel * pods) == 0
    data = n_devices // (model_parallel * pods)
    if pods > 1:
        return _make((pods, data, model_parallel), ("pod", "data", "model"))
    return _make((data, model_parallel), ("data", "model"))


def single_device_mesh() -> Mesh:
    return _make((1, 1), ("data", "model"))
