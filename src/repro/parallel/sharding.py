"""Model-facing sharding context.

Bridges the placement engine (:mod:`repro.core.placement`) and the model
code: model layers call ``ctx.constrain(x, logical_axes)`` at block
boundaries; the context resolves logical axes through the active rule table.
``ctx=None`` (or mesh=None) is a no-op so the same model code runs on one
CPU device in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.core.placement import Rule, logical_to_spec, standard_rules, tree_shardings


@dataclasses.dataclass
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: Sequence[Rule]

    @classmethod
    def make(cls, mesh: Optional[Mesh], mode: str = "fsdp_tp") -> "ShardingCtx":
        pod = "pod" if (mesh is not None and "pod" in mesh.axis_names) else None
        return cls(mesh, standard_rules(mode, pod_axis=pod))

    def spec(self, axes: Tuple[Optional[str], ...]):
        return logical_to_spec(axes, self.rules, self.mesh)

    def constrain(self, x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(axes)))

    def sharding(self, axes: Tuple[Optional[str], ...]) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(axes))


def act_spec(axes: Tuple[Optional[str], ...], ctx: Optional[ShardingCtx]):
    return ctx.spec(axes) if ctx and ctx.mesh is not None else None


def param_shardings(logical_tree: Any, ctx: ShardingCtx):
    """Pytree of NamedShardings for a params pytree's logical axes."""
    assert ctx.mesh is not None
    return tree_shardings(logical_tree, ctx.rules, ctx.mesh)
