"""Gradient compression with error feedback.

Used on the ``pod`` axis where the all-reduce crosses DCN (the slow link in
a multi-pod mesh): int8 block-quantized all-reduce cuts cross-pod bytes 4×
vs f32 (2× vs bf16) at negligible quality cost when error feedback carries
the quantization residual to the next step (Seide et al.; 1-bit Adam lineage).

The compressor is stateless across calls except for the residual pytree the
caller threads through the train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.compat import static_axis_size


@dataclasses.dataclass(frozen=True)
class Int8BlockCompressor:
    """Symmetric per-block int8 quantization; block over the last axis."""
    block: int = 256

    def quantize(self, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        orig_shape = x.shape
        flat = x.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % self.block
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, self.block)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        return q, scale

    def dequantize(self, q: jax.Array, scale: jax.Array,
                   shape: Tuple[int, ...]) -> jax.Array:
        flat = (q.astype(jnp.float32) * scale).reshape(-1)
        n = 1
        for s in shape:
            n *= s
        return flat[:n].reshape(shape)

    def roundtrip(self, x: jax.Array) -> jax.Array:
        q, s = self.quantize(x)
        return self.dequantize(q, s, x.shape)

    # -- inside shard_map -------------------------------------------------
    def all_reduce(self, x: jax.Array, axes: Sequence[str]) -> jax.Array:
        """Quantize → all-reduce int32 accumulators → dequantize → mean.

        Summing int8 values in int32 keeps the reduction exact given the
        shared max-scale; the scale itself is all-reduced with max.
        """
        q, scale = self.quantize(x)
        for ax in axes:
            scale = jax.lax.pmax(scale, ax)
        # requantize against the global scale so sums are consistent
        blocks = x.astype(jnp.float32).reshape(-1)
        pad = (-blocks.size) % self.block
        blocks = jnp.pad(blocks, (0, pad)).reshape(-1, self.block)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int32)
        n = 1
        for ax in axes:
            q = jax.lax.psum(q, ax)
            n *= static_axis_size(ax)
        return self.dequantize(q.astype(jnp.float32), scale, x.shape) / n


def compress_with_feedback(grads: Any, residual: Any,
                           comp: Int8BlockCompressor) -> Tuple[Any, Any]:
    """Error-feedback wrapper: g' = Q(g + r); r' = (g + r) - g'."""
    def one(g, r):
        total = g.astype(jnp.float32) + r
        approx = comp.roundtrip(total)
        return approx, total - approx
    out = jax.tree.map(one, grads, residual)
    approx = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return approx, new_res


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(dtype_bytes: int = 4) -> float:
    """Bytes on the wire vs uncompressed (scale overhead included)."""
    return (1 + 4 / 256) / dtype_bytes
