"""Pipeline parallelism over the ``pod`` axis (GPipe-style microbatching).

The production mesh's ``pod`` axis is data-parallel by default; this module
offers the alternative: partition the stacked-layer pytree into
``n_stages`` contiguous stages, place stage *i* on pod-slice *i*, and stream
microbatches through a ``collective_permute`` ring inside ``shard_map``.
Bubble fraction is (P-1)/(M+P-1) for P stages and M microbatches; the
benchmark `benchmarks/pipeline_bench.py` sweeps M.

Implementation notes:
* stages must divide ``n_layers``; each stage scans its own layer slice;
* the steady-state loop runs P+M-1 ticks; each tick = stage compute +
  ppermute of the activation to the next stage — XLA overlaps the permute
  with the next tick's compute (verified in the dry-run HLO schedule);
* works for any of the homogeneous layer plans (the stage body reuses
  ``transformer._layer_body``).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.config import ModelConfig
from repro.models import transformer as TF


def split_stages(params: Dict, n_stages: int, n_layers: int) -> Dict:
    """Reshape stacked layer params (L, ...) -> (S, L/S, ...)."""
    per = n_layers // n_stages
    assert per * n_stages == n_layers, "stages must divide n_layers"
    return jax.tree.map(
        lambda x: x.reshape((n_stages, per) + x.shape[1:]), params)


def pipelined_forward(cfg: ModelConfig, mesh: Mesh, *, n_microbatch: int,
                      stage_axis: str = "pod"):
    """Build fn(stage_params, x_embedded) -> activations, running the layer
    stack as a pipeline over ``stage_axis``.

    ``stage_params``: layer pytree reshaped to (n_stages, L/stages, ...) and
    sharded on the stage axis.  x: (B, S, d) embedded inputs (embedding and
    unembedding stay outside — they live on stage 0 / last stage).
    """
    n_stages = mesh.shape[stage_axis]

    def stage_fn(layer_params, x):
        # training pipeline: positions are always [0, S) for every microbatch
        B_mb, S_mb = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S_mb)[None], (B_mb, S_mb))
        body = TF._layer_body(cfg, None, use_cache=False, train=True,
                              positions=positions, cache_pos=None,
                              shared_params=None, shared_norm=None)
        L = jax.tree.leaves(layer_params)[0].shape[0]
        xs = {"params": layer_params,
              "idx": jnp.arange(L, dtype=jnp.int32)}
        aux0 = jnp.zeros((), jnp.float32)
        (x, aux, _, _), _ = jax.lax.scan(body, (x, aux0, None, None), xs)
        return x, aux

    def fn(stage_params, x):
        B, S, d = x.shape
        assert B % n_microbatch == 0
        mb = B // n_microbatch

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(stage_axis), stage_params),
                      P(None)),
            out_specs=(P(None), P()),
            check_rep=False)
        def run(sp, xin):
            sp = jax.tree.map(lambda a: a[0], sp)       # this stage's layers
            stage = jax.lax.axis_index(stage_axis)
            # static stage count (jax.lax.axis_size is missing on older JAX;
            # the mesh's axis extent is the same number and always static)
            n = mesh.shape[stage_axis]
            micro = xin.reshape(n_microbatch, mb, S, d)
            ticks = n_microbatch + n - 1
            out = jnp.zeros_like(micro)
            aux_total = jnp.zeros((), jnp.float32)
            buf = jnp.zeros((mb, S, d), xin.dtype)

            def tick(t, state):
                buf, out, aux_total = state
                # stage 0 injects microbatch t (if in range)
                inject = jnp.clip(t, 0, n_microbatch - 1)
                x_in = jnp.where(stage == 0, micro[inject], buf)
                y, aux = stage_fn(sp, x_in)
                active = (t - stage >= 0) & (t - stage < n_microbatch)
                aux_total = aux_total + jnp.where(active, aux, 0.0)
                # last stage writes its finished microbatch
                widx = jnp.clip(t - (n - 1), 0, n_microbatch - 1)
                write = active & (stage == n - 1)
                out = jax.lax.cond(
                    write,
                    lambda o: jax.lax.dynamic_update_index_in_dim(
                        o, y, widx, 0),
                    lambda o: o, out)
                # rotate activations to the next stage
                perm = [(i, (i + 1) % n) for i in range(n)]
                buf = jax.lax.ppermute(y, stage_axis, perm)
                return buf, out, aux_total

            buf, out, aux_total = jax.lax.fori_loop(
                0, ticks, tick, (buf, out, aux_total))
            # results live on the last stage; broadcast so every pod slice
            # returns the same value (out_specs P() is replicated)
            out = jax.lax.psum(
                jnp.where(stage == n - 1, out, jnp.zeros_like(out)),
                stage_axis)
            aux_total = jax.lax.psum(
                jnp.where(stage == n - 1, aux_total, 0.0), stage_axis)
            return out.reshape(B, S, d), aux_total / n_microbatch

        return run(stage_params, x)

    return fn


def bubble_fraction(n_stages: int, n_microbatch: int) -> float:
    return (n_stages - 1) / (n_microbatch + n_stages - 1)
