from .sharding import ShardingCtx, param_shardings, act_spec
from .mesh import make_production_mesh, single_device_mesh
