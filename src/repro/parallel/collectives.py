"""shard_map collective helpers used by the explicit-communication paths.

The pjit/GSPMD paths let XLA insert collectives; these helpers exist for the
places where we schedule communication BY HAND: the pipeline's
collective_permute ring, compressed gradient all-reduce, and the
bucketed/overlapped DP gradient sync.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.compat import static_axis_size
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def psum_tree(tree: Any, axis: str) -> Any:
    return jax.tree.map(lambda x: jax.lax.psum(x, axis), tree)


def pmean_tree(tree: Any, axis: str) -> Any:
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis), tree)


def ring_permute(x: jax.Array, axis: str, shift: int = 1) -> jax.Array:
    n = static_axis_size(axis)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis, perm)


def all_gather_seq(x: jax.Array, axis: str, dim: int = 1) -> jax.Array:
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True)


def reduce_scatter(x: jax.Array, axis: str, dim: int = 0) -> jax.Array:
    return jax.lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)


def dp_gradient_sync(grads: Any, mesh: Mesh, data_axes: Sequence[str],
                     compressor: Optional[Callable] = None) -> Any:
    """Explicit data-parallel gradient all-reduce via shard_map.

    With ``compressor`` (see :mod:`repro.parallel.compression`) the
    all-reduce runs on the compressed representation — the distributed-
    optimization trick for DCN-crossing (pod-axis) reductions.
    """
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    if not axes:
        return grads

    specs = jax.tree.map(lambda g: P(*([None] * g.ndim)), grads)

    @functools.partial(shard_map, mesh=mesh, in_specs=(specs,),
                       out_specs=specs)
    def sync(g):
        def one(x):
            if compressor is not None:
                return compressor.all_reduce(x, axes)
            for ax in axes:
                x = jax.lax.pmean(x, ax)
            return x
        return jax.tree.map(one, g)

    return sync(grads)
