"""Optimizers (functional, pytree-based — no external deps).

* :class:`AdamW` — f32 moments regardless of param dtype (mixed precision),
  decoupled weight decay, global-norm clipping, schedule support.
* :class:`Adafactor` — factored second moment for very large models
  (llama4-maverick's 400B params cannot afford Adam's 2×f32 state on a
  single pod; see DESIGN.md memory budget).
* Optimizer state carries the step count; all updates are jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Schedule = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params: Any) -> Any:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(self, grads: Any, state: Any, params: Any) -> Tuple[Any, Any]:
        step = state["step"] + 1
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                         state["v"], grads)
        c1 = 1 - self.b1 ** step.astype(jnp.float32)
        c2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = _lr_at(self.lr, step)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(jnp.float32)

        updates = jax.tree.map(upd, params, m, v)
        return updates, {"step": step, "m": m, "v": v}

    @staticmethod
    def global_norm(tree: Any) -> jax.Array:
        return global_norm(tree)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second-moment optimizer (Shazeer & Stern, 2018), the standard
    trick for >100B-param models: O(n+m) state for an (n, m) matrix."""
    lr: Schedule = 1e-2
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    min_dim_factored: int = 128

    def _factored(self, shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= self.min_dim_factored \
            and shape[-2] >= self.min_dim_factored

    def init(self, params: Any) -> Any:
        def one(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(one, params,
                                  is_leaf=lambda x: hasattr(x, "shape"))}

    def update(self, grads: Any, state: Any, params: Any) -> Tuple[Any, Any]:
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-self.decay)
        lr = _lr_at(self.lr, step)

        def one(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + self.eps)
                cfac = jax.lax.rsqrt(vc + self.eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                nv = {"vr": vr, "vc": vc}
            else:
                nvv = beta * v["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(nvv + self.eps)
                nv = {"v": nvv}
            # update clipping (RMS of update limited to clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            return (-lr * u).astype(jnp.float32), nv

        flat_g, tdef = jax.tree.flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        outs = [one(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return updates, {"step": step, "v": new_v}

    @staticmethod
    def global_norm(tree: Any) -> jax.Array:
        return global_norm(tree)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Schedule = 1e-2
    momentum: float = 0.0

    def init(self, params: Any) -> Any:
        st = {"step": jnp.zeros((), jnp.int32)}
        if self.momentum:
            st["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)
        return st

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = _lr_at(self.lr, step)
        if self.momentum:
            m = jax.tree.map(lambda m, g: self.momentum * m
                             + g.astype(jnp.float32), state["m"], grads)
            updates = jax.tree.map(lambda m: -lr * m, m)
            return updates, {"step": step, "m": m}
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return updates, {"step": step}

    @staticmethod
    def global_norm(tree):
        return global_norm(tree)
