"""repro — an auto-parallelizing distributed runtime for pure task graphs.

Top-level convenience surface::

    import repro

    g = repro.TaskGraph(); ...            # or trace with @repro.task
    repro.run_graph(g, n_workers=4, backend="process")

    cfg = repro.ClusterConfig(n_workers=4, fuse="auto")
    repro.run_graph(g, config=cfg, backend="process")

    with repro.connect("gw-host:7777", token=tok) as client:
        fut = client.submit(g)            # multi-tenant gateway session
        print(fut.result())

Everything is imported lazily: ``import repro`` must stay cheap (no jax,
no multiprocessing side effects) because workers, clients and launchers
all pay it on startup.
"""
from typing import Any

__all__ = [
    "ClusterConfig", "TaskGraph", "task", "run_graph", "make_executor",
    "execute_sequential", "connect", "Client", "GatewayError",
    "QuotaExceeded",
]

_LAZY = {
    "ClusterConfig": ("repro.config", "ClusterConfig"),
    "TaskGraph": ("repro.core.graph", "TaskGraph"),
    "task": ("repro.core.tracing", "task"),
    "run_graph": ("repro.core.executor", "run_graph"),
    "make_executor": ("repro.core.executor", "make_executor"),
    "execute_sequential": ("repro.core.executor", "execute_sequential"),
    "connect": ("repro.gateway.client", "connect"),
    "Client": ("repro.gateway.client", "Client"),
    "GatewayError": ("repro.gateway.errors", "GatewayError"),
    "QuotaExceeded": ("repro.gateway.errors", "QuotaExceeded"),
}


def __getattr__(name: str) -> Any:
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    import importlib
    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value      # cache: __getattr__ runs once per name
    return value


def __dir__() -> list:
    return sorted(set(globals()) | set(_LAZY))
