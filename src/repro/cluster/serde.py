"""Zero-copy serde for the cluster data plane.

PR-1 moved every cross-worker value through the driver as a double-pickled
pipe payload (worker → driver pipe → driver → consumer pipe): four
serialization copies plus two kernel pipe traversals per transfer.  This
module replaces the *payload* path with handle passing:

* :func:`encode` serializes a task value with **pickle protocol 5** and
  captures its out-of-band buffers (numpy/jax array bodies).  Buffers at or
  above ``threshold`` are written once into a
  :mod:`multiprocessing.shared_memory` segment; the returned
  :class:`Encoded` carries only the pickle *stream* and
  :class:`ShmRef` handles, so what crosses the driver pipe is a few hundred
  bytes regardless of payload size.  Large non-array payloads (big
  ``bytes``, deeply pickled objects) are covered too: when the pickle
  stream itself exceeds the threshold it is spilled to a segment as well.
* :func:`decode` attaches the named segments, materializes a
  process-private copy, and unmaps.  Consumers therefore never hold a
  mapping after decode, which is what lets the driver unlink segments the
  moment refcounts drain (``consumers_left`` GC) without use-after-unmap
  hazards — the crash-safety property the kill-mid-transfer tests pin.
* :class:`PeerRef` + :class:`PeerServer` are the fallback channel when
  POSIX shared memory is unavailable: every worker binds a unix-domain
  socket and serves its local store; a consumer resolves a ``PeerRef`` by
  connecting to the owner directly.  Bytes still bypass the driver pipe.

Ownership/lifecycle contract: the **driver is the single unlink
authority**.  Creating or attaching a segment immediately unregisters it
from this process's ``resource_tracker`` (which would otherwise unlink
segments at the *creator's* exit — exactly wrong when a worker produces a
segment the driver must outlive).  The driver unlinks via
:func:`release` when a value's refcount drains, and sweeps any orphans by
run-scoped name prefix (:func:`sweep_segments`) on exit, so a SIGKILL'd
worker can never leak ``/dev/shm`` entries past the run.
"""
from __future__ import annotations

import glob
import os
import pickle
import socket
import struct
import sys
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

SHM_THRESHOLD = 1 << 16     # buffers >= 64 KiB go out-of-band to /dev/shm
_SHM_DIR = "/dev/shm"       # POSIX shm backing dir (Linux); probed, not assumed

TRANSPORTS = ("auto", "shm", "sock", "tcp", "driver")

#: transports whose handles resolve across host boundaries.  Shm segments
#: and unix sockets are host-local; TCP peer pulls and driver-relayed
#: inline bytes work anywhere the control plane reaches.
CROSS_HOST_TRANSPORTS = ("auto", "tcp", "driver")


class TransferLost(RuntimeError):
    """A handle could not be resolved (segment unlinked / peer gone).

    This is a *recoverable* data-plane failure: the caller treats the value
    as lost and falls back to lineage recovery, exactly like a worker death.

    ``retryable`` distinguishes transient failures (connect refused,
    timeout, truncated stream — the owner may just be busy or the network
    flaky) from definitive ones (the owner answered and said it no longer
    holds the value): :func:`peer_fetch` retries only the former.
    """

    retryable = True


# --------------------------------------------------------------------- refs
@dataclass(frozen=True)
class ShmRef:
    """Name + length of one shared-memory segment (picklable, ~100 B)."""
    name: str
    nbytes: int


@dataclass(frozen=True)
class PeerRef:
    """Handle to a value held in a peer worker's store, reachable over that
    worker's socket server: ``addr`` is a unix-socket path, or
    ``tcp://host:port`` for the multi-host data plane.  NOT durable —
    dies with the owning process.

    ``secret`` is a per-server capability for the TCP family: the server
    only answers requests that present it, and the only way to learn it is
    to receive a PeerRef over the (token-gated) control channel — so an
    open network port does not expose task values to port scanners.  Unix
    servers rely on filesystem permissions instead and leave it empty."""
    addr: str
    tid: int
    nbytes: int
    wid: int
    secret: str = ""


@dataclass
class Encoded:
    """A serialized value: pickle stream + out-of-band buffers, each either
    inline ``bytes`` (small) or a :class:`ShmRef` (large, zero-copy path).
    Durable: inline parts live wherever the object lives; shm parts live in
    tmpfs and survive the death of the process that wrote them."""
    data: Union[bytes, ShmRef]
    buffers: List[Union[bytes, ShmRef]] = field(default_factory=list)
    nbytes: int = 0             # total payload size (for stats/placement)

    def pipe_nbytes(self) -> int:
        """Bytes this object adds to a driver-pipe message."""
        n = 64 if isinstance(self.data, ShmRef) else len(self.data)
        for b in self.buffers:
            n += 64 if isinstance(b, ShmRef) else len(b)
        return n

    def direct_nbytes(self) -> int:
        """Bytes moved out-of-band through shared memory."""
        n = self.data.nbytes if isinstance(self.data, ShmRef) else 0
        for b in self.buffers:
            if isinstance(b, ShmRef):
                n += b.nbytes
        return n

    def shm_refs(self) -> List[ShmRef]:
        refs = [self.data] if isinstance(self.data, ShmRef) else []
        refs.extend(b for b in self.buffers if isinstance(b, ShmRef))
        return refs


@dataclass(frozen=True)
class DualRef:
    """Same-host shm fast path inside a mixed-host ``transport="tcp"``
    run: the owner publishes the value BOTH ways — a shared-memory
    :class:`Encoded` (zero-copy for consumers on the owner's machine) and
    a :class:`PeerRef` (TCP pull for everyone else) — and the *consumer*
    picks by host id.  Without this, two workers sharing a machine in a
    multi-host pool would move bytes through the TCP loopback even though
    tmpfs is a ``mmap`` away (the open item from PR 3).

    NOT durable for loss accounting: the shm half outlives the owner, but
    only on ``host`` — a cross-host consumer cannot reach it once the
    peer server is gone, and host-scoped durability would poison the
    driver's "durable ⇒ recoverable from anywhere" recovery contract.
    Treating it like a :class:`PeerRef` is conservative (same-host
    survivors merely recompute a value they could have mapped)."""
    shm: Encoded
    peer: PeerRef
    host: str           # machine id (channel.host_id) holding the segment


Handle = Union[Encoded, PeerRef, DualRef]


def is_durable(handle: Handle) -> bool:
    """Durable handles survive the owning worker's death (driver memory or
    tmpfs); a PeerRef is only as alive as its worker, and a DualRef's shm
    half is host-scoped (see :class:`DualRef`)."""
    return isinstance(handle, Encoded)


def pipe_nbytes(handle: Handle) -> int:
    if isinstance(handle, Encoded):
        return handle.pipe_nbytes()
    if isinstance(handle, DualRef):
        return handle.shm.pipe_nbytes() + 64
    return 64


def direct_nbytes(handle: Handle) -> int:
    if isinstance(handle, Encoded):
        return handle.direct_nbytes()
    if isinstance(handle, DualRef):
        return handle.peer.nbytes
    return handle.nbytes


# ------------------------------------------------------------ shm plumbing
def _untrack(seg) -> None:
    """Remove ``seg`` from this process's resource_tracker: lifecycle is
    driver-owned, and the tracker would otherwise unlink at *this*
    process's exit (CPython registers on both create and attach)."""
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(getattr(seg, "_name", seg.name),
                                    "shared_memory")
    except Exception:
        pass


_SHM_OK: Optional[bool] = None


def shm_available() -> bool:
    """Probe (once) whether POSIX shared memory works in this environment
    (containers sometimes mount no /dev/shm, or deny shm_open)."""
    global _SHM_OK
    if _SHM_OK is None:
        try:
            from multiprocessing.shared_memory import SharedMemory
            probe = SharedMemory(create=True, size=1,
                                 name=f"rrprobe{os.getpid():x}"
                                      f"{uuid.uuid4().hex[:6]}")
            probe.unlink()      # unlink() also unregisters from the tracker
            probe.close()
            _SHM_OK = True
        except Exception:
            _SHM_OK = False
    return _SHM_OK


def resolve_transport(transport: str, multihost: bool = False) -> str:
    """Map ``auto`` to the best channel this deployment supports.

    ``multihost=True`` means at least one worker may live on another
    machine: shm segments and unix sockets do not exist over there, so
    ``auto`` resolves to ``tcp`` and explicitly asking for a host-local
    transport is a clear error instead of a cross-host resolve failure.
    """
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown transport {transport!r} "
                         f"(expected one of {TRANSPORTS})")
    if multihost and transport not in CROSS_HOST_TRANSPORTS:
        raise ValueError(
            f"transport {transport!r} is host-local (shm segments / unix "
            f"sockets cannot cross machines); multi-host runs support "
            f"{CROSS_HOST_TRANSPORTS}")
    if transport != "auto":
        return transport
    if multihost:
        return "tcp"
    if shm_available():
        return "shm"
    if hasattr(socket, "AF_UNIX"):
        return "sock"
    return "driver"


class SegmentNamer:
    """Generates unique, run-scoped segment names (``<prefix>_<n>``) so the
    driver can sweep every segment of a run by glob, even orphans whose
    creating worker was SIGKILL'd before reporting the handle."""

    def __init__(self, prefix: str) -> None:
        self.prefix = prefix
        self._n = 0
        self._lock = threading.Lock()

    def __call__(self) -> str:
        with self._lock:
            self._n += 1
            return f"{self.prefix}_{self._n}"


def _write_segment(mv: memoryview, name: str) -> ShmRef:
    from multiprocessing.shared_memory import SharedMemory
    seg = SharedMemory(create=True, size=max(1, mv.nbytes), name=name)
    _untrack(seg)
    seg.buf[:mv.nbytes] = mv
    seg.close()
    return ShmRef(name, mv.nbytes)


def _read_segment(ref: ShmRef) -> bytearray:
    from multiprocessing.shared_memory import SharedMemory
    try:
        seg = SharedMemory(name=ref.name)
    except (FileNotFoundError, OSError) as e:
        raise TransferLost(f"shm segment {ref.name} gone: {e!r}") from e
    _untrack(seg)
    try:
        # bytearray keeps copy-decoded arrays writable (backend parity)
        return bytearray(seg.buf[:ref.nbytes])
    finally:
        seg.close()


def _unlink_ref(ref: ShmRef) -> None:
    path = os.path.join(_SHM_DIR, ref.name)
    try:
        os.unlink(path)
        return
    except FileNotFoundError:
        return
    except OSError:
        pass
    try:            # non-Linux fallback: attach + unlink through the API
        from multiprocessing.shared_memory import SharedMemory
        seg = SharedMemory(name=ref.name)   # attach registers; unlink()
        seg.unlink()                        # unregisters — tracker balanced
        seg.close()
    except Exception:
        pass


def release(handle: Optional[Handle]) -> None:
    """Driver-side: free a handle's shared-memory segments (idempotent)."""
    if isinstance(handle, DualRef):
        handle = handle.shm
    if isinstance(handle, Encoded):
        for ref in handle.shm_refs():
            _unlink_ref(ref)


def sweep_segments(prefix: str) -> int:
    """Unlink every ``/dev/shm`` segment of a run (by name prefix).  Run at
    driver exit: catches orphans from workers killed mid-publish, whose
    handles never reached the driver.  Returns the number unlinked."""
    if not prefix or not os.path.isdir(_SHM_DIR):
        return 0
    n = 0
    for path in glob.glob(os.path.join(_SHM_DIR, glob.escape(prefix) + "*")):
        try:
            os.unlink(path)
            n += 1
        except OSError:
            pass
    return n


def _segment_owner_pid(name: str) -> Optional[int]:
    """Parse the *driver* pid embedded in a run-scoped segment name.

    Run prefixes are ``rr{driver_pid:x}{8 uuid hex}`` (see the executor's
    ``seg_prefix``); namers append ``d``/``w<wid>`` and a ``_<n>``
    counter, bare :func:`encode` calls append nothing.  The pid and uuid
    halves are both hex, so the split anchors on structure: ``w`` is not
    a hex digit, ``d``-suffixed names always carry a ``_<n>`` counter,
    and the uuid half is exactly 8 chars.  Unparseable names return
    ``None`` — the sweep must never guess.
    """
    if not name.startswith("rr"):
        return None
    rest = name[2:]
    if "w" in rest:                     # rr<pid><uuid8>w<wid>_<n>
        head = rest.split("w", 1)[0]
    elif "_" in rest:                   # rr<pid><uuid8>d_<n>
        head = rest.split("_", 1)[0]
        if not head.endswith("d"):
            return None
        head = head[:-1]
    else:                               # rr<pid><uuid8>  (bare encode)
        head = rest
    if len(head) <= 8:
        return None
    try:
        pid = int(head[:-8], 16)
    except ValueError:
        return None
    # kernel pid_max tops out at 2**22; anything bigger is a foreign file
    # whose name happens to be hex, and os.kill(huge, 0) would raise
    # OverflowError instead of answering the liveness question
    return pid if 0 < pid < (1 << 22) else None


# ------------------------------------------------------------ resume leases
# A checkpointed run's segments must survive the driver's death for the
# rejoin window — they are the resume's recovery inputs.  A dead driver
# pid alone is therefore NOT license to sweep: the driver leaves a lease
# file next to the segments (refreshed while it runs) and the startup
# sweep honors any lease still inside its window.  Lease names start with
# a dot so the run-prefix globs (``rr*``) never see them.
_LEASE_PREFIX = ".rrlease-"
#: slack added to a lease's window: covers the gap between the driver's
#: last refresh and its death, plus resume/rejoin handshake time
LEASE_MARGIN = 30.0


def _lease_path(seg_prefix: str, shm_dir: Optional[str] = None) -> str:
    return os.path.join(_SHM_DIR if shm_dir is None else shm_dir,
                        _LEASE_PREFIX + seg_prefix)


def write_resume_lease(seg_prefix: str, run_id: str, window: float,
                       shm_dir: Optional[str] = None) -> Optional[str]:
    """Declare ``seg_prefix`` resumable: segments under it stay protected
    from the startup sweep until ``window + LEASE_MARGIN`` seconds after
    the lease's last refresh.  Returns the lease path (None if the shm
    dir does not exist — nothing to protect there)."""
    path = _lease_path(seg_prefix, shm_dir)
    try:
        with open(path, "w") as f:
            f.write(f"{run_id} {window:.1f}\n")
        return path
    except OSError:
        return None


def refresh_resume_lease(seg_prefix: str,
                         shm_dir: Optional[str] = None) -> None:
    """Bump the lease's clock (its mtime): the rejoin window counts from
    the driver's *death*, which is unknowable in advance, so the live
    driver keeps the lease fresh and the window effectively measures
    silence since the last refresh."""
    try:
        os.utime(_lease_path(seg_prefix, shm_dir))
    except OSError:
        pass


def clear_resume_lease(seg_prefix: str,
                       shm_dir: Optional[str] = None) -> None:
    """Clean shutdown: the run is over, its segments are swept, the lease
    goes with them (idempotent)."""
    try:
        os.unlink(_lease_path(seg_prefix, shm_dir))
    except OSError:
        pass


def _live_leases(shm_dir: str) -> List[str]:
    """Prefixes under an unexpired lease; expired lease files are reaped
    in passing."""
    now = time.time()
    live: List[str] = []
    for path in glob.glob(os.path.join(shm_dir, _LEASE_PREFIX + "*")):
        prefix = os.path.basename(path)[len(_LEASE_PREFIX):]
        window = 60.0
        try:
            with open(path) as f:
                parts = f.read().split()
            if len(parts) >= 2:
                window = float(parts[1])
            mtime = os.stat(path).st_mtime
        except (OSError, ValueError):
            continue                    # unreadable: keep it, protect it
        if now - mtime <= window + LEASE_MARGIN:
            live.append(prefix)
        else:
            try:
                os.unlink(path)         # expired: the run is not coming back
            except OSError:
                pass
    return live


def sweep_stale_segments(shm_dir: Optional[str] = None) -> int:
    """Startup sweep of ``rr*`` segments whose owning run is dead.

    A SIGKILL'd worker (or an emulated-crash driver) never runs its
    shutdown sweep, so its run's segments leak in ``/dev/shm`` until the
    *next* ``repro-worker`` on the host starts and calls this.  Scoped
    strictly to dead, non-resumable runs, on two independent tests:

    * **pid** — a segment is removed only when its name parses to a run
      prefix whose embedded driver pid no longer exists (an unparseable
      name or a live, even recycled, pid keeps the segment);
    * **lease** — a dead pid whose run left an unexpired resume lease
      (:func:`write_resume_lease`) is a *resumable* run inside its rejoin
      window: its segments are the resume's recovery inputs and are kept.
      This closes the race where a ``repro-worker`` starting on the
      driver's host swept a just-killed checkpointed run's segments
      moments before the resumed driver re-adopted them.

    Returns the number of segments unlinked.
    """
    shm_dir = _SHM_DIR if shm_dir is None else shm_dir
    if not os.path.isdir(shm_dir):
        return 0
    leased = _live_leases(shm_dir)
    n = 0
    for path in glob.glob(os.path.join(shm_dir, "rr*")):
        name = os.path.basename(path)
        pid = _segment_owner_pid(name)
        if pid is None or pid <= 0:
            continue
        if any(name.startswith(p) for p in leased):
            continue                    # resumable run inside its window
        try:
            os.kill(pid, 0)
            continue                    # owner alive: not ours to touch
        except ProcessLookupError:
            pass                        # owner dead: stale residue
        except OSError:
            continue                    # EPERM etc: owner exists, skip
        try:
            os.unlink(path)
            n += 1
        except OSError:
            pass
    return n


def sweep_peer_sockets(peer_dir: Optional[str]) -> int:
    """Remove a run's :class:`PeerServer` unix-socket files and their
    tmpdir.  Part of the same shutdown sweep as :func:`sweep_segments`: a
    SIGKILL'd worker never runs ``PeerServer.close``, so its ``w<id>.sock``
    would otherwise outlive the run in the tmpdir.  Returns the number of
    socket files removed (idempotent; a missing dir is fine)."""
    if not peer_dir or not os.path.isdir(peer_dir):
        return 0
    n = 0
    for name in os.listdir(peer_dir):
        if not name.endswith(".sock"):
            continue
        try:
            os.unlink(os.path.join(peer_dir, name))
            n += 1
        except OSError:
            pass
    try:
        os.rmdir(peer_dir)
    except OSError:          # non-socket stragglers: take the dir anyway
        import shutil
        shutil.rmtree(peer_dir, ignore_errors=True)
    return n


# ------------------------------------------------------------ encode/decode
def encode(value: Any, *, transport: str = "shm",
           threshold: int = SHM_THRESHOLD,
           namer: Optional[Callable[[], str]] = None) -> Encoded:
    """Serialize ``value`` with pickle protocol 5; spill large buffers (and
    a large pickle stream) to shared memory when ``transport == 'shm'``.
    Raises whatever pickle raises for unserializable values — callers turn
    that into a task error, never a worker death."""
    threshold = max(1, threshold)
    raw: List[pickle.PickleBuffer] = []
    data = pickle.dumps(value, protocol=5, buffer_callback=raw.append)
    use_shm = transport == "shm" and shm_available()
    gen = namer or (lambda: f"rr{os.getpid():x}{uuid.uuid4().hex[:8]}")
    total = len(data)
    buffers: List[Union[bytes, ShmRef]] = []
    for pb in raw:
        mv = pb.raw()
        total += mv.nbytes
        if use_shm and mv.nbytes >= threshold:
            buffers.append(_write_segment(mv, gen()))
        else:
            # bytearray, not bytes: reconstructed arrays stay writable,
            # matching what the thread/sequential backends hand back
            buffers.append(bytearray(mv))
        pb.release()
    stream: Union[bytes, ShmRef] = data
    if use_shm and len(data) >= threshold:
        stream = _write_segment(memoryview(data), gen())
    return Encoded(stream, buffers, total)


class SegmentKeeper:
    """Pins shared-memory attachments alive for zero-copy decoded values.

    A zero-copy decode reconstructs arrays *viewing* the mapped segment, and
    a pure task's output may alias its input (identity, slicing), so a held
    mapping can never be safely unmapped — it is pinned for the life of the
    process and reclaimed by the OS at exit (``seg.close`` is disarmed so
    ``SharedMemory.__del__`` doesn't raise ``BufferError`` over the live
    array views at interpreter shutdown).  Unlinking (the driver's job) is
    safe while held: POSIX keeps the pages until the last mapping dies.
    Workers use a keeper; the driver, which outlives runs, always takes the
    copying path instead.
    """

    def __init__(self) -> None:
        self._segs: List[Any] = []

    def hold(self, seg: Any) -> None:
        seg.close = lambda: None     # pinned: only process exit unmaps
        self._segs.append(seg)

    def close(self) -> None:
        """Drop the pin bookkeeping (mappings live until process exit)."""
        self._segs.clear()


def _attach_view(ref: ShmRef, keeper: SegmentKeeper) -> memoryview:
    from multiprocessing.shared_memory import SharedMemory
    try:
        seg = SharedMemory(name=ref.name)
    except (FileNotFoundError, OSError) as e:
        raise TransferLost(f"shm segment {ref.name} gone: {e!r}") from e
    _untrack(seg)
    keeper.hold(seg)
    return seg.buf[:ref.nbytes]


def decode(enc: Encoded, keeper: Optional[SegmentKeeper] = None) -> Any:
    """Reconstruct the value from an :class:`Encoded`.

    Without a ``keeper`` shared-memory parts are copied out and unmapped
    immediately — the safe mode for the long-lived driver, where eager
    unlink must never race a held mapping.  With a ``keeper`` the decode is
    **zero-copy**: array buffers alias the mapping (exactly the object
    sharing the thread backend gets for free), and the keeper pins the
    attachment until process exit.  Raises :class:`TransferLost` if a
    segment was already unlinked."""
    if keeper is None:
        data: Any = _read_segment(enc.data) \
            if isinstance(enc.data, ShmRef) else enc.data
        buffers = [_read_segment(b) if isinstance(b, ShmRef) else b
                   for b in enc.buffers]
    else:
        data = _attach_view(enc.data, keeper) \
            if isinstance(enc.data, ShmRef) else enc.data
        buffers = [_attach_view(b, keeper) if isinstance(b, ShmRef) else b
                   for b in enc.buffers]
    return pickle.loads(data, buffers=buffers)


def resolve(handle: Handle,
            keeper: Optional[SegmentKeeper] = None) -> Any:
    """Materialize any handle: decode shm/inline, or pull from a peer.

    A :class:`DualRef` resolves by **host identity**: a consumer on the
    owner's machine maps the shared-memory half (zero-copy, no sockets),
    anyone else — or a same-host consumer racing a GC unlink — pulls over
    the TCP peer server."""
    if isinstance(handle, Encoded):
        return decode(handle, keeper)
    if isinstance(handle, DualRef):
        if handle.host == _this_host():
            try:
                return decode(handle.shm, keeper)
            except TransferLost:
                pass        # segment swept under us: the peer may live on
        return peer_fetch(handle.peer)
    if isinstance(handle, PeerRef):
        return peer_fetch(handle)
    raise TypeError(f"not a transfer handle: {type(handle).__name__}")


_HOST_ID: Optional[str] = None


def _this_host() -> str:
    global _HOST_ID
    if _HOST_ID is None:
        from .channel import host_id
        _HOST_ID = host_id()
    return _HOST_ID


# ------------------------------------------------------------- peer channel
_LEN = struct.Struct("<q")
_SECRET_LEN = 32            # uuid4().hex — fixed-width capability token

# exact-read is shared with the control channel's framing (ChannelClosed
# subclasses ConnectionError, so existing handlers here keep working)
from .channel import _recv_exact        # noqa: E402


class PeerServer:
    """Worker-side socket server: peers (and the driver, for final
    collection) pull values straight from this worker's local store,
    bypassing the driver control channel entirely.  One request per
    connection: ``<tid:int64>`` in, ``<len:int64><pickled Encoded>`` out
    (len == -1 when the value is not in the store).

    Two address families share the protocol: a unix-domain socket at
    ``path`` (the single-host ``sock`` transport), or — when ``path`` is
    ``None`` — a TCP socket bound to an ephemeral port and advertised as
    ``tcp://<advertise_host>:<port>`` (the multi-host ``tcp`` transport,
    where a consumer on another machine dials the producer directly).
    :attr:`path` is the advertised address either way, and is what goes
    into every :class:`PeerRef` this worker hands out.
    """

    def __init__(self, path: Optional[str], store: Dict[int, Any], *,
                 advertise_host: str = "127.0.0.1") -> None:
        self._store = store
        self._unix_path: Optional[str] = path
        if path is not None:
            self.secret = ""        # unix: filesystem perms are the gate
            self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                os.unlink(path)      # stale file from a recycled wid/run
            except OSError:
                pass
            self._sock.bind(path)
            self.path = path
        else:
            # TCP: an open port on 0.0.0.0 — requests must present the
            # per-server capability secret, which travels only inside
            # PeerRefs on the authenticated control channel
            self.secret = uuid.uuid4().hex
            self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._sock.bind(("0.0.0.0", 0))
            self.path = f"tcp://{advertise_host}:{self._sock.getsockname()[1]}"
        self._sock.listen(16)
        self._closed = False
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"peer-server-{os.path.basename(self.path)}"
                         ).start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_one, args=(conn,),
                             daemon=True).start()

    def _serve_one(self, conn: socket.socket) -> None:
        try:
            with conn:
                # a client that connects and goes silent (port scanner on
                # the open TCP family) must not pin this thread forever
                conn.settimeout(60.0)
                (tid,) = _LEN.unpack(_recv_exact(conn, _LEN.size))
                if self.secret:
                    import hmac
                    got = _recv_exact(conn, _SECRET_LEN)
                    if not hmac.compare_digest(got, self.secret.encode()):
                        return      # unauthorized: drop the connection
                if tid not in self._store:
                    conn.sendall(_LEN.pack(-1))
                    return
                enc = encode(self._store[tid], transport="driver")
                blob = pickle.dumps(enc, protocol=5)
                conn.sendall(_LEN.pack(len(blob)) + blob)
        except Exception:
            pass        # consumer sees a broken stream -> TransferLost

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass
        if self._unix_path is not None:
            try:
                os.unlink(self._unix_path)
            except OSError:
                pass


def _peer_connect(addr: str, timeout: float) -> socket.socket:
    """Dial a peer address: ``tcp://host:port`` or a unix-socket path."""
    if addr.startswith("tcp://"):
        host, _, port = addr[len("tcp://"):].rpartition(":")
        return socket.create_connection((host, int(port)), timeout=timeout)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(timeout)
    sock.connect(addr)
    return sock


# Process-local fault/retry configuration for the data plane.  Workers
# install these from their run config (every worker process sets them at
# startup — fork children must not inherit a stale hook from a previous
# in-process run); the driver keeps the defaults unless a caller passes
# an explicit policy.
_FETCH_FAULT_HOOK: Optional[Callable[[PeerRef, int], None]] = None
_DEFAULT_RETRY: Optional[Any] = None


def set_fetch_fault(hook: Optional[Callable[[PeerRef, int], None]]) -> None:
    """Install (or clear, with ``None``) the per-process fault-injection
    hook: called as ``hook(ref, attempt)`` at the top of every peer-fetch
    attempt.  May sleep (delay faults) or raise :class:`TransferLost`
    (transfer failures) — see :meth:`repro.faults.FaultPlan.fetch_hook`."""
    global _FETCH_FAULT_HOOK
    _FETCH_FAULT_HOOK = hook


def set_default_retry(policy: Optional[Any]) -> None:
    """Set this process's default :class:`repro.faults.RetryPolicy` for
    peer fetches (``None`` restores the built-in default)."""
    global _DEFAULT_RETRY
    _DEFAULT_RETRY = policy


def default_retry() -> Any:
    if _DEFAULT_RETRY is not None:
        return _DEFAULT_RETRY
    from repro.faults.retry import RetryPolicy
    return RetryPolicy(attempts=3, base_delay=0.05, factor=2.0,
                       max_delay=1.0)


def _peer_fetch_once(ref: PeerRef, timeout: float) -> Any:
    try:
        with _peer_connect(ref.addr, timeout) as sock:
            sock.settimeout(timeout)
            request = _LEN.pack(ref.tid)
            if ref.addr.startswith("tcp://"):
                secret = ref.secret.encode()
                if len(secret) != _SECRET_LEN:
                    e = TransferLost(
                        f"peer ref for task {ref.tid} carries no valid "
                        f"capability secret")
                    e.retryable = False     # malformed ref: retry is futile
                    raise e
                request += secret
            sock.sendall(request)
            (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
            if n < 0:
                e = TransferLost(
                    f"peer {ref.addr} no longer holds task {ref.tid}")
                e.retryable = False     # a definitive answer, not a flake
                raise e
            blob = _recv_exact(sock, n)
    except TransferLost:
        raise
    except (OSError, ConnectionError, socket.timeout) as e:
        raise TransferLost(
            f"peer {ref.addr} unreachable for task {ref.tid}: {e!r}") from e
    try:
        return decode(pickle.loads(blob))
    except TransferLost:
        raise
    except Exception as e:      # truncated/garbled stream: the peer died
        # mid-write (or something that isn't a PeerServer answered)
        raise TransferLost(
            f"peer {ref.addr} sent a corrupt stream for task "
            f"{ref.tid}: {e!r}") from e


def peer_fetch(ref: PeerRef, timeout: float = 30.0,
               retry: Optional[Any] = None) -> Any:
    """Pull ``ref.tid`` from the owning worker's socket (unix or TCP).

    Transient failures (unreachable peer, timeout, truncated stream) are
    retried under ``retry`` — default: this process's
    :func:`set_default_retry` policy, else a small bounded backoff.
    Definitive failures (the owner answered that it no longer holds the
    value) surface immediately.  When retries exhaust, the failure is
    still a :class:`TransferLost` — the caller degrades from there
    (driver-relay fallback, then lineage recovery)."""
    policy = retry if retry is not None else default_retry()

    def attempt(i: int) -> Any:
        if _FETCH_FAULT_HOOK is not None:
            _FETCH_FAULT_HOOK(ref, i)
        return _peer_fetch_once(ref, timeout)

    return policy.run(
        attempt,
        retryable=lambda e: isinstance(e, TransferLost)
        and getattr(e, "retryable", True))


# ------------------------------------------------------------------- sizing
def payload_nbytes(value: Any) -> int:
    """Cheap recursive payload-size estimate (exact for array leaves via
    ``.nbytes``); recorded per completed task and fed to the
    transfer-cost-aware placement score."""
    nb = getattr(value, "nbytes", None)
    if isinstance(nb, int):
        return nb
    if isinstance(value, (bytes, bytearray, memoryview)):
        return len(value)
    if isinstance(value, (str,)):
        return len(value)
    if isinstance(value, (list, tuple, set, frozenset)):
        return 64 + sum(payload_nbytes(v) for v in value)
    if isinstance(value, dict):
        return 64 + sum(payload_nbytes(k) + payload_nbytes(v)
                        for k, v in value.items())
    try:
        return sys.getsizeof(value)
    except Exception:
        return 64
