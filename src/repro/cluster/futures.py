"""Futures for async graph submission (``submit``/``gather``).

A :class:`ClusterFuture` is the driver-side handle for one submitted
:class:`~repro.core.graph.TaskGraph`.  The heavy lifting happens on a
background driver thread per submission; every run gets a fresh worker
pool, and submissions to the SAME executor queue behind its run lock (its
stats are per-run) — use one executor per job for true concurrency.  The
future carries completion state across threads plus a snapshot of the
run's ``stats`` (including the data-plane counters ``bytes_moved`` /
``transfers_direct`` / ``transfers_driver`` and the speculation counters
``n_speculative`` / ``speculative_wins`` / ``speculative_wasted_s``) and
``wall_time``, so callers of overlapping submissions don't race on the
executor's per-run fields.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional


class ClusterFuture:
    def __init__(self, label: str = "") -> None:
        self.label = label
        self._event = threading.Event()
        self._result: Optional[Dict[int, Any]] = None
        self._error: Optional[BaseException] = None
        self._stats: Dict[str, int] = {}
        self._wall_time = 0.0

    # -- producer side ------------------------------------------------------
    def _set_result(self, value: Dict[int, Any],
                    stats: Optional[Dict[str, int]] = None,
                    wall_time: float = 0.0) -> None:
        self._result = value
        self._stats = dict(stats or {})
        self._wall_time = wall_time
        self._event.set()

    def _set_error(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()

    # -- consumer side ------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Dict[int, Any]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"future {self.label or id(self)} not done "
                               f"after {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def exception(self, timeout: Optional[float] = None):
        self._event.wait(timeout)
        return self._error

    @property
    def stats(self) -> Dict[str, int]:
        """Per-run stats snapshot (empty until the run completes)."""
        return dict(self._stats)

    @property
    def wall_time(self) -> float:
        return self._wall_time


def gather(*futures: ClusterFuture,
           timeout: Optional[float] = None) -> List[Dict[int, Any]]:
    """Wait for every future; returns their results in argument order.
    ``timeout`` bounds the TOTAL wait (shared deadline across futures).
    The first error encountered is raised (after all futures settle)."""
    deadline = None if timeout is None else time.monotonic() + timeout
    for f in futures:
        remaining = (None if deadline is None
                     else max(0.0, deadline - time.monotonic()))
        if not f._event.wait(remaining):
            raise TimeoutError(
                f"gather: future {f.label or id(f)} not done within "
                f"{timeout}s total")
    return [f.result(0) for f in futures]
