"""Worker-process side of the cluster runtime.

A worker is one OS process connected to the driver by a control-plane
*channel* (:mod:`repro.cluster.channel`): a duplex pipe for forked/spawned
in-host workers, or a framed TCP stream for workers dialed in from other
hosts.  The worker body below is channel-agnostic — it sees only blocking
``recv()``/``send()`` with :class:`~repro.cluster.channel.ChannelClosed`
as the "driver gone" signal.

Since the fusion pass (:mod:`repro.core.fusion`) the unit of dispatch is a
**super-task**: one ``run`` message names a cluster id and the worker
executes every member task locally, in topo order, inside one Python
frame.  Intermediate member values never touch ``serde`` or the control
channel — only *kept* values (cluster outputs another cluster or the
driver will read) land in the local store.  With fusion off every cluster
is a single task and the behavior is exactly the pre-fusion worker.

It owns a *local object store* (``{tid: value}``) holding the kept results
of every cluster it has executed — plus, since the zero-copy data plane, a
replica of every transferred input it has resolved (reported back to the
driver in the ``done`` message so replica sets stay exact).  Bulk values do
not cross the control channel: a ``fetch`` is answered with a small
*handle* (:class:`~repro.cluster.serde.Encoded` shared-memory refs, a
``PeerRef`` to this worker's unix/TCP socket server, or — on a TCP data
plane with same-host peers — a ``DualRef`` publishing both, letting each
consumer pick shm or socket by host id), and the consumer maps/pulls the
payload directly — worker-to-worker, driver untouched.

Message protocol (tuples; first element is the verb; ``cid`` is a cluster
id from the run's fusion plan — equal to the task id when fusion is off):

  driver -> worker
    ("run",   cid, extra)   execute super-task ``cid``; ``extra`` maps
                            input value tid -> transfer handle for external
                            inputs not already in this worker's store
    ("fetch", tid)          publish value ``tid`` and reply with its handle
    ("fetch_many", tids)    publish a batch (final collection): one
                            ``value_many`` reply carries every handle
    ("drop",  tids)         free stored values (driver-coordinated GC)
    ("cancel", cid)         a speculative twin of ``cid`` won elsewhere:
                            best-effort abort.  Idempotent — a queued run
                            of ``cid`` is skipped (acked ``cancelled``); a
                            run already executing completes and reports a
                            late ``done`` the driver reconciles; a cid
                            this worker never sees again is a no-op (the
                            mark is consumed by the next run or by the
                            super-task's own completion)
    ("batch", msgs)         a coalesced burst of the above (one frame /
                            syscall; unwrapped here, order preserved)
    ("hb",)                 keepalive (TCP channels; refreshes liveness)
    ("die",)                chaos hook: SIGKILL self (the driver cannot
                            signal a remote pid directly)
    ("stop",)               drain and exit

  worker -> driver
    ("done",    wid, cid, wall, sizes, replicated)
                            super-task finished; kept values stay local.
                            ``sizes`` maps kept member tid -> payload
                            bytes (locality-aware placement); ``replicated``
                            lists input value tids this worker now also
                            holds.
    ("error",   wid, cid, name, repr)    a member raised — surfaced as a
                            task error, never a worker death
    ("fetch_error", wid, tid, name, repr)  a fetch reply's VALUE could not
                            be serialized; a separate verb because value
                            tids and super-task ids are different
                            namespaces under fusion
    ("value",   wid, tid, found, handle) fetch reply (handle, not payload)
    ("value_many", wid, entries)         fetch_many reply: a list of
                            ``(tid, found, handle)`` triples in one frame
    ("deplost", wid, cid, deps)          transfer handles in a ``run`` could
                            not be resolved (owner died mid-transfer);
                            driver re-queues the super-task, recovers deps
    ("cancelled", wid, cid)              a queued run of ``cid`` was skipped
                            because a ``cancel`` (possibly stale) covered
                            it; the driver re-queues it if still wanted
    ("batch",   msgs)                    coalesced burst of the above (the
                            sender thread drains its outbox greedily)
    ("hb",)                              heartbeat (TCP channels)
    ("bye",     wid)                     explicit goodbye: clean shutdown,
                            never to be mistaken for a missed-heartbeat
                            death

Fork-started workers inherit the (closure-bearing, generally unpicklable)
:class:`~repro.core.graph.TaskGraph` and the run's ``inputs`` dict by
memory copy; spawn-started and remote TCP workers receive them pickled
(via process args or the handshake's welcome frame) — the paper's "ship
the program to every node" step either way.  The run's
:class:`~repro.core.fusion.WorkerFusionView` (cluster member lists + keep
sets, a few bytes per task) travels the same way, after which per-cluster
messages carry only ids and handles, independent of payload size.
"""
from __future__ import annotations

import os
import pickle
import signal
from typing import Any, Dict, List, Optional, Sequence

from repro.core.executor import _run_node as run_node   # noqa: F401 — the
# worker executes nodes with the EXACT core implementation so both backends
# share semantics (including the MissingInput contract; the driver re-raises
# it by name on its side)
from repro.core.fusion import WorkerFusionView
from repro.core.graph import TaskGraph

from . import serde
from .channel import (ChannelClosed, WorkerPipeEndpoint, host_id,
                      wrap_batch)

#: how many queued replies the sender thread folds into one batch frame
_SEND_BATCH = 64


def pipe_worker_main(wid: int, conn, graph: TaskGraph,
                     inputs: Optional[Dict[str, Any]],
                     transport: str = "driver",
                     shm_threshold: int = serde.SHM_THRESHOLD,
                     seg_prefix: str = "",
                     peer_dir: Optional[str] = None,
                     fusion: Optional[WorkerFusionView] = None,
                     fault_plan: Any = None,
                     fetch_retry: Any = None) -> None:
    """Process entrypoint for pipe/spawn channel workers: wrap the raw
    duplex-pipe connection in the channel-agnostic endpoint and run."""
    worker_main(wid, WorkerPipeEndpoint(conn), graph, inputs, transport,
                shm_threshold, seg_prefix, peer_dir, fusion=fusion,
                fault_plan=fault_plan, fetch_retry=fetch_retry)


def worker_main(wid: int, chan, graph: TaskGraph,
                inputs: Optional[Dict[str, Any]],
                transport: str = "driver",
                shm_threshold: int = serde.SHM_THRESHOLD,
                seg_prefix: str = "",
                peer_dir: Optional[str] = None,
                peer_host: str = "127.0.0.1",
                fusion: Optional[WorkerFusionView] = None,
                fault_plan: Any = None,
                fetch_retry: Any = None) -> None:
    """Worker body: reader thread + sender thread + compute loop, over any
    control channel ``chan`` (blocking ``recv``/``send`` endpoint).

    Deadlock-freedom argument (handles are small, but driver-transport
    payloads can still exceed the kernel pipe/socket buffer): the reader
    thread does *nothing but recv*, so the driver's blocking
    dispatch-sends always drain; the sender thread does *nothing but send*
    from an outbox queue, so neither the reader nor a long-running task can
    ever stall an outgoing reply; the driver's pump loop drains worker
    output whenever it isn't mid-send.  Any single blocked channel
    therefore unblocks without waiting on this process's compute.

    The reader answers ``fetch``/``drop`` directly (peers' input transfers
    are served while a task is running); ``run``/``stop`` are queued for
    the compute loop.  ``store`` accesses are single-op (GIL-atomic) dict
    operations.
    """
    import queue
    import threading
    import time

    # data-plane fault injection + retry policy for THIS process's peer
    # fetches (docs/faults.md).  Installed unconditionally: a forked worker
    # inherits the parent's process-global serde state, so a run without a
    # plan must actively clear whatever an earlier run installed.
    serde.set_fetch_fault(fault_plan.fetch_hook(wid)
                          if fault_plan is not None else None)
    serde.set_default_retry(fetch_retry)

    # resident-mode "graph" deltas mutate the inputs table in place, so a
    # None (no inputs) run still needs one real dict shared by the reader
    # closure and the compute loop
    inputs = dict(inputs) if inputs else {}

    store: Dict[int, Any] = {}
    published: Dict[int, serde.Handle] = {}     # memoized publish per tid
    cancelled: set = set()      # cids whose next queued run is to be skipped
    # (set add/discard are GIL-atomic: reader marks, compute loop consumes)
    keeper = serde.SegmentKeeper()      # pins zero-copy decoded mappings
    runq: "queue.SimpleQueue[tuple]" = queue.SimpleQueue()
    outq: "queue.SimpleQueue[Optional[tuple]]" = queue.SimpleQueue()
    namer = serde.SegmentNamer(f"{seg_prefix}w{wid}") if seg_prefix else None
    my_host = host_id()

    if getattr(chan, "supports_rejoin", False):
        # Driver-restart re-adoption: the first frame on a rejoined socket
        # is this worker's object-store inventory, which the resumed
        # driver reconciles against its checkpoint.  The compute loop may
        # mutate the store mid-snapshot, hence the retry.
        def _inventory():
            snap: List[tuple] = []
            for _ in range(8):
                try:
                    snap = list(store.items())
                    break
                except RuntimeError:
                    continue
            return [(tid, serde.payload_nbytes(v)) for tid, v in snap]

        chan.inventory_fn = _inventory

    peer_server: Optional[serde.PeerServer] = None
    if transport == "sock" and peer_dir:
        try:
            peer_server = serde.PeerServer(
                os.path.join(peer_dir, f"w{wid}.sock"), store)
        except OSError:
            peer_server = None      # degrade to inline (driver) publishes
    elif transport == "tcp":
        try:
            peer_server = serde.PeerServer(None, store,
                                           advertise_host=peer_host)
        except OSError:
            peer_server = None

    # A DualRef's shm half lives on THIS machine, which the driver — the
    # usual unlink authority — cannot reach when this worker is on
    # another host, so the worker cleans up its own dual-published
    # segments: a driver-coordinated "drop" unlinks immediately (the
    # driver released its reference before sending the drop; idempotent
    # if it already unlinked on a single-host run, and a same-host
    # consumer caught mid-resolve falls back to the peer half), while a
    # mid-run re-publish merely *retires* the old handle — the driver may
    # still be shipping it — for the shutdown sweep.
    retired: List[serde.Handle] = []

    def unpublish(tid: int, now: bool) -> None:
        handle = published.pop(tid, None)
        if isinstance(handle, serde.DualRef):
            if now:
                serde.release(handle)
            else:
                retired.append(handle)

    def members_of(cid: int):
        if fusion is None:
            return (cid,)
        return fusion.members.get(cid, (cid,))

    def keep_of(cid: int):
        if fusion is None:
            return (cid,)
        return fusion.keep.get(cid, members_of(cid))

    def publish(tid: int) -> serde.Handle:
        """Produce (and memoize) the transfer handle for a stored value:
        shm-backed Encoded, a PeerRef to this worker's socket server, a
        DualRef publishing both (TCP data plane with shm available — the
        same-host fast path in mixed-host pools), or inline bytes for
        small values / driver transport."""
        handle = published.get(tid)
        if handle is not None:
            return handle
        value = store[tid]
        nbytes = serde.payload_nbytes(value)
        if peer_server is not None and nbytes >= shm_threshold:
            peer = serde.PeerRef(peer_server.path, tid, nbytes, wid,
                                 secret=peer_server.secret)
            handle = peer
            if (transport == "tcp" and namer is not None
                    and serde.shm_available()):
                # mixed-host tcp run: publish BOTH ways, consumers pick
                # by host id (same-host -> mmap, cross-host -> TCP pull)
                try:
                    handle = serde.DualRef(
                        serde.encode(value, transport="shm",
                                     threshold=shm_threshold, namer=namer),
                        peer, my_host)
                except Exception:   # shm full / shm_open denied for THIS
                    pass            # size: the peer half alone is the
                    # PR-3 behavior and always works — a fast path must
                    # never turn a publishable value into a run abort
        else:
            handle = serde.encode(
                value, transport="driver" if transport in ("sock", "tcp")
                else transport, threshold=shm_threshold, namer=namer)
        published[tid] = handle
        return handle

    def sender() -> None:
        """Drain the outbox; coalesce bursts into one batch frame (one
        pickle + one syscall) so a super-task finishing while fetch
        replies queue behind it costs a single write."""
        while True:
            msg = outq.get()
            if msg is None:
                return
            batch: List[tuple] = [msg]
            while len(batch) < _SEND_BATCH:
                try:
                    nxt = outq.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    _send_batch(batch)
                    return
                batch.append(nxt)
            _send_batch(batch)

    def _send_batch(batch: List[tuple]) -> None:
        try:
            wrapped = wrap_batch(batch)
            if wrapped is not None:
                chan.send(wrapped)
            return
        except ChannelClosed:
            return
        except Exception:
            pass        # fall through: isolate the poisoned message
        flat: List[tuple] = []  # unpicklable/oversized payload in a reply:
        for msg in batch:       # report it as a task error instead of
            # wedging the outbox (which would read as a dead worker).  A
            # value_many frame decomposes to per-value replies first, so
            # the fatal report names the exact poisoned value, not the
            # whole bulk reply
            if msg[0] == "value_many":
                flat.extend(("value", msg[1], t, found, handle)
                            for t, found, handle in msg[2])
            else:
                flat.append(msg)
        for msg in flat:
            try:
                chan.send(msg)
            except ChannelClosed:
                return
            except Exception as e:
                tid = msg[2] if len(msg) > 2 and isinstance(msg[2], int) \
                    else -1
                # a poisoned fetch reply carries a VALUE id; everything
                # else (done/deplost/...) names its super-task
                verb = "fetch_error" if msg[0] == "value" else "error"
                try:
                    chan.send((verb, wid, tid,
                               "SerializationError", repr(e)))
                except ChannelClosed:
                    return
                except Exception:
                    pass

    def handle_ctrl(msg: tuple) -> bool:
        """Reader-thread dispatch of one control message.  Returns False
        once the compute loop owns shutdown (``stop`` queued)."""
        verb = msg[0]
        if verb == "batch":
            for m in msg[1]:
                if not handle_ctrl(m):
                    return False
            return True
        if verb == "fetch":
            tid = msg[1]
            if tid not in store:
                outq.put(("value", wid, tid, False, None))
            else:
                try:
                    outq.put(("value", wid, tid, True, publish(tid)))
                except Exception as e:  # noqa: BLE001 — a value that
                    # cannot be serialized must surface on the consumer's
                    # future as a task error, not kill this worker.
                    # fetch_error, NOT error: this tid is a VALUE id, and
                    # under fusion the driver's error handler would read
                    # it as a cluster id and corrupt an unrelated
                    # super-task's runner bookkeeping
                    outq.put(("fetch_error", wid, tid,
                              "SerializationError", repr(e)))
        elif verb == "fetch_many":
            # bulk publication (final collection): one request, one reply
            # carrying every handle — the driver's per-value fetch loop
            # collapsed into a single round-trip per worker
            entries: List[tuple] = []
            for tid in msg[1]:
                if tid not in store:
                    entries.append((tid, False, None))
                    continue
                try:
                    entries.append((tid, True, publish(tid)))
                except Exception as e:  # noqa: BLE001 — same contract as
                    outq.put(("fetch_error", wid, tid,      # single fetch
                              "SerializationError", repr(e)))
            outq.put(("value_many", wid, entries))
        elif verb == "drop":
            for t in msg[1]:
                store.pop(t, None)
                unpublish(t, now=True)
        elif verb == "graph":
            # resident-mode job delta: the driver admitted (or retired) a
            # tenant job mid-run.  Admitted ids are disjoint from every id
            # already known (each job owns a private range) and retired
            # ids belong to terminal jobs whose runs were cancelled first,
            # so the compute loop can keep executing while these dicts
            # change — every mutation is a GIL-atomic dict op on keys the
            # loop is not touching.  The payload is pre-pickled once on
            # the driver and fanned out as bytes to every worker.
            delta = pickle.loads(msg[1])
            graph.nodes.update(delta.get("nodes", {}))
            inputs.update(delta.get("inputs", {}))
            if fusion is not None:
                fusion.members.update(delta.get("members", {}))
                fusion.keep.update(delta.get("keep", {}))
            for t in delta.get("retire", ()):
                graph.nodes.pop(t, None)
                store.pop(t, None)
                unpublish(t, now=True)
                cancelled.discard(t)
                if fusion is not None:
                    fusion.members.pop(t, None)
                    fusion.keep.pop(t, None)
            for name in delta.get("retire_inputs", ()):
                inputs.pop(name, None)
        elif verb == "cancel":
            # best-effort, between super-tasks: mark the cid; the compute
            # loop skips a queued run of it (a run already executing
            # finishes and the driver reconciles the late done)
            cancelled.add(msg[1])
        elif verb == "hb":
            pass                     # endpoint already refreshed liveness
        elif verb == "die":          # chaos hook for remote workers
            os.kill(os.getpid(), signal.SIGKILL)
        else:                        # "run" / "stop"
            runq.put(msg)
            if verb == "stop":
                return False
        return True

    def reader() -> None:
        while True:
            try:
                msg = chan.recv()
            except ChannelClosed:
                runq.put(("stop",))      # driver went away
                return
            if not handle_ctrl(msg):
                return

    send_thread = threading.Thread(target=sender, daemon=True,
                                   name=f"worker-{wid}-sender")
    send_thread.start()
    threading.Thread(target=reader, daemon=True,
                     name=f"worker-{wid}-reader").start()
    while True:
        msg = runq.get()
        verb = msg[0]
        if verb == "stop":
            if peer_server is not None:
                peer_server.close()
            # shutdown sweep for THIS host's dual-published segments: the
            # driver's run-prefix sweep only reaches its own /dev/shm
            for handle in retired:
                serde.release(handle)
            for handle in published.values():
                if isinstance(handle, serde.DualRef):
                    serde.release(handle)
            outq.put(("bye", wid))
            outq.put(None)
            send_thread.join(timeout=5.0)
            keeper.close()       # last mappings: safe, nothing runs after
            chan.close()
            return
        if verb != "run":                # pragma: no cover — protocol bug
            raise RuntimeError(f"worker {wid}: unknown message {verb!r}")
        _, cid, extra = msg
        if cid in cancelled:
            # the winner already finished elsewhere; the mark is consumed
            # so a FUTURE legitimate dispatch of the same cid (lineage
            # recovery after a GC) runs normally — and the ack lets the
            # driver re-queue if this run was in fact still wanted
            cancelled.discard(cid)
            outq.put(("cancelled", wid, cid))
            continue
        t0 = time.perf_counter()
        cur = None      # member being executed, for the error report —
        # bound BEFORE the resolve loop: a failure there must still reach
        # the except arm below, not die on an unbound name
        try:
            frame: Dict[int, Any] = {}   # this super-task's value table
            lost: List[int] = []
            replicated: List[int] = []
            for d, handle in extra.items():
                try:        # zero-copy: arrays view the mapped segment
                    frame[d] = serde.resolve(handle, keeper)
                except serde.TransferLost:
                    lost.append(d)
            if lost:
                # owner died (or GC raced) between dispatch and resolve:
                # hand the super-task back; the driver recovers the inputs
                outq.put(("deplost", wid, cid, lost))
                continue
            for d, v in frame.items():   # keep transferred inputs: replicas
                store[d] = v
                unpublish(d, now=False)
                replicated.append(d)
            # run every member locally, in topo order, in ONE frame:
            # intermediates live and die here — no store write, no
            # publish, no control message (the fusion win)
            aborted = False
            for m in members_of(cid):
                if cid in cancelled:
                    # cooperative mid-task cancel: a speculation loser
                    # stops at the next member boundary instead of running
                    # the whole frame to completion.  Nothing from the
                    # aborted frame reaches the store; the ack carries the
                    # partial wall so the driver can account true waste.
                    aborted = True
                    break
                cur = m
                for d in graph.nodes[m].all_deps:
                    if d not in frame:
                        frame[d] = store[d]
                frame[m] = run_node(graph, m, frame, inputs)
            cur = None
            if aborted:
                cancelled.discard(cid)
                outq.put(("cancelled", wid, cid, replicated,
                          time.perf_counter() - t0))
                continue
            sizes: Dict[int, int] = {}
            for m in keep_of(cid):
                store[m] = frame[m]
                unpublish(m, now=False)  # recompute invalidates old handle
                sizes[m] = serde.payload_nbytes(frame[m])
            # a cancel that raced the execution is moot now — consume the
            # mark so it cannot eat a future re-dispatch of this cid
            cancelled.discard(cid)
            outq.put(("done", wid, cid, time.perf_counter() - t0,
                      sizes, replicated))
        except BaseException as e:       # noqa: BLE001 — shipped to driver
            cancelled.discard(cid)
            detail = repr(e)
            if cur is not None and cur != cid:
                # a fused super-task failed: name the MEMBER that raised,
                # so the error reads the same as an unfused run's would
                detail += (f" (in member task "
                           f"{graph.nodes[cur].name}#{cur})")
            outq.put(("error", wid, cid, type(e).__name__, detail))


def tcp_worker_main(address: str, *,
                    token: Optional[str] = None,
                    graph: Optional[TaskGraph] = None,
                    inputs: Optional[Dict[str, Any]] = None,
                    timeout: float = 30.0,
                    close_fds: Sequence[int] = ()) -> int:
    """Process entrypoint for TCP-channel workers (local forked dialers and
    the ``repro-worker`` CLI alike): dial the driver at ``address``,
    handshake, and run :func:`worker_main` with the negotiated identity and
    run config.

    A worker launched with ``graph`` already in hand (forked locally, graph
    inherited) advertises ``has_graph=True`` and the driver skips shipping
    it; a bare remote worker receives the pickled ``(graph, inputs)`` pair
    in the welcome frame.  The run's fusion view rides the welcome config
    either way.  Returns the assigned worker id.
    """
    import pickle

    from .channel import dial_driver

    # a fork-started dialer inherits the DRIVER's open fds — most fatally
    # its listening socket, which would keep the port bound after a driver
    # SIGKILL and block the restarted driver's re-bind.  The driver names
    # the fds the child must not hold; close them before anything else.
    for fd in close_fds:
        try:
            os.close(fd)
        except OSError:
            pass
    endpoint, wid, config, graph_blob = dial_driver(
        address, token=token, has_graph=graph is not None, timeout=timeout)
    if graph is None:
        if graph_blob is None:
            raise ChannelClosed(
                "driver sent no graph to a worker that has none")
        graph, inputs = pickle.loads(graph_blob)
    worker_main(wid, endpoint, graph, inputs,
                transport=config.get("transport", "driver"),
                shm_threshold=config.get("shm_threshold",
                                         serde.SHM_THRESHOLD),
                seg_prefix=config.get("seg_prefix", ""),
                peer_dir=config.get("peer_dir"),
                peer_host=config.get("peer_host", "127.0.0.1"),
                fusion=config.get("fusion"),
                fault_plan=config.get("fault_plan"),
                fetch_retry=config.get("fetch_retry"))
    return wid
