"""Worker-process side of the cluster runtime.

A worker is one OS process connected to the driver by a single duplex pipe.
It owns a *local object store* (``{tid: value}``) holding the results of
every task it has executed — plus, since the zero-copy data plane, a
replica of every transferred input it has resolved (reported back to the
driver in the ``done`` message so replica sets stay exact).  Bulk values no
longer cross the pipe: a ``fetch`` is answered with a small *handle*
(:class:`~repro.cluster.serde.Encoded` shared-memory refs, or a ``PeerRef``
to this worker's unix socket when shm is unavailable), and the consumer
maps/pulls the payload directly — worker-to-worker, driver untouched.

Message protocol (tuples; first element is the verb):

  driver -> worker
    ("run",   tid, extra)   execute task ``tid``; ``extra`` maps dep tid ->
                            transfer handle for inputs not already in this
                            worker's store
    ("fetch", tid)          publish ``tid`` and reply with its handle
    ("drop",  tids)         free stored values (driver-coordinated GC)
    ("stop",)               drain and exit

  worker -> driver
    ("done",    wid, tid, wall, nbytes, replicated)
                            task finished; value stays local.  ``nbytes``
                            feeds locality-aware placement; ``replicated``
                            lists dep tids this worker now also holds.
    ("error",   wid, tid, name, repr)    task raised; ``SerializationError``
                            means the *value* could not be published/moved —
                            surfaced as a task error, never a worker death
    ("value",   wid, tid, found, handle) fetch reply (handle, not payload)
    ("deplost", wid, tid, deps)          transfer handles in a ``run`` could
                            not be resolved (owner died mid-transfer);
                            driver re-queues the task and recovers the deps
    ("bye",     wid)                     shutdown ack

Workers are started with the ``fork`` start method, so the (closure-bearing,
generally unpicklable) :class:`~repro.core.graph.TaskGraph` and the run's
``inputs`` dict are inherited by memory copy — the paper's "ship the program
to every node" step costs one fork, and per-task messages carry only ids and
handles (a few hundred bytes, independent of payload size).
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

from repro.core.executor import _run_node as run_node   # noqa: F401 — the
# worker executes nodes with the EXACT core implementation so both backends
# share semantics (including the MissingInput contract; the driver re-raises
# it by name on its side)
from repro.core.graph import TaskGraph

from . import serde


def worker_main(wid: int, conn, graph: TaskGraph,
                inputs: Optional[Dict[str, Any]],
                transport: str = "driver",
                shm_threshold: int = serde.SHM_THRESHOLD,
                seg_prefix: str = "",
                peer_dir: Optional[str] = None) -> None:
    """Worker process body: reader thread + sender thread + compute loop.

    Deadlock-freedom argument (handles are small, but driver-transport
    payloads can still exceed the kernel pipe buffer): the reader thread
    does *nothing but recv*, so the driver's blocking dispatch-sends always
    drain; the sender thread does *nothing but send* from an outbox queue,
    so neither the reader nor a long-running task can ever stall an
    outgoing reply; the driver's pump loop drains worker output whenever it
    isn't mid-send.  Any single blocked pipe therefore unblocks without
    waiting on this process's compute.

    The reader answers ``fetch``/``drop`` directly (peers' input transfers
    are served while a task is running); ``run``/``stop`` are queued for
    the compute loop.  ``store`` accesses are single-op (GIL-atomic) dict
    operations.
    """
    import queue
    import threading
    import time

    store: Dict[int, Any] = {}
    published: Dict[int, serde.Handle] = {}     # memoized publish per tid
    keeper = serde.SegmentKeeper()      # pins zero-copy decoded mappings
    runq: "queue.SimpleQueue[tuple]" = queue.SimpleQueue()
    outq: "queue.SimpleQueue[Optional[tuple]]" = queue.SimpleQueue()
    namer = serde.SegmentNamer(f"{seg_prefix}w{wid}") if seg_prefix else None

    peer_server: Optional[serde.PeerServer] = None
    if transport == "sock" and peer_dir:
        try:
            peer_server = serde.PeerServer(
                os.path.join(peer_dir, f"w{wid}.sock"), store)
        except OSError:
            peer_server = None      # degrade to inline (driver) publishes

    def publish(tid: int) -> serde.Handle:
        """Produce (and memoize) the transfer handle for a stored value:
        shm-backed Encoded, a PeerRef to this worker's socket, or inline
        bytes for small values / driver transport."""
        handle = published.get(tid)
        if handle is not None:
            return handle
        value = store[tid]
        if (peer_server is not None
                and serde.payload_nbytes(value) >= shm_threshold):
            handle = serde.PeerRef(peer_server.path, tid,
                                   serde.payload_nbytes(value), wid)
        else:
            handle = serde.encode(
                value, transport=transport if transport != "sock" else
                "driver", threshold=shm_threshold, namer=namer)
        published[tid] = handle
        return handle

    def sender() -> None:
        while True:
            msg = outq.get()
            if msg is None:
                return
            try:
                conn.send(msg)
            except (BrokenPipeError, OSError):
                return
            except Exception as e:      # unpicklable/oversized payload in a
                # reply: report it as a task error instead of wedging the
                # outbox (which would read as a dead worker to the driver)
                tid = msg[2] if len(msg) > 2 and isinstance(msg[2], int) \
                    else -1
                try:
                    conn.send(("error", wid, tid,
                               "SerializationError", repr(e)))
                except (BrokenPipeError, OSError):
                    return
                except Exception:
                    pass

    def reader() -> None:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                runq.put(("stop",))      # driver went away
                return
            verb = msg[0]
            if verb == "fetch":
                tid = msg[1]
                if tid not in store:
                    outq.put(("value", wid, tid, False, None))
                else:
                    try:
                        outq.put(("value", wid, tid, True, publish(tid)))
                    except Exception as e:  # noqa: BLE001 — a value that
                        # cannot be serialized must surface on the consumer's
                        # future as a task error, not kill this worker
                        outq.put(("error", wid, tid,
                                  "SerializationError", repr(e)))
            elif verb == "drop":
                for t in msg[1]:
                    store.pop(t, None)
                    published.pop(t, None)
            else:                        # "run" / "stop"
                runq.put(msg)
                if verb == "stop":
                    return

    send_thread = threading.Thread(target=sender, daemon=True,
                                   name=f"worker-{wid}-sender")
    send_thread.start()
    threading.Thread(target=reader, daemon=True,
                     name=f"worker-{wid}-reader").start()
    while True:
        msg = runq.get()
        verb = msg[0]
        if verb == "stop":
            if peer_server is not None:
                peer_server.close()
            outq.put(("bye", wid))
            outq.put(None)
            send_thread.join(timeout=5.0)
            keeper.close()       # last mappings: safe, nothing runs after
            return
        if verb != "run":                # pragma: no cover — protocol bug
            raise RuntimeError(f"worker {wid}: unknown message {verb!r}")
        _, tid, extra = msg
        t0 = time.perf_counter()
        try:
            table: Dict[int, Any] = {}
            lost: List[int] = []
            replicated: List[int] = []
            for d, handle in extra.items():
                try:        # zero-copy: arrays view the mapped segment
                    table[d] = serde.resolve(handle, keeper)
                except serde.TransferLost:
                    lost.append(d)
            if lost:
                # owner died (or GC raced) between dispatch and resolve:
                # hand the task back; the driver recovers the inputs
                outq.put(("deplost", wid, tid, lost))
                continue
            for d, v in table.items():   # keep transferred inputs: replicas
                store[d] = v
                published.pop(d, None)
                replicated.append(d)
            for d in graph.nodes[tid].all_deps:
                if d not in table:
                    table[d] = store[d]
            value = run_node(graph, tid, table, inputs)
            store[tid] = value
            published.pop(tid, None)     # recompute invalidates old handle
            outq.put(("done", wid, tid, time.perf_counter() - t0,
                      serde.payload_nbytes(value), replicated))
        except BaseException as e:       # noqa: BLE001 — shipped to driver
            outq.put(("error", wid, tid, type(e).__name__, repr(e)))
